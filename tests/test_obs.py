"""Observability-layer invariants (repro.obs + the instrumented call sites).

The obs layer is only admissible if it is *free* when off and *inert* when
on:

  * trace-off bit-parity: ``run_protocol(trace=None)`` is bit-identical to
    the uninstrumented engine on every CPU-reachable backend (the disabled
    branch is Python-static, so the jaxpr itself is unchanged);
  * trace-on outcome invariance: enabling the flight recorder never changes
    assignments, lock state, or probe accounting — it only *adds* a
    ``TraceBuffer`` return;
  * ring-buffer honesty: per-kind ``counts`` are wraparound-immune
    (``counts.sum(axis=1) == n`` even when ``n > cap``), decoded events are
    chronological and drawn from the closed event vocabulary;
  * taxonomy closure: every classified trial gets a code from ``TAXONOMY``
    and the ``unknown`` bucket stays empty on the fig19 residual setup;
  * recorder transparency: a ``PhaseRecorder`` around ``sweep`` changes no
    grid value while capturing spans, chunk plans, and (under
    ``measure_memory``) compiled-memory watermarks vs the 256 MB budget;
  * health-matrix consistency: ``run_fabric_timeline(health=True)`` changes
    no chaos stat and its codes agree with the stats they summarize;
  * manifest round-trip: whatever the instruments record renders back
    through ``repro.obs.report``.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.fabric import FABRIC_TINY
from repro.configs.wdm import WDM16_G200, drift_timeline
from repro.core import (
    ArbitrationConfig,
    DWDMGrid,
    SweepRequest,
    make_units,
    run_timeline,
    slice_timeline,
    sweep,
)
from repro.core.protocol import default_rounds, run_protocol
from repro.core.relation import chain_spec
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables
from repro.fabric import make_fabric_timeline, run_fabric_timeline
from repro.fabric.sampling import make_fabric_units
from repro.obs import (
    EVENT_KINDS,
    HEALTH_CODES,
    PhaseRecorder,
    current_recorder,
    format_events,
    health_matrix_summary,
    measured_call,
    note,
    span,
    trace_buffer,
    trace_append,
    trace_events,
    trace_summary,
    use_recorder,
)
from repro.obs.manifest import RunManifest, latest_manifest, read_manifest
from repro.obs.report import render_report
from repro.obs.taxonomy import TAXONOMY, classify_trials, explain_residuals

CFG = ArbitrationConfig(grid=DWDMGrid(n_ch=8))
BACKENDS = (None, "jnp", "interpret")


@pytest.fixture(scope="module")
def tables():
    units = make_units(CFG, seed=3, n_laser=3, n_ring=4)
    sys_b = instantiate(CFG, units)
    return build_search_tables(sys_b, 3.0, max_alias=CFG.max_fsr_alias)


def _arrays(pytree):
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(pytree)]


# ---------------------------------------------------------------------------
# flight recorder: parity + ring semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_trace_on_changes_no_outcome(tables, backend):
    """trace= only *adds* a buffer: every other output is bit-identical,
    on every CPU-reachable backend column."""
    spec = chain_spec(CFG.s)
    kw = dict(with_stats=True, with_state=True, backend=backend)
    assign0, stats0, state0 = run_protocol(tables, spec, **kw)
    assign1, stats1, state1, buf = run_protocol(tables, spec, trace=32, **kw)
    for a, b in zip(_arrays((assign0, stats0, state0)),
                    _arrays((assign1, stats1, state1))):
        assert np.array_equal(a, b)
    # and the backend column itself changes nothing vs the default path
    if backend is not None:
        base = run_protocol(tables, spec, with_stats=True, with_state=True)
        for a, b in zip(_arrays(base), _arrays((assign0, stats0, state0))):
            assert np.array_equal(a, b)
    assert np.asarray(buf.n).sum() > 0  # the engine did record something


def test_trace_counts_are_wrap_immune(tables):
    """Per-kind counts survive ring overflow; decode is chronological and
    stays inside the closed event vocabulary."""
    spec = chain_spec(CFG.s)
    out_small = run_protocol(tables, spec, trace=4)   # tiny cap: overflows
    out_large = run_protocol(tables, spec, trace=256)  # effectively unbounded
    buf_s, buf_l = out_small[-1], out_large[-1]
    # counts/n are fired-event totals, independent of capacity
    assert np.array_equal(np.asarray(buf_s.n), np.asarray(buf_l.n))
    assert np.array_equal(np.asarray(buf_s.counts), np.asarray(buf_l.counts))
    assert np.array_equal(np.asarray(buf_l.counts).sum(axis=1),
                          np.asarray(buf_l.n))
    summ = trace_summary(buf_s)
    assert summ["events_total"] == int(np.asarray(buf_l.n).sum())
    assert summ["overflowed_trials"] == int((np.asarray(buf_s.n) > 4).sum())
    for ev in trace_events(buf_l):
        if not len(ev):
            continue
        assert ev.shape[1] == 4
        assert np.all((ev[:, 2] >= 0) & (ev[:, 2] < len(EVENT_KINDS)))
        assert np.all(np.diff(ev[:, 0]) >= 0)  # rounds never go backwards
        assert isinstance(format_events(ev, limit=5), str)
    # the overflowed ring keeps the *newest* cap events
    n = np.asarray(buf_l.n)
    trial = int(np.argmax(n))
    if n[trial] > 4:
        tail = trace_events(buf_l, trial)[-4:]
        assert np.array_equal(trace_events(buf_s, trial), tail)


def test_taxonomy_closed_on_fig19_residuals():
    """The acceptance gate at test scale: every WDM16 trial where seq_retry
    fails against a feasible ideal gets a non-unknown code."""
    cfg = WDM16_G200
    units = make_units(cfg, seed=21, n_laser=5, n_ring=5)
    trs = np.linspace(0.25 * cfg.grid.grid_spacing,
                      cfg.grid.n_ch * cfg.grid.grid_spacing, 12,
                      dtype=np.float32)[::4]
    tax = explain_residuals(cfg, units, trs, scheme="seq_retry", depth=1,
                            trace_cap=64)
    assert tax["unknown"] == 0
    assert "unknown" not in tax["histogram"]
    assert tax["residual_total"] > 0  # mid-TR seq_retry does fail here
    assert tax["residual_total"] == sum(tax["histogram"].values())
    for p in tax["points"]:
        assert all(0 <= c < len(TAXONOMY) for c in p["codes"])
        assert len(p["codes"]) == p["residual_trials"]


def test_classify_trials_locked_and_hopeless(tables):
    """Degenerate corners of the classifier: a fully locked trial is
    ST_LOCKED; an infeasible one is hopeless regardless of activity."""
    spec = chain_spec(CFG.s)
    _, stats, state, buf = run_protocol(
        tables, spec, with_stats=True, with_state=True, trace=64
    )
    t = state.lock.shape[0]
    rounds = default_rounds(CFG.grid.n_ch)
    complete = np.asarray((state.lock >= 0).all(axis=1))
    codes = np.asarray(classify_trials(
        state.lock, tables.n_valid, buf.counts, stats.worked, rounds=rounds
    ))
    assert codes.shape == (t,)
    assert np.all((codes >= 0) & (codes < len(TAXONOMY)))
    assert np.all((codes == TAXONOMY.index("locked")) == complete)
    # feasible=False forces every incomplete trial to "hopeless"
    codes_h = np.asarray(classify_trials(
        state.lock, tables.n_valid, buf.counts, stats.worked, rounds=rounds,
        feasible=jnp.zeros((t,), bool),
    ))
    assert np.all(codes_h[~complete] == TAXONOMY.index("hopeless"))


# ---------------------------------------------------------------------------
# phase telemetry: recorder transparency
# ---------------------------------------------------------------------------

def test_recorder_leaves_sweep_grid_bit_identical():
    units = make_units(CFG, seed=5, n_laser=3, n_ring=4)
    req = dict(cfg=CFG, units=units, scheme="seq_retry",
               axes={"tr_mean": np.linspace(1.5, 5.5, 3, dtype=np.float32)})
    bare = sweep(SweepRequest(**req))
    rec = PhaseRecorder(measure_memory=True)
    with use_recorder(rec):
        recd = sweep(SweepRequest(**req))
    assert current_recorder() is None  # context restored
    for a, b in zip(_arrays(bare.data), _arrays(recd.data)):
        assert np.array_equal(a, b)
    fields = rec.phase_fields()
    assert any(k.startswith("sweep") for k in fields)
    assert all(f["ms"] >= 0 for f in fields.values())
    # chunk plan + compiled-memory watermark landed as notes
    names = [n["name"] for n in rec.notes]
    assert "sweep.plan" in names
    mem = rec.memory_fields()
    assert any(n["name"].startswith("memory.sweep") for n in mem)
    wm = next(n for n in mem if "temp" in n["name"])
    assert 0 < wm["bytes"] and 0 < wm["frac"] < 1


def test_phase_helpers_are_noops_without_recorder(tables):
    """Module-level span()/note()/measured_call() cost nothing and change
    nothing when no recorder is installed — the default state everywhere."""
    assert current_recorder() is None
    with span("never-recorded", kind="host"):
        note("never.recorded", x=1)
    spec = chain_spec(CFG.s)
    plain = run_protocol(tables, spec)
    via = measured_call("p", run_protocol, (tables, spec), {},
                        dynamic_args=(tables,))
    assert np.array_equal(np.asarray(plain), np.asarray(via))


def test_recorder_span_nesting_and_current_path():
    rec = PhaseRecorder()
    with use_recorder(rec):
        with rec.span("outer"):
            with rec.span("inner", kind="compile"):
                assert rec.current_path() == "outer/inner"
        assert rec.current_path() is None
    by = rec.phase_fields()
    assert by["outer"]["count"] == 1 and by["inner"]["kind"] == "compile"


# ---------------------------------------------------------------------------
# chaos health matrix
# ---------------------------------------------------------------------------

def test_fabric_health_matrix_parity_and_consistency():
    spec = FABRIC_TINY
    n = CFG.grid.n_ch
    units = make_fabric_units(CFG, spec, 0)
    tl = make_fabric_timeline(spec, 3, n, thermal=0.15,
                              events=[(1, "link_kill", 0)])
    _, plain = run_fabric_timeline(CFG, units, spec, tl)
    _, obs = run_fabric_timeline(CFG, units, spec, tl, health=True)
    assert plain.health is None
    for a, b in zip(_arrays(plain._replace(health=None)),
                    _arrays(obs._replace(health=None))):
        assert np.array_equal(a, b)
    health = np.asarray(obs.health)
    assert health.shape == (3, spec.n_links) and health.dtype == np.int8
    assert np.all((health >= 0) & (health < len(HEALTH_CODES)))
    # the killed link reads "down" exactly while link_alive says so
    alive = np.asarray(tl.link_alive, bool)
    assert np.array_equal(health == 0, ~alive)
    summ = health_matrix_summary(obs.health)
    assert summ["steps"] == 3 and summ["links"] == spec.n_links
    assert summ["by_code"].get("down", 0) == int((~alive).sum())
    assert 0.0 <= summ["healthy_frac"] <= 1.0


# ---------------------------------------------------------------------------
# temporal: traced re-lock scans
# ---------------------------------------------------------------------------

def test_run_timeline_trace_parity_and_stacking():
    tcfg, tl = drift_timeline("wdm16-hotswap")
    tl = slice_timeline(tl, 0, 3)
    units = make_units(tcfg, seed=1, n_laser=4, n_ring=4)
    var = {"tr_mean": 4.0 * tcfg.grid.grid_spacing}
    final0, stats0 = run_timeline(tcfg, units, tl, var)
    final1, stats1, bufs = run_timeline(tcfg, units, tl, var, trace=16)
    for a, b in zip(_arrays((final0, stats0)), _arrays((final1, stats1))):
        assert np.array_equal(a, b)
    # lax.scan stacks one TraceBuffer per step
    assert bufs.ev.shape[0] == 3 and bufs.ev.shape[2] == 16
    assert np.array_equal(np.asarray(bufs.counts).sum(axis=-1),
                          np.asarray(bufs.n))


def test_run_timeline_trace_rejects_one_shot_schemes():
    tcfg, tl = drift_timeline("wdm16-hotswap")
    tl = slice_timeline(tl, 0, 2)
    units = make_units(tcfg, seed=1, n_laser=3, n_ring=3)
    with pytest.raises(ValueError, match="one-shot"):
        run_timeline(tcfg, units, tl, {"tr_mean": 5.0}, scheme="vtrs_ssm",
                     trace=8)


# ---------------------------------------------------------------------------
# manifest + report round-trip
# ---------------------------------------------------------------------------

def test_manifest_report_roundtrip(tmp_path):
    buf = trace_buffer(2, 4)
    fire = jnp.array([True, False])
    buf = trace_append(buf, fire, 0, 1, 0, 3)       # probe on trial 0
    buf = trace_append(buf, ~fire, 1, 2, 1, 5)      # lock on trial 1
    rec = PhaseRecorder()
    with rec.span("demo", kind="execute"):
        pass
    rec.memory("demo.temp", 64 << 20, 256 << 20)
    health = jnp.array([[4, 0], [2, 3]], jnp.int8)

    man = RunManifest.create(str(tmp_path), label="t", answer=42)
    with man:
        man.record_phases(rec, scope="ph")
        man.record_trace(buf, scope="tr",
                         taxonomy={"histogram": {"starvation": 1},
                                   "unknown": 0})
        man.record_health(health, scope="he")
        man.record_bench({"figure": "f", "name": "f/x", "module_wall_ms": 1.0,
                          "derived": {"v": 1}})

    assert latest_manifest(str(tmp_path)) == man.path
    lines = list(read_manifest(man.path))
    kinds = [l["kind"] for l in lines]
    for k in ("meta", "phases", "trace", "health", "bench_record"):
        assert k in kinds
    assert lines[0]["answer"] == 42
    # every line is plain JSON (numpy scrubbed)
    for line in lines:
        json.dumps(line)

    report = render_report(man.path)
    for section in ("phases [ph]", "trace [tr]", "health [he]",
                    "bench trajectory"):
        assert section in report
    assert "starvation" in report and "25.0%" in report  # 64/256 MiB note
    # corrupt trailing line is skipped, not fatal
    with open(man.path, "a") as fh:
        fh.write("{not json\n")
    assert len(list(read_manifest(man.path))) == len(lines)
