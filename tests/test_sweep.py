"""Sweep-engine golden tests: the batched engine must match the per-point
reference loop bit-for-bit, the vectorized relation search must match the
per-position loop, the Hall matching fast path must match Kuhn, and the
kernel wrappers must stay vmap-safe (the engine maps them over grid points)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.wdm import WDM8_G200
from repro.core import (
    ArbitrationConfig,
    evaluate_scheme,
    make_units,
    register_scheme,
    registered_schemes,
    sweep_grid,
    sweep_grid_reference,
    sweep_min_tr,
    sweep_policy,
    sweep_scheme,
)
from repro.core import matching
from repro.core.relation import chain_spec, relation_search, relation_search_loop
from repro.core.reach import reach_matrix, scaled_residual
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables
from repro.core.sequential import sequential_tuning

RLVS = np.array([0.28, 1.12, 2.24], np.float32)
TRS = np.array([2.0, 5.0, 9.5], np.float32)
AXES = {"sigma_rlv": RLVS, "tr_mean": TRS}


def _units(cfg, seed=4, n=6):
    return make_units(cfg, seed=seed, n_laser=n, n_ring=n)


# ---------------------------------------------------------------- engine ---

def test_policy_sweep_bit_exact_both_paths():
    """Engine == reference loop, with and without the TR fast path."""
    cfg = WDM8_G200
    units = _units(cfg)
    for policy in ("lta", "ltc", "ltd"):
        ref = np.asarray(sweep_grid_reference(cfg, units, AXES, policy=policy))
        fast = np.asarray(sweep_policy(cfg, units, policy, AXES))
        direct = np.asarray(sweep_policy(cfg, units, policy, AXES, tr_fast=False))
        assert np.array_equal(fast, ref), policy
        assert np.array_equal(direct, ref), policy


@pytest.mark.parametrize("scheme", ["seq", "vtrs_ssm"])
def test_scheme_sweep_bit_exact(scheme):
    cfg = WDM8_G200.with_orders("permuted")
    units = _units(cfg)
    res = sweep_scheme(cfg, units, scheme, AXES)
    ref = sweep_grid_reference(cfg, units, AXES, scheme=scheme)
    for field in res._fields:
        a = np.asarray(getattr(res, field))
        b = np.asarray(getattr(ref, field))
        assert np.array_equal(a, b), (scheme, field)


def test_scheme_sweep_fixed_overrides_bit_exact():
    cfg = WDM8_G200
    units = _units(cfg)
    fixed = {"sigma_fsr_frac": 0.05, "sigma_tr_frac": 0.20}
    res = sweep_scheme(cfg, units, "rs_ssm", {"tr_mean": TRS}, fixed=fixed)
    ref = sweep_grid_reference(cfg, units, {"tr_mean": TRS}, scheme="rs_ssm", fixed=fixed)
    assert np.array_equal(np.asarray(res.cafp), np.asarray(ref.cafp))


def test_min_tr_sweep_bit_exact():
    cfg = WDM8_G200
    units = _units(cfg)
    axes = {"fsr_mean": np.array([6.72, 8.96, 15.68], np.float32)}
    for policy in ("lta", "ltc"):
        got = np.asarray(sweep_min_tr(cfg, units, policy, axes))
        ref = np.asarray(
            sweep_grid_reference(cfg, units, axes, policy=policy, metric="min_tr")
        )
        assert np.array_equal(got, ref), policy


def test_sweep_chunking_invariant():
    """Chunk size is a pure performance knob: results are identical."""
    cfg = WDM8_G200
    units = _units(cfg)
    base = np.asarray(sweep_policy(cfg, units, "ltd", AXES))
    for chunk in (1, 9):
        got = np.asarray(sweep_policy(cfg, units, "ltd", AXES, chunk_size=chunk))
        assert np.array_equal(got, base), chunk


def test_sweep_single_axis_and_tr_only():
    """A tr_mean-only axis exercises the fast path's empty-sigma branch."""
    cfg = WDM8_G200
    units = _units(cfg)
    got = np.asarray(sweep_policy(cfg, units, "ltc", {"tr_mean": TRS}))
    ref = np.asarray(sweep_grid_reference(cfg, units, {"tr_mean": TRS}, policy="ltc"))
    assert np.array_equal(got, ref)


def test_sweep_axis_order_follows_axes_mapping():
    cfg = WDM8_G200
    units = _units(cfg)
    a = np.asarray(sweep_policy(cfg, units, "ltd", {"sigma_rlv": RLVS, "tr_mean": TRS}))
    b = np.asarray(sweep_policy(cfg, units, "ltd", {"tr_mean": TRS, "sigma_rlv": RLVS}))
    assert a.shape == (len(RLVS), len(TRS))
    assert b.shape == (len(TRS), len(RLVS))
    assert np.array_equal(a, b.T)


def test_sweep_backend_jnp_bit_exact():
    cfg = WDM8_G200
    units = _units(cfg)
    ref = np.asarray(sweep_grid_reference(cfg, units, AXES, policy="ltc"))
    got = np.asarray(sweep_policy(cfg, units, "ltc", AXES, backend="jnp"))
    assert np.array_equal(got, ref)
    res = sweep_scheme(cfg, units, "vtrs_ssm", {"tr_mean": TRS[:2]}, backend="jnp")
    sref = sweep_grid_reference(cfg, units, {"tr_mean": TRS[:2]}, scheme="vtrs_ssm")
    assert np.array_equal(np.asarray(res.cafp), np.asarray(sref.cafp))


def test_sweep_validation_errors():
    cfg = WDM8_G200
    units = _units(cfg, n=2)
    with pytest.raises(ValueError, match="exactly one"):
        sweep_grid(cfg, units, AXES)
    with pytest.raises(ValueError, match="unknown sweep axis"):
        sweep_policy(cfg, units, "ltc", {"bogus": RLVS})
    with pytest.raises(ValueError, match="cannot be an axis"):
        sweep_min_tr(cfg, units, "ltc", AXES)
    with pytest.raises(ValueError, match="overlap"):
        sweep_policy(cfg, units, "ltc", AXES, fixed={"sigma_rlv": 1.0})


def test_sweep_reference_validates_like_engine():
    """The oracle must reject exactly what the engine rejects (same shared
    validation): bad fixed names, axes/fixed overlap, metric misuse."""
    cfg = WDM8_G200
    units = _units(cfg, n=2)
    for call in (sweep_grid, sweep_grid_reference):
        with pytest.raises(ValueError, match="exactly one"):
            call(cfg, units, AXES)
        with pytest.raises(ValueError, match="unknown sweep axis"):
            call(cfg, units, {"tr_mean": TRS}, policy="ltc", fixed={"bogus": 1.0})
        with pytest.raises(ValueError, match="overlap"):
            call(cfg, units, AXES, policy="ltc", fixed={"sigma_rlv": 1.0})
        with pytest.raises(ValueError, match="unknown metric"):
            call(cfg, units, AXES, policy="ltc", metric="nope")
        with pytest.raises(ValueError, match="cannot be an axis"):
            call(cfg, units, AXES, policy="ltc", metric="min_tr")
        with pytest.raises(ValueError, match="policy sweeps"):
            call(cfg, units, {"sigma_rlv": RLVS}, scheme="seq", metric="min_tr")


# ---------------------------------------------------------- sharded mesh ---

def test_sweep_mesh_sharded_bit_exact_in_process():
    """shard_map over a 1-device host mesh == unsharded engine, for both a
    policy grid and a scheme EvalResult pytree."""
    from repro.launch.mesh import make_sweep_mesh

    cfg = WDM8_G200
    units = _units(cfg)
    mesh = make_sweep_mesh()
    base = np.asarray(sweep_policy(cfg, units, "ltc", AXES))
    got = np.asarray(sweep_policy(cfg, units, "ltc", AXES, mesh=mesh, chunk_size=2))
    assert np.array_equal(got, base)
    r0 = sweep_scheme(cfg, units, "seq", {"tr_mean": TRS})
    r1 = sweep_scheme(cfg, units, "seq", {"tr_mean": TRS}, mesh=mesh, chunk_size=2)
    for field in r0._fields:
        assert np.array_equal(
            np.asarray(getattr(r0, field)), np.asarray(getattr(r1, field))
        ), field


def test_sweep_mesh_must_be_1d():
    cfg = WDM8_G200
    units = _units(cfg, n=2)
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh2d = jax.sharding.Mesh(devs, ("a", "b"))
    with pytest.raises(ValueError, match="1-D"):
        sweep_policy(cfg, units, "ltc", AXES, mesh=mesh2d)


def test_sweep_mesh_size_invariance_subprocess():
    """Mesh size is a pure performance knob: 1-device and 8-placeholder-
    device grids are bit-identical to the unsharded engine (wdm16, so the
    N > 10 bottleneck sweep runs under shard_map too).  Subprocess because
    jax locks the host device count at first init."""
    import os
    import subprocess
    import sys as _sys
    from pathlib import Path

    script = """
import numpy as np
from repro.configs.wdm import WDM16_G200
from repro.core import make_units, sweep_policy
from repro.launch.mesh import make_sweep_mesh

cfg = WDM16_G200
units = make_units(cfg, seed=4, n_laser=5, n_ring=5)
axes = {"sigma_rlv": np.array([0.28, 2.24], np.float32),
        "tr_mean": np.array([4.0, 9.5], np.float32)}
base = np.asarray(sweep_policy(cfg, units, "lta", axes))
for nd in (1, 8):
    got = np.asarray(
        sweep_policy(cfg, units, "lta", axes, mesh=make_sweep_mesh(nd), chunk_size=1)
    )
    assert np.array_equal(got, base), nd
print("MESH_INVARIANT_OK")
"""
    root = Path(__file__).resolve().parent.parent
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=str(root / "src") + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    out = subprocess.run(
        [_sys.executable, "-c", script],
        capture_output=True, text=True, env=env, cwd=root, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_INVARIANT_OK" in out.stdout


# ---------------------------------------------------- streaming tables ---

def test_streaming_tables_multi_block_merge_matches_dense():
    """Non-degenerate streaming: at this shape ``merge_plan`` splits the
    build into 32 fori_loop steps (line_block=8, ring_block=1), so the
    cross-block stable-merge/tie-order logic itself runs — not the
    single-sort degenerate case that small test shapes collapse to.
    (Lives here rather than test_property.py so it runs even where
    hypothesis is unavailable.)"""
    from functools import partial

    from repro.core import DWDMGrid
    from repro.core.search_table import (
        build_search_tables,
        build_search_tables_dense,
        merge_plan,
    )

    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=16))
    sys = instantiate(cfg, make_units(cfg, 13, 32, 32))  # T = 1024
    T, N = sys.laser.shape
    plan = merge_plan(T, N, max_alias=8)
    steps = (N // plan.line_block) * (N // plan.ring_block)
    assert steps > 1, plan  # the point of this test: a real multi-step merge

    @partial(jax.jit, static_argnames=("has_vis",))
    def both(s, vis, has_vis):
        v = vis if has_vis else None
        return (build_search_tables(s, 9.5, visible=v, max_alias=8),
                build_search_tables_dense(s, 9.5, visible=v, max_alias=8))

    for vis in (None, jax.random.bernoulli(jax.random.key(3), 0.6, (T, N, N))):
        stream, dense = both(
            sys, vis if vis is not None else jnp.zeros(()), vis is not None
        )
        assert np.array_equal(np.asarray(stream.wl), np.asarray(dense.wl))
        assert np.array_equal(np.asarray(stream.n_valid), np.asarray(dense.n_valid))
        assert np.array_equal(
            np.asarray(stream.delta), np.asarray(dense.delta), equal_nan=True
        )


# ------------------------------------------------------- relation search ---

@pytest.mark.parametrize("kind", ["natural", "permuted"])
@pytest.mark.parametrize("vt", [False, True])
def test_relation_search_vectorized_matches_loop(kind, vt):
    cfg = ArbitrationConfig().with_orders(kind)
    for seed, tr_mean in ((0, 3.0), (1, 9.5)):
        sys = instantiate(cfg, make_units(cfg, seed, 5, 5))
        tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
        spec = chain_spec(cfg.s)
        vec = np.asarray(relation_search(tables, spec, variation_tolerant=vt))
        loop = np.asarray(relation_search_loop(tables, spec, variation_tolerant=vt))
        assert np.array_equal(vec, loop), (seed, tr_mean)


# ------------------------------------------------------------- hall path ---

def _kuhn_bottleneck(w):
    """Value oracle: binary search over sorted edge weights with Kuhn
    matching existence checks (the pre-Hall reference implementation)."""
    import math

    T, N, _ = w.shape
    cand = np.sort(np.asarray(w).reshape(T, N * N), axis=1)
    lo = np.zeros(T, np.int32)
    hi = np.full(T, N * N - 1, np.int32)
    for _ in range(int(math.ceil(math.log2(N * N))) + 1):
        mid = (lo + hi) // 2
        thr = cand[np.arange(T), mid]
        adj = matching.adjacency_bitmask(jnp.asarray(w) <= thr[:, None, None])
        mw, _ = matching.max_matching(adj)
        ok = np.asarray(jnp.all(mw >= 0, axis=1))
        lo = np.where(ok, lo, mid + 1)
        hi = np.where(ok, mid, hi)
    return cand[np.arange(T), hi]


def test_hall_matching_matches_kuhn():
    cfg = WDM8_G200
    sys = instantiate(cfg, make_units(cfg, 3, 6, 6))
    w = scaled_residual(sys)
    hall_thr = np.asarray(matching._bottleneck_threshold_hall(w))
    # value-level oracle: the Hall threshold is bit-for-bit the binary-search
    # result (an actual edge weight), not merely consistent at spot TRs
    assert np.array_equal(hall_thr, _kuhn_bottleneck(w))
    for tr in (2.0, 4.0, 8.96):
        reach = reach_matrix(sys, tr)
        hall = np.asarray(matching._has_perfect_matching_hall(reach))
        adj = matching.adjacency_bitmask(reach)
        mw, _ = matching.max_matching(adj)
        kuhn = np.asarray(jnp.all(mw >= 0, axis=1))
        assert np.array_equal(hall, kuhn), tr
        # threshold form consistent with existence form at every TR
        assert np.array_equal(hall_thr <= tr, kuhn), tr


# ----------------------------------------------------------- ops vmap -----

def test_ops_wrappers_vmap_safe_jnp():
    from repro.kernels import ops
    from repro.core import DWDMGrid

    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=4))
    sys = instantiate(cfg, make_units(cfg, 0, 3, 3))
    s = tuple(int(v) for v in cfg.s)
    scales = jnp.asarray([0.9, 1.0, 1.1])

    ltd, ltc = jax.vmap(
        lambda t: ops.feasibility(sys.laser, sys.ring, sys.fsr, sys.tr_unit * t,
                                  s=s, backend="jnp")
    )(scales)
    assert ltd.shape == (3, sys.n_trials)
    # batch slice 1.0 must equal the unbatched call
    l0, c0 = ops.feasibility(sys.laser, sys.ring, sys.fsr, sys.tr_unit, s=s,
                             backend="jnp")
    assert np.array_equal(np.asarray(ltc[1]), np.asarray(c0))

    d, w, nv = jax.vmap(
        lambda t: ops.build_tables(sys.laser, sys.ring, sys.fsr, t * sys.tr_unit,
                                   max_alias=4, backend="jnp")
    )(jnp.asarray([4.0, 5.0]))
    d0, w0, nv0 = ops.build_tables(sys.laser, sys.ring, sys.fsr, 5.0 * sys.tr_unit,
                                   max_alias=4, backend="jnp")
    assert np.array_equal(np.asarray(nv[1]), np.asarray(nv0))
    assert np.array_equal(np.asarray(w[1]), np.asarray(w0))

    adj = matching.adjacency_bitmask(reach_matrix(sys, 4.0))
    mw, ok = jax.vmap(lambda _: ops.perfect_matching(adj, backend="jnp"))(
        jnp.arange(2)
    )
    mw0, ok0 = ops.perfect_matching(adj, backend="jnp")
    assert np.array_equal(np.asarray(ok[0]), np.asarray(ok0))


@pytest.mark.slow
def test_ops_wrappers_vmap_safe_interpret():
    from repro.kernels import ops

    cfg = ArbitrationConfig()
    sys = instantiate(cfg, make_units(cfg, 0, 3, 3))
    s = tuple(int(v) for v in cfg.s)
    ltd, ltc = jax.vmap(
        lambda t: ops.feasibility(sys.laser, sys.ring, sys.fsr, sys.tr_unit * t,
                                  s=s, backend="interpret")
    )(jnp.asarray([1.0, 1.1]))
    l0, c0 = ops.feasibility(sys.laser, sys.ring, sys.fsr, sys.tr_unit, s=s,
                             backend="interpret")
    np.testing.assert_allclose(np.asarray(ltd[0]), np.asarray(l0), atol=1e-5)


# ------------------------------------------------------------- registry ---

def test_scheme_registry_round_trip():
    name = "test_seq_clone"
    if name not in registered_schemes():
        register_scheme(name, lambda cfg, tables, spec: sequential_tuning(tables, spec))
    cfg = WDM8_G200
    units = _units(cfg, n=4)
    # registered schemes work through the sweep engine exactly like built-ins
    ra = sweep_scheme(cfg, units, name, {"tr_mean": TRS[:2]})
    rb = sweep_scheme(cfg, units, "seq", {"tr_mean": TRS[:2]})
    assert np.array_equal(np.asarray(ra.cafp), np.asarray(rb.cafp))


def test_scheme_registry_errors():
    with pytest.raises(ValueError, match="already registered"):
        register_scheme("seq", lambda cfg, tables, spec: None)
    with pytest.raises(ValueError, match="unknown scheme"):
        cfg = WDM8_G200
        evaluate_scheme(cfg, _units(cfg, n=2), "no_such_scheme", 5.0)
    with pytest.raises(ValueError, match="policy"):
        register_scheme("bad_policy_scheme", lambda c, t, s: None, policy="nope")


# ------------------------------------------------- backend -> arbiters ---

_SPY_BACKENDS = []


def test_backend_reaches_registered_arbiter():
    """SweepRequest.backend is forwarded into the scheme's arbiter.

    The spy arbiter records the backend value it receives at trace time;
    the legacy 3-arg lambda above (``test_seq_clone``) proves old-style
    arbiters still register (``_normalize_arbiter`` swallows the kwarg)."""
    name = "test_backend_spy"
    if name not in registered_schemes():
        def spy(cfg, tables, spec, *, backend=None):
            _SPY_BACKENDS.append(backend)
            return sequential_tuning(tables, spec)

        register_scheme(name, spy)
    cfg = WDM8_G200
    units = _units(cfg, n=4)
    _SPY_BACKENDS.clear()
    sweep_scheme(cfg, units, name, {"tr_mean": TRS[:1]}, backend="jnp")
    assert "jnp" in _SPY_BACKENDS
    _SPY_BACKENDS.clear()
    sweep_scheme(cfg, units, name, {"tr_mean": TRS[:2]})
    assert _SPY_BACKENDS and set(_SPY_BACKENDS) == {None}


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_protocol_scheme_backend_parity(backend):
    """A *registered* protocol scheme honors SweepRequest.backend — the
    kernel-backed masked re-search loop must match core jnp bit-for-bit."""
    cfg = WDM8_G200
    units = _units(cfg, n=4)
    axes = {"tr_mean": TRS[:2]}
    base = sweep_scheme(cfg, units, "protocol_lta_h1", axes)
    got = sweep_scheme(cfg, units, "protocol_lta_h1", axes, backend=backend)
    for field in ("cafp", "afp", "lock_err", "order_err"):
        a = np.asarray(getattr(got, field))
        b = np.asarray(getattr(base, field))
        assert np.array_equal(a, b), (backend, field)
