"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept across channel counts, trial counts (incl. non-multiples of the lane
block), orderings and tuning ranges."""
import numpy as np
import pytest

from repro.core import ArbitrationConfig, DWDMGrid, make_units, permuted_order
from repro.core.matching import adjacency_bitmask
from repro.core.reach import reach_matrix, scaled_residual
from repro.core.sampling import instantiate
from repro.kernels import ops


# n=10 -> 100 trials: fits one 128-lane interpret block (half the cost
# of the previous 144-trial default) with identical coverage.
def _sys(n_ch=8, seed=0, n=10, kind="natural"):
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=n_ch)).with_orders(kind)
    units = make_units(cfg, seed=seed, n_laser=n, n_ring=n)
    return cfg, instantiate(cfg, units)


@pytest.mark.parametrize("n_ch", [4, 8, pytest.param(16, marks=pytest.mark.slow)])
@pytest.mark.parametrize("kind", ["natural", "permuted"])
def test_feasibility_kernel(n_ch, kind):
    cfg, sys = _sys(n_ch=n_ch, kind=kind)
    s = tuple(int(v) for v in cfg.s)
    args = (sys.laser, sys.ring, sys.fsr, sys.tr_unit)
    ltd_k, ltc_k = ops.feasibility(*args, s=s, backend="interpret")
    ltd_r, ltc_r = ops.feasibility(*args, s=s, backend="jnp")
    np.testing.assert_allclose(np.asarray(ltd_k), np.asarray(ltd_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ltc_k), np.asarray(ltc_r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_trials", [7, 128, 150])
def test_feasibility_kernel_padding(n_trials):
    """Trial counts that are not lane-block multiples survive padding."""
    import math

    n = max(2, int(math.isqrt(n_trials)))
    cfg, sys = _sys(n=n)
    t = min(n_trials, sys.n_trials)
    sub = type(sys)(*[a[:t] for a in sys])
    s = tuple(int(v) for v in cfg.s)
    args = (sub.laser, sub.ring, sub.fsr, sub.tr_unit)
    ltd_k, ltc_k = ops.feasibility(*args, s=s, backend="interpret")
    ltd_r, ltc_r = ops.feasibility(*args, s=s, backend="jnp")
    assert ltd_k.shape == (t,)
    np.testing.assert_allclose(np.asarray(ltd_k), np.asarray(ltd_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ltc_k), np.asarray(ltc_r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_ch", [4, 8, pytest.param(16, marks=pytest.mark.slow)])
@pytest.mark.parametrize("tr_mean", [2.0, 4.5, 9.0])
def test_match_kernel(n_ch, tr_mean):
    _, sys = _sys(n_ch=n_ch, seed=1)
    adj = adjacency_bitmask(reach_matrix(sys, tr_mean))
    mw_k, ok_k = ops.perfect_matching(adj, backend="interpret")
    mw_r, ok_r = ops.perfect_matching(adj, backend="jnp")
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    # Where a perfect matching exists both must produce a *valid* one.
    okk = np.asarray(ok_k)
    mwk = np.asarray(mw_k)
    adj_np = np.asarray(adj)
    for t in np.where(okk)[0][:32]:
        wl = mwk[t]
        assert len(set(wl.tolist())) == n_ch          # all distinct lines
        for i in range(n_ch):
            assert (adj_np[t, i] >> wl[i]) & 1 == 1   # edges exist


@pytest.mark.parametrize("n_ch", [8, 16])
def test_bottleneck_kernel(n_ch):
    """Bottleneck sweep kernel (interpret) vs the jnp dispatch — N=8 crosses
    the Hall path, N=16 the core single-pass sweep; all bit-identical."""
    _, sys = _sys(n_ch=n_ch, seed=3, n=6)        # 36 trials, one padded block
    w = scaled_residual(sys)
    thr_k = ops.bottleneck_threshold(w, backend="interpret")
    thr_r = ops.bottleneck_threshold(w, backend="jnp")
    np.testing.assert_array_equal(np.asarray(thr_k), np.asarray(thr_r))


@pytest.mark.parametrize("n_ch", [4, 8, pytest.param(16, marks=pytest.mark.slow)])
@pytest.mark.parametrize("tr_mean", [2.0, 5.0, 9.5])
@pytest.mark.parametrize("max_alias", [2, pytest.param(4, marks=pytest.mark.slow)])
def test_table_kernel(n_ch, tr_mean, max_alias):
    _, sys = _sys(n_ch=n_ch, seed=2)
    tr = tr_mean * sys.tr_unit
    args = (sys.laser, sys.ring, sys.fsr, tr)
    d_k, w_k, nv_k = ops.build_tables(*args, max_alias=max_alias, backend="interpret")
    d_r, w_r, nv_r = ops.build_tables(*args, max_alias=max_alias, backend="jnp")
    np.testing.assert_array_equal(np.asarray(nv_k), np.asarray(nv_r))
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    fin = np.isfinite(np.asarray(d_r))
    np.testing.assert_allclose(np.asarray(d_k)[fin], np.asarray(d_r)[fin], atol=1e-5)


def test_table_kernel_multi_group_merge(monkeypatch):
    """Force a multi-group alias merge at CI-affordable size: with the VMEM
    row bound shrunk to 64, n_ch=8 / max_alias=8 splits into 4 merge steps
    per ring (alias_group=5), exercising the cross-group top-E buffer logic
    that the default test shapes collapse to a single sort."""
    from repro.kernels import table_build

    monkeypatch.setattr(table_build, "_VMEM_ROWS", 64)
    table_build.table_pallas.clear_cache()  # drop single-sort compilations
    try:
        _, sys = _sys(n_ch=8, seed=6, n=8)  # 64 trials, padded to one block
        tr = 9.5 * sys.tr_unit              # TR ~ FSR: multi-alias entries
        args = (sys.laser, sys.ring, sys.fsr, tr)
        d_k, w_k, nv_k = ops.build_tables(*args, max_alias=8, backend="interpret")
        d_r, w_r, nv_r = ops.build_tables(*args, max_alias=8, backend="jnp")
        np.testing.assert_array_equal(np.asarray(nv_k), np.asarray(nv_r))
        np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
        fin = np.isfinite(np.asarray(d_r))
        np.testing.assert_allclose(
            np.asarray(d_k)[fin], np.asarray(d_r)[fin], atol=1e-5
        )
    finally:
        table_build.table_pallas.clear_cache()


@pytest.mark.parametrize("vis_ndim", [2, 3])
def test_table_kernel_visible_masks(vis_ndim):
    """Visible-masked re-search through the kernel wrappers: interpret-mode
    streaming merge vs the jnp streaming builder, with bus-wide (2-D) and
    per-ring (3-D) masks including fully-masked rings (n_valid == 0)."""
    import jax

    _, sys = _sys(n_ch=8, seed=5)
    T, N = sys.laser.shape
    shape = (T, N) if vis_ndim == 2 else (T, N, N)
    vis = jax.random.bernoulli(jax.random.key(0), 0.5, shape)
    if vis_ndim == 3:
        vis = vis.at[: T // 2].set(False)
    tr = 5.0 * sys.tr_unit
    args = (sys.laser, sys.ring, sys.fsr, tr)
    d_k, w_k, nv_k = ops.build_tables(
        *args, visible=vis, max_alias=2, backend="interpret"
    )
    d_r, w_r, nv_r = ops.build_tables(*args, visible=vis, max_alias=2, backend="jnp")
    np.testing.assert_array_equal(np.asarray(nv_k), np.asarray(nv_r))
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    fin = np.isfinite(np.asarray(d_r))
    np.testing.assert_allclose(np.asarray(d_k)[fin], np.asarray(d_r)[fin], atol=1e-5)
    if vis_ndim == 3:
        assert int(np.asarray(nv_r)[: T // 2].max()) == 0
