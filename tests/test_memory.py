"""Compiled-memory smoke tests (XLA ``memory_analysis`` pins).

The streaming top-E table build keeps the scheme path's compiled temp
footprint at O(T*N*E) + a bounded merge transient.  A regression back to
the dense (T, N, N*J) candidate tensor multiplies the WDM32 bench-scale
temps ~8x (measured: ~21 MB streaming vs ~160 MB for the dense builder
alone), so it fails these bounds in CI long before it OOMs a paper-scale
sweep on a user's machine.
"""
import jax
import pytest

from repro.configs.wdm import WDM32_G200
from repro.core import evaluate_scheme, make_units
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables, merge_plan
from repro.core.sweep import scheme_point_bytes


def _temp_bytes(lowered):
    stats = lowered.compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        pytest.skip("backend does not report compiled memory statistics")
    return stats.temp_size_in_bytes


def test_streaming_builder_compiled_temps_match_plan():
    """The builder's compiled scratch stays within its own ``merge_plan``
    accounting (tables + transient) at WDM32 bench scale."""
    cfg = WDM32_G200
    units = make_units(cfg, seed=0, n_laser=24, n_ring=24)
    sys = instantiate(cfg, units)
    T, N = sys.laser.shape
    lowered = jax.jit(
        lambda s: build_search_tables(s, 9.0, max_alias=cfg.max_fsr_alias)
    ).lower(sys)
    plan = merge_plan(T, N, max_alias=cfg.max_fsr_alias)
    assert _temp_bytes(lowered) <= plan.total_bytes


def test_scheme_path_compiled_temps_wdm32():
    """End-to-end scheme evaluation (tables + record phase + SSM + scoring)
    at WDM32 bench scale: compiled temps stay within 1.5x of the engine's
    per-point estimate.  The dense candidate tensor alone would be ~7x over
    this bound (measured ~160 MB vs the ~34 MB allowance)."""
    cfg = WDM32_G200
    units = make_units(cfg, seed=0, n_laser=24, n_ring=24)
    trials = units.u_rlv.shape[0] * units.u_go.shape[0]
    lowered = evaluate_scheme.lower(cfg, units, "vtrs_ssm", 9.0)
    bound = int(1.5 * scheme_point_bytes(cfg, trials))
    assert _temp_bytes(lowered) <= bound
