"""Compiled-memory smoke tests (XLA ``memory_analysis`` pins).

The streaming top-E table build keeps the scheme path's compiled temp
footprint at O(T*N*E) + a bounded merge transient.  A regression back to
the dense (T, N, N*J) candidate tensor multiplies the WDM32 bench-scale
temps ~8x (measured: ~21 MB streaming vs ~160 MB for the dense builder
alone), so it fails these bounds in CI long before it OOMs a paper-scale
sweep on a user's machine.
"""
import jax
import pytest

from repro.configs.wdm import WDM32_G200, WDM64_G200
from repro.core import evaluate_scheme, make_units
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables, merge_plan
from repro.core.sweep import scheme_point_bytes


def _temp_bytes(lowered):
    stats = lowered.compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        pytest.skip("backend does not report compiled memory statistics")
    return stats.temp_size_in_bytes


@pytest.mark.parametrize("cfg_name", ["wdm32", "wdm64"])
def test_streaming_builder_compiled_temps_match_plan(cfg_name):
    """The rank-merge builder's compiled scratch stays within its own
    ``merge_plan`` accounting (tables + transient) at WDM32/WDM64 bench
    scale (measured ~22.3/50.6 MB vs plans of 22.6/73.6 MB)."""
    cfg = {"wdm32": WDM32_G200, "wdm64": WDM64_G200}[cfg_name]
    units = make_units(cfg, seed=0, n_laser=24, n_ring=24)
    sys = instantiate(cfg, units)
    T, N = sys.laser.shape
    lowered = jax.jit(
        lambda s: build_search_tables(s, 9.0, max_alias=cfg.max_fsr_alias)
    ).lower(sys)
    plan = merge_plan(T, N, max_alias=cfg.max_fsr_alias)
    assert _temp_bytes(lowered) <= plan.total_bytes


@pytest.mark.parametrize("cfg_name", ["wdm32", "wdm64"])
def test_scheme_path_compiled_temps(cfg_name):
    """End-to-end scheme evaluation (tables + record phase + SSM + scoring)
    at WDM32/WDM64 bench scale: compiled temps stay within 2x of the
    engine's per-point estimate (rank-merge measured at 1.63x/1.46x — the
    extra over 1x is the fori_loop's double-buffered table carry plus the
    SSM/scoring stages' own temps).  The dense candidate tensor alone would
    blow this bound ~4x at WDM32 (measured ~160 MB vs the ~45 MB allowance)
    long before it OOMs a paper-scale sweep on a user's machine."""
    cfg = {"wdm32": WDM32_G200, "wdm64": WDM64_G200}[cfg_name]
    units = make_units(cfg, seed=0, n_laser=24, n_ring=24)
    trials = units.u_rlv.shape[0] * units.u_go.shape[0]
    lowered = evaluate_scheme.lower(cfg, units, "vtrs_ssm", 9.0)
    bound = int(2.0 * scheme_point_bytes(cfg, trials))
    assert _temp_bytes(lowered) <= bound
