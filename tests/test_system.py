"""End-to-end behaviour tests for the paper's system: the arbitration stack
wired into the deployment-facing surfaces (optics fabric, failure-rate
planning, scheme selection) behaves as the paper prescribes."""
import numpy as np

from repro.configs.wdm import WDM8_G200, WDM16_G200
from repro.core import evaluate_scheme, make_units
from repro.optics import bringup, expected_failure_rates, rearbitrate
from repro.optim.compression import compress, compression_for_bandwidth, init_feedback


def test_fleet_failure_rates_scale_with_tuning_range():
    """System-level: widening the tuner range buys yield (AFP down),
    while the algorithm's conditional failures stay ~0 (VT-RS/SSM)."""
    afps = []
    for tr in (3.0, 5.0, 8.0):
        r = expected_failure_rates(WDM8_G200, tr, n=24)
        afps.append(r["afp"])
        assert r["cafp"] <= 0.02
    assert afps[0] > afps[1] > afps[2] - 1e-9


def test_bringup_rearbitrate_recovers_bandwidth():
    fab = bringup(pods=2, links_per_pod_pair=12, cfg=WDM16_G200, tr_mean=9.0)
    fab2, _ = rearbitrate(fab, WDM16_G200, seed=3)
    assert fab2.bandwidth_fraction >= fab.bandwidth_fraction
    assert all(l.lanes_total == 16 for l in fab2.links)


def test_scheme_selection_tradeoff():
    """§V-D holistic selection: VT-RS/SSM never does worse than RS/SSM and
    both dominate sequential (the deployment default is VT)."""
    units = make_units(WDM8_G200, seed=77, n_laser=20, n_ring=20)
    for tr in (4.0, 7.0):
        seq = float(evaluate_scheme(WDM8_G200, units, "seq", tr).cafp)
        rs = float(evaluate_scheme(WDM8_G200, units, "rs_ssm", tr).cafp)
        vt = float(evaluate_scheme(WDM8_G200, units, "vtrs_ssm", tr).cafp)
        assert vt <= rs <= seq


def test_gradient_compression_error_feedback():
    """Cross-pod degraded-link path: compression is lossy per step but the
    residual carries the rest (sum over steps ~ dense sum)."""
    import jax.numpy as jnp

    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)), jnp.float32)}
    state = init_feedback({"w": g["w"]})
    sent_total = np.zeros((64, 32), np.float32)
    for _ in range(30):
        send, state, stats = compress(g, state, k_frac=0.1)
        sent_total += np.asarray(send["w"])
        assert stats["wire_fraction"] <= 0.21
    # error feedback: transmitted mass converges to the dense gradient sum
    dense_total = np.asarray(g["w"]) * 30
    rel = np.abs(sent_total - dense_total).mean() / np.abs(dense_total).mean()
    assert rel < 0.15, rel
    k = compression_for_bandwidth(0.5)
    assert 0.0 < k <= 0.25
