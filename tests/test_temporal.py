"""Temporal re-arbitration invariants (repro.core.temporal + the protocol
engine's warm-start contract).

The restartable-state refactor is only sound if:

  * warm fixed point: resuming from a completed trial's state costs nothing
    — zero probes, zero executed rounds, zero churn;
  * cold-start equivalence: ``init_state=None`` and an explicit
    ``cold_state`` produce bit-identical assignments and stats (the
    pre-refactor behavior is the None spelling);
  * lane-kill isolation: after a single lane kill, unaffected feasible
    locks are never disturbed — under transactional re-arbitration an
    infeasible re-lock rolls back entirely, and a feasible one (dead lane
    paired with a freed line) re-locks only the broken ring;
  * batch independence: per-trial probe/refund accounting is identical
    whether a trial runs alone or inside a batch, including when resumed
    mid-timeline from a checkpoint;
  * resume equivalence: a timeline split at any step, checkpointed through
    ``checkpoint/store.py`` and resumed, replays bit-identically.

As in tests/test_protocol.py the checks run twice: deterministic
parametrized cases (always on) and hypothesis variants when importable.
"""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on container
    HAVE_HYPOTHESIS = False

from repro.configs.wdm import DRIFT_SCENARIOS, drift_timeline
from repro.core import (
    ArbitrationConfig,
    DWDMGrid,
    SweepRequest,
    cold_state,
    make_timeline,
    make_units,
    restore_campaign,
    revalidate_state,
    run_protocol,
    run_timeline,
    save_campaign,
    slice_timeline,
    sweep,
    sweep_reference,
)
from repro.core.protocol import ProtocolState
from repro.core.relation import chain_spec
from repro.core.sampling import SystemBatch, instantiate
from repro.core.search_table import build_search_tables

SETTINGS = dict(max_examples=6, deadline=None)

#: deterministic (n_ch, seed, tr_mean) grid for the always-on runs
CASES = [
    (4, 0, 3.0),
    (8, 1, 4.0),
    (8, 5, 6.0),
]


def _system(n_ch, seed, n=3):
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=n_ch))
    units = make_units(cfg, seed, n, n)
    return cfg, units, instantiate(cfg, units)


def _tables_spec(cfg, sys, tr_mean):
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    return tables, chain_spec(cfg.s)


def _dense_system(n_ch=8, t=4):
    """Every ring reaches every line: laser on-grid, rings centered, TR huge.

    Deterministic playground for the lane-kill isolation invariant — any
    starved ring can always see every unclaimed line, so a seeker never
    needs a donor chain.
    """
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=n_ch))
    laser = jnp.broadcast_to(
        jnp.arange(n_ch, dtype=jnp.float32)[None, :] * 0.8, (t, n_ch)
    )
    ring = jnp.zeros((t, n_ch), jnp.float32)
    fsr = jnp.full((t, n_ch), 100.0, jnp.float32)
    sys = SystemBatch(laser=laser, ring=ring, fsr=fsr,
                      tr_unit=jnp.ones((t, n_ch), jnp.float32))
    return cfg, sys


# ------------------------------------------------------ invariant checkers --

def check_cold_state_equivalence(n_ch, seed, tr_mean):
    """init_state=None == explicit cold_state, bit for bit."""
    cfg, _, sys = _system(n_ch, seed)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    t = sys.laser.shape[0]
    a0, s0 = run_protocol(tables, spec, with_stats=True)
    a1, s1, _ = run_protocol(tables, spec, with_stats=True,
                             init_state=cold_state(t, n_ch), with_state=True)
    for x, y in zip(jax.tree.leaves((a0, s0)), jax.tree.leaves((a1, s1))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def check_warm_fixed_point(n_ch, seed, tr_mean):
    """Resuming a finished run is free: no probes, no executed rounds, and
    the state (hence every lock) is unchanged."""
    cfg, _, sys = _system(n_ch, seed)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    _, _, state = run_protocol(tables, spec, with_stats=True, with_state=True)
    resumed = state._replace(probes=jnp.zeros_like(state.probes))
    _, stats, state2 = run_protocol(tables, spec, with_stats=True,
                                    with_state=True, init_state=resumed)
    done = np.asarray(jnp.all(state.lock >= 0, axis=1))
    assert np.all(np.asarray(stats.probes)[done] == 0)
    assert np.all(np.asarray(stats.worked)[done] == 0)
    np.testing.assert_array_equal(
        np.asarray(state2.lock)[done], np.asarray(state.lock)[done]
    )
    np.testing.assert_array_equal(
        np.asarray(state2.entry)[done], np.asarray(state.entry)[done]
    )


def check_batch_independent_resume(n_ch, seed, tr_mean):
    """Per-trial accounting (probes incl. sticky-halt refunds, rounds,
    locks) is identical for a trial alone vs inside the batch, resuming
    from a mid-run warm state either way."""
    cfg, _, sys = _system(n_ch, seed)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    t = sys.laser.shape[0]
    # a mid-run state: a short bounded run that typically leaves work undone
    _, _, mid = run_protocol(tables, spec, with_stats=True, with_state=True,
                             n_rounds=1)
    mid = mid._replace(probes=jnp.zeros_like(mid.probes))
    _, full_stats, full_state = run_protocol(
        tables, spec, with_stats=True, with_state=True, init_state=mid,
        transactional=True, patience=3,
    )
    for ti in range(t):
        sub_tables = jax.tree.map(lambda a: a[ti:ti + 1], tables)
        sub_mid = jax.tree.map(lambda a: a[ti:ti + 1], mid)
        _, s, st = run_protocol(
            sub_tables, spec, with_stats=True, with_state=True,
            init_state=sub_mid, transactional=True, patience=3,
        )
        for got, want in (
            (s.probes, full_stats.probes[ti]),
            (s.worked, full_stats.worked[ti]),
            (s.locked, full_stats.locked[ti]),
        ):
            assert int(np.asarray(got)[0]) == int(np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(st.lock)[0], np.asarray(full_state.lock)[ti]
        )


def check_timeline_resume_equivalence(n_ch, seed, tr_mean, split=2):
    """A campaign checkpointed at ``split`` and resumed replays the tail
    bit-identically (stats and final state)."""
    cfg, units, _ = _system(n_ch, seed)
    tl = make_timeline(4, n_ch, thermal=0.3,
                       events=((2, "lane_kill", 1), (3, "lane_swap", 1)))
    var = {"tr_mean": tr_mean}
    final, stats = run_timeline(cfg, units, tl, var)
    t = final.lock.shape[0]
    head_state, head = run_timeline(cfg, units, slice_timeline(tl, 0, split), var)
    with tempfile.TemporaryDirectory() as d:
        save_campaign(d, split, head_state)
        step, resumed = restore_campaign(d, t, n_ch)
    assert step == split
    for a, b in zip(jax.tree.leaves(resumed), jax.tree.leaves(head_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tail_state, tail = run_timeline(cfg, units, slice_timeline(tl, split), var,
                                    init_state=resumed)
    for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(tail_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rejoined = jax.tree.map(
        lambda h, tt: np.concatenate([np.asarray(h), np.asarray(tt)]), head, tail
    )
    for a, b in zip(jax.tree.leaves(stats), jax.tree.leaves(rejoined)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- always-on sweeps --

@pytest.mark.parametrize("n_ch,seed,tr_mean", CASES)
def test_cold_state_equivalence(n_ch, seed, tr_mean):
    check_cold_state_equivalence(n_ch, seed, tr_mean)


@pytest.mark.parametrize("n_ch,seed,tr_mean", CASES)
def test_warm_fixed_point(n_ch, seed, tr_mean):
    check_warm_fixed_point(n_ch, seed, tr_mean)


@pytest.mark.parametrize("n_ch,seed,tr_mean", CASES[:2])
def test_batch_independent_resume(n_ch, seed, tr_mean):
    check_batch_independent_resume(n_ch, seed, tr_mean)


@pytest.mark.parametrize("n_ch,seed,tr_mean", CASES[:2])
def test_timeline_resume_equivalence(n_ch, seed, tr_mean):
    check_timeline_resume_equivalence(n_ch, seed, tr_mean)


def test_timeline_fixed_point_steps():
    """A drift-free timeline after a completed arbitration never probes,
    never churns, never breaks a lock."""
    n_ch = 8
    cfg, units, _ = _system(n_ch, 3)
    tl = make_timeline(3, n_ch)
    final, stats = run_timeline(cfg, units, tl, {"tr_mean": 6.0})
    locked0 = np.asarray(stats.locked)[0]
    # steps 1..: pure resume of whatever step 0 established
    assert np.all(np.asarray(stats.probes)[1:] == 0)
    assert np.all(np.asarray(stats.rounds)[1:] == 0)
    assert np.all(np.asarray(stats.churn)[1:] == 0)
    assert np.all(np.asarray(stats.broken)[1:] == 0)
    assert np.all(np.asarray(stats.locked)[1:] == locked0[None])


def test_lane_kill_rolls_back_not_thrash():
    """Killing a lane with all rings live is infeasible: transactional
    re-arbitration must roll back, leaving every unaffected lock exactly
    where it was and exactly one ring starved."""
    n_ch = 8
    cfg, sys = _dense_system(n_ch)
    t = sys.laser.shape[0]
    tables, spec = _tables_spec(cfg, sys, 50.0)
    _, _, state = run_protocol(tables, spec, with_stats=True, with_state=True)
    assert np.all(np.asarray(state.lock) >= 0)  # dense: always completes
    kill = 2
    vis = jnp.broadcast_to(
        (jnp.arange(n_ch) != kill)[None, None, :], (t, n_ch, n_ch)
    )
    tables_k = build_search_tables(sys, 50.0, visible=vis,
                                   max_alias=cfg.max_fsr_alias)
    reval, kept = revalidate_state(tables_k, state)
    broken = np.asarray((state.lock == kill).sum(axis=1))
    np.testing.assert_array_equal(broken, 1)  # dense perm: one holder each
    start = reval._replace(probes=jnp.zeros_like(reval.probes))
    _, stats, new = run_protocol(tables_k, spec, with_stats=True,
                                 with_state=True, init_state=start,
                                 transactional=True, patience=3)
    # infeasible (8 rings, 7 lines): committed state == revalidated start
    np.testing.assert_array_equal(np.asarray(new.lock), np.asarray(reval.lock))
    assert np.all(np.asarray(stats.locked) == n_ch - 1)


def test_lane_kill_feasible_relock_touches_only_broken_ring():
    """Lane l dies, ring j (holding line f) dies too: the ring that held l
    re-locks onto a free line; every other live lock is untouched."""
    n_ch = 8
    cfg, sys = _dense_system(n_ch)
    t = sys.laser.shape[0]
    tables, spec = _tables_spec(cfg, sys, 50.0)
    _, _, state = run_protocol(tables, spec, with_stats=True, with_state=True)
    lock = np.asarray(state.lock)
    kill_lane = int(lock[0, 0])       # the line ring 0 holds (same all trials)
    dead_ring = 3
    assert int(lock[0, dead_ring]) != kill_lane
    lane_alive = jnp.arange(n_ch) != kill_lane
    ring_alive = jnp.arange(n_ch) != dead_ring
    vis = jnp.broadcast_to(
        lane_alive[None, None, :] & ring_alive[None, :, None], (t, n_ch, n_ch)
    )
    tables_k = build_search_tables(sys, 50.0, visible=vis,
                                   max_alias=cfg.max_fsr_alias)
    reval, kept = revalidate_state(tables_k, state)
    start = reval._replace(probes=jnp.zeros_like(reval.probes))
    _, stats, new = run_protocol(tables_k, spec, with_stats=True,
                                 with_state=True, init_state=start,
                                 transactional=True, patience=3)
    new_lock = np.asarray(new.lock)
    live = np.ones(n_ch, bool)
    live[dead_ring] = False
    unaffected = live & (lock[0] != kill_lane)
    # dense reach: the seeker sees the freed line directly, no donor chains
    np.testing.assert_array_equal(new_lock[:, unaffected], lock[:, unaffected])
    relocked = live & (lock[0] == kill_lane)
    assert np.all(new_lock[:, relocked] == lock[0, dead_ring])
    assert np.all(np.asarray(stats.probes) > 0)


def test_hysteresis_breaks_marginal_locks():
    """revalidate_state with a margin clears locks whose residual sits
    within ``hysteresis`` of the tuning-range edge, and only those."""
    n_ch = 8
    cfg, sys = _dense_system(n_ch, t=2)
    tr = 2.0  # lines at 0.8 k, rings at 0: line k costs 0.8 k
    tables, spec = _tables_spec(cfg, sys, tr)
    _, _, state = run_protocol(tables, spec, with_stats=True, with_state=True)
    reval0, kept0 = revalidate_state(tables, state, tr=tr * sys.tr_unit,
                                     hysteresis=0.0)
    np.testing.assert_array_equal(np.asarray(kept0),
                                  np.asarray(state.lock >= 0))
    reval, kept = revalidate_state(tables, state, tr=tr * sys.tr_unit,
                                   hysteresis=0.5)
    delta = np.take_along_axis(
        np.asarray(tables.delta), np.maximum(np.asarray(state.entry), 0)[..., None], -1
    )[..., 0]
    held = np.asarray(state.lock) >= 0
    expect = held & (delta >= 0.5) & (delta <= tr - 0.5)
    np.testing.assert_array_equal(np.asarray(kept), expect)
    assert np.any(held & ~expect)  # the margin actually bit something
    np.testing.assert_array_equal(np.asarray(reval.lock < 0), ~expect)


def test_drift_scenarios_resolve():
    """Every registered drift scenario builds a timeline matching its cfg."""
    for name in DRIFT_SCENARIOS:
        cfg, tl = drift_timeline(name)
        assert tl.n_ch == len(cfg.s)
        assert tl.n_steps >= 2
        assert bool(jnp.all(tl.lane_alive[0]))  # step 0 pristine


def test_sweep_timeline_integration():
    """sweep(timeline=) returns trial-mean TemporalStats grids with a
    trailing step axis; the reference loop declines timeline requests."""
    n_ch = 8
    cfg, units, _ = _system(n_ch, 2)
    tl = make_timeline(3, n_ch, thermal=0.2)
    req = SweepRequest(cfg=cfg, units=units, scheme="protocol_lta",
                       axes={"sigma_rlv": np.array([0.2, 0.4])},
                       fixed={"tr_mean": 5.0}, timeline=tl)
    res = sweep(req)
    assert res.data.probes.shape == (2, 3)
    assert res.data.locked.shape == (2, 3)
    with pytest.raises(NotImplementedError):
        sweep_reference(req)
    with pytest.raises(ValueError):
        SweepRequest(cfg=cfg, units=units, scheme="vtrs_ssm",
                     axes={"sigma_rlv": np.array([0.2])}, timeline=tl)
    with pytest.raises(ValueError):
        SweepRequest(cfg=cfg, units=units, scheme="protocol_lta",
                     metric="min_tr", axes={"sigma_rlv": np.array([0.2])},
                     timeline=tl)


# ------------------------------------------------------ hypothesis layer --

if HAVE_HYPOTHESIS:

    @given(n_ch=st.sampled_from([4, 8]), seed=st.integers(0, 31),
           tr_mean=st.floats(2.0, 8.0))
    @settings(**SETTINGS)
    def test_hypo_warm_fixed_point(n_ch, seed, tr_mean):
        check_warm_fixed_point(n_ch, seed, tr_mean)

    @given(n_ch=st.sampled_from([4, 8]), seed=st.integers(0, 31),
           tr_mean=st.floats(2.0, 8.0))
    @settings(**SETTINGS)
    def test_hypo_cold_state_equivalence(n_ch, seed, tr_mean):
        check_cold_state_equivalence(n_ch, seed, tr_mean)

    @given(n_ch=st.sampled_from([4, 8]), seed=st.integers(0, 15),
           tr_mean=st.floats(2.0, 7.0))
    @settings(**SETTINGS)
    def test_hypo_batch_independent_resume(n_ch, seed, tr_mean):
        check_batch_independent_resume(n_ch, seed, tr_mean)

    @given(seed=st.integers(0, 15), tr_mean=st.floats(3.0, 7.0),
           split=st.integers(1, 3))
    @settings(**SETTINGS)
    def test_hypo_timeline_resume_equivalence(seed, tr_mean, split):
        check_timeline_resume_equivalence(4, seed, tr_mean, split=split)
