"""Distributed-semantics tests that need multiple devices: run a child
process with --xla_force_host_platform_device_count to compare the gather
and all-to-all MoE implementations under a real (data, model) mesh."""
import subprocess
import sys

import pytest

# Spawns a child JAX process with 8 forced host devices: minutes of compile
# on a loaded CPU and timing-sensitive; excluded from the tier-1 default.
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.distributed import sharding
from repro.distributed.ctx import activation_axes
from repro.launch.mesh import make_host_mesh
from repro.models import model as M

cfg = get_smoke("qwen3-moe-235b-a22b")
cfg = dataclasses.replace(cfg, n_layers=2, n_experts=4, top_k=2,
                          capacity_factor=8.0)  # high cap: no drops => equal
mesh = make_host_mesh(model_parallel=4)  # (data=2, model=4); E=4 divides
B, L = 4, 16
tokens = jax.random.randint(jax.random.key(0), (B, L), 0, cfg.vocab)
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

outs = {}
for impl in ("gather", "a2a"):
    c = dataclasses.replace(cfg, moe_impl=impl)
    params = M.init_params(jax.random.key(1), c)
    with mesh, activation_axes(mesh, dp=("data",)):
        p_sh = sharding.param_shardings(c, mesh)
        params_s = jax.device_put(params, p_sh)
        loss, aux = jax.jit(lambda p, b: M.loss_fn(p, c, b))(params_s, batch)
        outs[impl] = float(loss)
print("gather", outs["gather"], "a2a", outs["a2a"])
assert np.isfinite(outs["gather"]) and np.isfinite(outs["a2a"])
np.testing.assert_allclose(outs["gather"], outs["a2a"], rtol=2e-2, atol=2e-2)
print("MOE_IMPL_PARITY_OK")
"""


def test_moe_a2a_matches_gather():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "MOE_IMPL_PARITY_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
