"""Attention/serving variant tests: causal-pair flash vs dense vs naive,
decode against prefill caches, chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers


def _qkv(B=2, L=64, H=4, KVH=2, hd=16, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, L, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KVH, hd), jnp.float32)
    return q, k, v


def _naive_causal(q, k, v):
    B, L, H, hd = q.shape
    KVH = k.shape[2]
    g = H // KVH
    qh = q.reshape(B, L, KVH, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((L, L), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, L, H, hd)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_flash_dense_vs_naive(chunk):
    q, k, v = _qkv()
    out = layers.flash_attention(q, k, v, causal=True, q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive_causal(q, k, v)), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("seed", [0, 3])
def test_causal_pairs_vs_dense(chunk, seed):
    """§Perf causal tile skipping is numerically identical to the dense
    tile scan (same online softmax, half the tiles)."""
    q, k, v = _qkv(seed=seed)
    a = layers.flash_attention(q, k, v, causal=True, q_chunk=chunk,
                               kv_chunk=chunk)
    b = layers.flash_attention(q, k, v, causal=True, q_chunk=chunk,
                               kv_chunk=chunk, causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_causal_pairs_grad():
    q, k, v = _qkv(L=32)

    def loss(fn_kwargs):
        def f(q):
            o = layers.flash_attention(q, k, v, causal=True, q_chunk=8,
                                       kv_chunk=8, **fn_kwargs)
            return jnp.sum(o ** 2)
        return jax.grad(f)(q)

    g_dense = loss({})
    g_pairs = loss({"causal_skip": True})
    np.testing.assert_allclose(
        np.asarray(g_dense), np.asarray(g_pairs), rtol=5e-4, atol=5e-4
    )


def test_decode_matches_prefill_logits():
    """decode_attention over a padded cache == last-row flash attention."""
    q, k, v = _qkv(L=33)
    full = _naive_causal(q, k, v)
    pad = 7
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = layers.decode_attention(q[:, -1:], kc, vc, kv_len=33)
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4
    )
