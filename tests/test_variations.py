"""Declarative evaluation API tests: the ``Variations`` pytree + axis
registry, the ``SweepRequest`` frontend, the parametrized scheme registry,
and the deprecated-kwarg shims (which must stay bit-identical to the pytree
path)."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.wdm import WDM8_G200, WDM32_G200, WDM32_G400, WDM_CONFIGS
from repro.core import (
    ArbitrationConfig,
    DWDMGrid,
    SCHEME_POLICY,
    SCHEMES,
    SweepRequest,
    Variations,
    axis_names,
    axis_spec,
    evaluate_policy,
    evaluate_scheme,
    instantiate,
    make_seq_retry,
    make_units,
    policy_min_tr,
    register_axis,
    register_scheme,
    register_scheme_family,
    registered_schemes,
    scheme_spec,
    sweep,
    sweep_min_tr,
    sweep_policy,
    sweep_reference,
    sweep_scheme,
)
from repro.core import variations as variations_mod
from repro.core.api import evaluate_scheme_impl
from repro.core.sweep import (
    _CHUNK_BUDGET,
    _auto_chunk,
    policy_point_bytes,
    scheme_point_bytes,
)
from repro.core.search_table import max_entries_for

RLVS = np.array([0.28, 2.24], np.float32)
TRS = np.array([2.0, 5.0, 9.5], np.float32)


def _units(cfg, seed=4, n=5):
    return make_units(cfg, seed=seed, n_laser=n, n_ring=n)


# ------------------------------------------------------- Variations pytree ---

def test_variations_construction_and_accessors():
    v = Variations(sigma_rlv=2.0, tr_mean=5.0, sigma_go=None)
    assert v.names == ("sigma_rlv", "tr_mean")  # None dropped, keys sorted
    assert "sigma_rlv" in v and "sigma_go" not in v
    assert v.get("sigma_rlv") == 2.0
    assert v.get("sigma_go") is None
    assert len(Variations()) == 0
    # resolve: override wins, else registry default under the config
    cfg = WDM8_G200
    assert v.resolve("sigma_rlv", cfg) == 2.0
    assert v.resolve("sigma_go", cfg) == cfg.var.sigma_go
    assert Variations().resolve("tr_mean", cfg) == cfg.grid.tr_mean
    assert Variations().resolve("fsr_mean", cfg) == cfg.grid.fsr


def test_variations_replace_and_merge():
    v = Variations(sigma_rlv=2.0)
    assert v.replace(tr_mean=5.0).names == ("sigma_rlv", "tr_mean")
    assert v.replace(sigma_rlv=None).names == ()
    assert v.replace(sigma_rlv=3.0).get("sigma_rlv") == 3.0
    assert v.get("sigma_rlv") == 2.0  # original untouched
    merged = v.merge({"sigma_go": 1.0})
    assert merged.names == ("sigma_go", "sigma_rlv")
    with pytest.raises(ValueError, match="specified twice"):
        v.merge({"sigma_rlv": 9.0})
    with pytest.raises(AttributeError, match="immutable"):
        v.sigma_rlv = 1.0


def test_variations_unknown_axis_and_validation():
    with pytest.raises(ValueError, match="unknown variation axis"):
        Variations(bogus=1.0)
    with pytest.raises(ValueError, match="unknown variation axis"):
        Variations().get("bogus")
    with pytest.raises(ValueError, match="must be >= 0"):
        Variations(sigma_rlv=-1.0)
    with pytest.raises(ValueError, match="monotone"):
        Variations(sigma_llv_frac=0.7)


def test_variations_is_a_pytree_and_jit_static_by_key_set():
    v = Variations(sigma_rlv=2.0, tr_mean=5.0)
    leaves, treedef = jax.tree_util.tree_flatten(v)
    assert leaves == [2.0, 5.0]
    v2 = jax.tree_util.tree_unflatten(treedef, [3.0, 6.0])
    assert v2.names == v.names and v2.get("sigma_rlv") == 3.0

    calls = []

    @jax.jit
    def f(var):
        calls.append(1)
        return var.get("sigma_rlv") * 2.0

    assert float(f(Variations(sigma_rlv=1.0))) == 2.0
    assert float(f(Variations(sigma_rlv=4.0))) == 8.0
    assert len(calls) == 1  # same key set -> same treedef -> no retrace


def test_axis_registry_introspection():
    names = axis_names()
    # the original seven engine axes, in their historical order, plus the
    # registry-added thermal_drift extension
    assert names[:7] == ("tr_mean", "sigma_rlv", "sigma_go",
                         "sigma_llv_frac", "sigma_fsr_frac", "sigma_tr_frac",
                         "fsr_mean")
    assert "thermal_drift" in names
    assert axis_spec("sigma_rlv").doc
    with pytest.raises(ValueError, match="already registered"):
        register_axis("sigma_rlv", lambda cfg: 0.0)


# ------------------------------------------------- deprecated kwarg shims ---

def test_instantiate_legacy_kwargs_warn_and_match_pytree():
    cfg = WDM8_G200
    units = _units(cfg)
    with pytest.warns(DeprecationWarning, match="Variations"):
        legacy = instantiate(cfg, units, sigma_rlv=2.0, sigma_go=1.0)
    new = instantiate(cfg, units, Variations(sigma_rlv=2.0, sigma_go=1.0))
    for a, b in zip(legacy, new):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="specified twice"):
        with pytest.warns(DeprecationWarning):
            instantiate(cfg, units, Variations(sigma_rlv=2.0), sigma_rlv=3.0)


def test_evaluator_legacy_kwargs_bit_identical():
    cfg = WDM8_G200
    units = _units(cfg)
    with pytest.warns(DeprecationWarning, match="Variations"):
        legacy = evaluate_scheme_impl(cfg, units, "seq", 5.0, sigma_rlv=2.0)
    new = evaluate_scheme_impl(cfg, units, "seq",
                               variations=Variations(tr_mean=5.0, sigma_rlv=2.0))
    for field in legacy._fields:
        assert np.array_equal(
            np.asarray(getattr(legacy, field)), np.asarray(getattr(new, field))
        ), field
    # jitted frontends: legacy kwargs == pytree, bit for bit (same traced
    # graph, only the input treedef differs)
    j_legacy = evaluate_scheme(cfg, units, "seq", 5.0, sigma_rlv=2.0)
    j_new = evaluate_scheme(cfg, units, "seq",
                            variations=Variations(tr_mean=5.0, sigma_rlv=2.0))
    assert np.array_equal(np.asarray(j_legacy.cafp), np.asarray(j_new.cafp))
    m_legacy = policy_min_tr(cfg, units, "ltc", sigma_rlv=2.0, fsr_mean=8.0)
    m_new = policy_min_tr(cfg, units, "ltc",
                          Variations(sigma_rlv=2.0, fsr_mean=8.0))
    assert float(m_legacy) == float(m_new)


def test_evaluator_tr_mean_conflicts_rejected():
    cfg = WDM8_G200
    units = _units(cfg, n=2)
    with pytest.raises(ValueError, match="both positionally"):
        evaluate_scheme(cfg, units, "seq", 5.0,
                        variations=Variations(tr_mean=6.0))
    with pytest.raises(ValueError, match="solves for the tuning range"):
        policy_min_tr(cfg, units, "ltc", Variations(tr_mean=5.0))


# ------------------------------------------------------ SweepRequest path ---

def test_sweep_request_matches_legacy_wrappers_and_reference():
    """Golden parity: the declarative path == the bare-grid wrappers == the
    per-point reference loop, for each figure family's request shape."""
    cfg = WDM8_G200
    units = _units(cfg)
    axes = {"sigma_rlv": RLVS, "tr_mean": TRS}

    # fig4 family: policy shmoo
    req = SweepRequest(cfg=cfg, units=units, policy="lta", axes=axes)
    res = sweep(req)
    assert np.array_equal(np.asarray(res.data),
                          np.asarray(sweep_policy(cfg, units, "lta", axes)))
    assert np.array_equal(np.asarray(res.data),
                          np.asarray(sweep_reference(req).data))

    # fig5/7/8 family: min-TR along a named axis
    mt_axes = {"fsr_mean": np.array([6.72, 8.96], np.float32)}
    req = SweepRequest(cfg=cfg, units=units, policy="ltc", metric="min_tr",
                       axes=mt_axes)
    res = sweep(req)
    assert np.array_equal(np.asarray(res.data),
                          np.asarray(sweep_min_tr(cfg, units, "ltc", mt_axes)))
    assert np.array_equal(np.asarray(res.data),
                          np.asarray(sweep_reference(req).data))

    # fig15/16 family: scheme sweep with fixed overrides, Variations-typed
    fixed = Variations(sigma_fsr_frac=0.05, sigma_tr_frac=0.20)
    req = SweepRequest(cfg=cfg, units=units, scheme="rs_ssm",
                       axes={"tr_mean": TRS}, fixed=fixed)
    res = sweep(req)
    legacy = sweep_scheme(cfg, units, "rs_ssm", {"tr_mean": TRS},
                          fixed={"sigma_fsr_frac": 0.05, "sigma_tr_frac": 0.20})
    ref = sweep_reference(req).data
    for field in res.data._fields:
        a = np.asarray(getattr(res.data, field))
        assert np.array_equal(a, np.asarray(getattr(legacy, field))), field
        assert np.array_equal(a, np.asarray(getattr(ref, field))), field


def test_sweep_result_carries_axis_metadata():
    cfg = WDM8_G200
    units = _units(cfg)
    req = SweepRequest(cfg=cfg, units=units, policy="ltd",
                       axes={"sigma_rlv": RLVS, "tr_mean": TRS})
    res = sweep(req)
    assert res.axis_names == ("sigma_rlv", "tr_mean")
    assert np.asarray(res.data).shape == (len(RLVS), len(TRS))
    assert np.array_equal(res.axis("sigma_rlv"), RLVS)
    assert np.array_equal(res.axis("tr_mean"), TRS)
    assert res.coords[1].dtype == np.float32
    with pytest.raises(ValueError, match="no axis"):
        res.axis("fsr_mean")


def test_sweep_request_error_paths():
    cfg = WDM8_G200
    units = _units(cfg, n=2)
    with pytest.raises(ValueError, match="unknown sweep axis"):
        SweepRequest(cfg=cfg, units=units, policy="ltc", axes={"bogus": RLVS})
    with pytest.raises(ValueError, match="unknown sweep axis"):
        SweepRequest(cfg=cfg, units=units, policy="ltc",
                     axes={"tr_mean": TRS}, fixed={"bogus": 1.0})
    with pytest.raises(ValueError, match="exactly one"):
        SweepRequest(cfg=cfg, units=units, axes={"tr_mean": TRS})
    with pytest.raises(ValueError, match="at least one sweep axis"):
        SweepRequest(cfg=cfg, units=units, policy="ltc", axes={})
    with pytest.raises(ValueError, match="must be >= 0"):
        SweepRequest(cfg=cfg, units=units, policy="ltc",
                     axes={"sigma_rlv": np.array([-1.0])})
    # request validation == engine validation == reference validation (the
    # wrappers construct the same SweepRequest)
    with pytest.raises(ValueError, match="cannot be an axis"):
        sweep_min_tr(cfg, units, "ltc", {"tr_mean": TRS})


# --------------------------------------------------- axis extensibility ---

def test_register_axis_is_immediately_sweepable():
    """The extension contract: one register_axis call makes a new variation
    source a valid Variations key, SweepRequest axis, and instantiate-time
    transform — no signature edits anywhere."""
    name = "tv_laser_heater"
    register_axis(
        name, lambda cfg: 0.0,
        doc="test axis: uniform laser red-shift [nm]",
        transform=lambda sys, value, cfg: sys._replace(laser=sys.laser + value),
    )
    try:
        cfg = WDM8_G200
        units = _units(cfg)
        # consumed by instantiate through the transform hook
        shifted = instantiate(cfg, units, Variations(**{name: 0.5}))
        base = instantiate(cfg, units)
        assert np.allclose(np.asarray(shifted.laser),
                           np.asarray(base.laser) + 0.5)
        assert np.array_equal(np.asarray(shifted.ring), np.asarray(base.ring))
        # immediately a valid sweep axis, bit-identical to the ref loop
        req = SweepRequest(cfg=cfg, units=units, policy="ltc",
                           axes={name: np.array([0.0, 0.5], np.float32),
                                 "tr_mean": TRS})
        got = np.asarray(sweep(req).data)
        assert np.array_equal(got, np.asarray(sweep_reference(req).data))
        # zero shift reproduces the baseline column exactly
        base_req = SweepRequest(cfg=cfg, units=units, policy="ltc",
                                axes={"tr_mean": TRS})
        assert np.array_equal(got[0], np.asarray(sweep(base_req).data))
    finally:
        variations_mod._AXIS_REGISTRY.pop(name, None)


def test_thermal_drift_axis():
    cfg = WDM8_G200
    units = _units(cfg)
    base = instantiate(cfg, units)
    drifted = instantiate(cfg, units, Variations(thermal_drift=0.3))
    assert np.allclose(np.asarray(drifted.ring), np.asarray(base.ring) + 0.3)
    # zero drift is bit-identical to not passing the axis at all
    zero = instantiate(cfg, units, Variations(thermal_drift=0.0))
    assert np.array_equal(np.asarray(zero.ring), np.asarray(base.ring))
    # sweepable like any paper axis
    res = sweep(SweepRequest(
        cfg=cfg, units=units, policy="ltd", metric="min_tr",
        axes={"thermal_drift": np.array([0.0, 0.5, 1.0], np.float32)},
    ))
    mt = np.asarray(res.data)
    assert mt.shape == (3,) and np.all(np.isfinite(mt))


# ------------------------------------------------- parametrized schemes ---

def test_seq_retry_family_registered_with_params():
    for name, budget in (("seq_retry_r1", 1), ("seq_retry_r2", 2),
                         ("seq_retry_r4", 4)):
        spec = scheme_spec(name)
        assert spec.policy == "lta"
        assert dict(spec.params)["n_rounds"] == budget
    assert dict(scheme_spec("seq_retry_phys").params)["constrained_first"] is False


def test_scheme_family_duplicate_registration_rejected():
    base = "tv_dup_family"
    register_scheme_family(
        base, make_seq_retry, {"a": {"n_rounds": 1}}, policy="lta"
    )
    with pytest.raises(ValueError, match="already registered"):
        register_scheme_family(
            base, make_seq_retry, {"a": {"n_rounds": 2}}, policy="lta"
        )


def test_parametrized_full_budget_matches_unbudgeted():
    """A family variant with budget == N_ch is the same arbiter as the
    unparametrized seq_retry (whose default budget is N_ch) — evaluated
    through the registry, bit for bit.  A 4-channel config keeps the
    unrolled-retry compilation cheap."""
    name = "tv_seq_retry_r4ch"
    if name not in registered_schemes():
        register_scheme(name, make_seq_retry(n_rounds=4), policy="lta",
                        params={"n_rounds": 4})
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=4))
    units = _units(cfg)
    a = evaluate_scheme(cfg, units, name, 3.0)
    b = evaluate_scheme(cfg, units, "seq_retry", 3.0)
    for field in a._fields:
        assert np.array_equal(np.asarray(getattr(a, field)),
                              np.asarray(getattr(b, field))), field


def test_retry_budget_monotone_through_engine():
    """More retry budget never hurts CAFP (the fig17 claim, at test scale —
    a 4-channel config so three registry variants compile quickly)."""
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=4))
    units = _units(cfg, seed=17, n=6)
    trs = {"tr_mean": np.array([2.0, 3.0, 4.4], np.float32)}
    means = []
    for scheme in ("seq_retry_r1", "seq_retry_r2", "seq_retry_r4"):
        res = sweep(SweepRequest(cfg=cfg, units=units, scheme=scheme, axes=trs))
        means.append(float(np.mean(np.asarray(res.data.cafp))))
    assert means[0] >= means[1] - 1e-6
    assert means[1] >= means[2] - 1e-6


# ------------------------------------------------------- live registry ---

def test_schemes_views_are_live():
    """Satellite fix: SCHEMES/SCHEME_POLICY used to be import-time
    snapshots; schemes registered afterwards must now be visible."""
    name = "tv_live_view_scheme"
    assert name not in SCHEMES
    before = len(SCHEMES)
    register_scheme(name, make_seq_retry(n_rounds=1), policy="lta")
    assert name in SCHEMES
    assert name in tuple(SCHEMES)
    assert len(SCHEMES) == before + 1
    assert SCHEME_POLICY[name] == "lta"
    assert dict(SCHEME_POLICY)[name] == "lta"
    assert tuple(SCHEMES) == registered_schemes()


# ------------------------------------------------------- wdm32 capacity ---

def test_wdm32_table_footprint_fits_engine_budget():
    """ROADMAP wdm32 audit: with the streaming top-E table build, *paper
    scale* (100x100 trials) WDM32 points fit the engine's per-chunk memory
    budget on BOTH paths — the policy/min-TR path that fig5 runs and the
    scheme/table path that fig18 runs (the latter was ~2.5 GB against the
    256 MB budget with the dense builder).  Bench-scale (24x24) scheme
    chunks must also grow well past one point per chunk."""
    full_trials, fast_trials = 100 * 100, 24 * 24
    for cfg in (WDM32_G200, WDM32_G400):
        assert max_entries_for(cfg.grid.n_ch) == 3 * 32
        assert policy_point_bytes(cfg, full_trials) <= _CHUNK_BUDGET
        assert scheme_point_bytes(cfg, full_trials) <= _CHUNK_BUDGET
        # >= 4x below the dense-build estimate at N=32, J=17 (ISSUE 4 bar)
        n, j = cfg.grid.n_ch, 2 * cfg.max_fsr_alias + 1
        dense = fast_trials * n * (n * j + max_entries_for(n)) * 4 * 3
        assert dense >= 4 * scheme_point_bytes(cfg, fast_trials)
        units = make_units(cfg, seed=0, n_laser=24, n_ring=24)
        assert _auto_chunk(cfg, units, 16, None) >= 1
        assert _auto_chunk(cfg, units, 16, "seq") >= 8  # was pinned at 1
    # and the fig5 min-TR benchmark actually covers the wdm32 configs
    import benchmarks.fig5_min_tuning_range as fig5

    assert {"wdm32-g200", "wdm32-g400"} <= set(WDM_CONFIGS)
    assert fig5.WDM_CONFIGS is WDM_CONFIGS
