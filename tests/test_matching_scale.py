"""N > _HALL_MAX_N matching fast path: the single-pass bottleneck sweep
(threshold and existence forms) must match the Kuhn/binary-search oracle
bit-for-bit — value-level pins at wdm16/wdm32, tie-heavy quantized weights,
and hypothesis properties over random reach masks."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.wdm import WDM16_G200, WDM32_G200
from repro.core import make_units
from repro.core import matching
from repro.core.reach import reach_matrix, scaled_residual
from repro.core.sampling import instantiate


def _weights(cfg, seed=7, n=5):
    sys = instantiate(cfg, make_units(cfg, seed, n, n))
    return sys, scaled_residual(sys)


def _kuhn_exists(reach):
    mw, _ = matching.max_matching(matching.adjacency_bitmask(reach))
    return np.asarray(jnp.all(mw >= 0, axis=1))


def test_sweep_bottleneck_bit_exact_vs_kuhn_wdm16():
    """Value-level pin: the sweep threshold IS the binary-search result."""
    sys, w = _weights(WDM16_G200)
    assert w.shape[-1] > matching._HALL_MAX_N  # exercises the sweep path
    got = np.asarray(matching.bottleneck_matching_threshold(w))
    oracle = np.asarray(matching._bottleneck_threshold_kuhn(w))
    assert np.array_equal(got, oracle)
    # Existence form == Kuhn at spot TRs, and consistent with the threshold.
    for tr in (3.0, 8.0, 14.0):
        reach = reach_matrix(sys, tr)
        ok = np.asarray(matching.has_perfect_matching(reach))
        kuhn_ok = _kuhn_exists(reach)
        assert np.array_equal(ok, kuhn_ok), tr
        assert np.array_equal(got <= tr, kuhn_ok), tr


@pytest.mark.slow
def test_sweep_bottleneck_bit_exact_vs_kuhn_wdm32():
    sys, w = _weights(WDM32_G200, n=4)
    got = np.asarray(matching.bottleneck_matching_threshold(w))
    assert np.array_equal(got, np.asarray(matching._bottleneck_threshold_kuhn(w)))
    reach = reach_matrix(sys, 20.0)
    assert np.array_equal(
        np.asarray(matching.has_perfect_matching(reach)), _kuhn_exists(reach)
    )


def _np_max_matching(adj_bool):
    """Textbook recursive Kuhn on one trial — the multiword oracle."""
    n = adj_bool.shape[0]
    mr = -np.ones(n, int)

    def try_ring(i, seen):
        for k in range(n):
            if adj_bool[i, k] and not seen[k]:
                seen[k] = True
                if mr[k] < 0 or try_ring(mr[k], seen):
                    mr[k] = i
                    return True
        return False

    return sum(try_ring(i, np.zeros(n, bool)) for i in range(n))


def test_multiword_bitmask_matching_wdm64():
    """N > 32 packs into (T, N, W) uint32 words; Kuhn on the multiword path
    must agree with a numpy reference on matched counts, produce a
    consistent matching, and agree with the existence fast path."""
    rng = np.random.default_rng(3)
    for n in (40, 64):
        for density in (0.04, 0.1, 0.5):
            reach = rng.random((6, n, n)) < density
            adj = matching.adjacency_bitmask(jnp.asarray(reach))
            assert adj.shape == (6, n, -(-n // 32))
            assert adj.dtype == jnp.uint32
            mw, mr = matching.max_matching(adj)
            mw, mr = np.asarray(mw), np.asarray(mr)
            counts = (mw >= 0).sum(axis=1)
            ref = [_np_max_matching(reach[t]) for t in range(6)]
            assert np.array_equal(counts, ref), (n, density)
            for t in range(6):
                for r in np.nonzero(mw[t] >= 0)[0]:
                    assert reach[t, r, mw[t, r]]      # matched along an edge
                    assert mr[t, mw[t, r]] == r       # two-sided consistency
            perfect = np.asarray(matching.has_perfect_matching(jnp.asarray(reach)))
            assert np.array_equal(counts == n, perfect), (n, density)


def test_single_word_bitmask_layout_unchanged():
    """N <= 32 keeps the original (T, N) int32 packing — the layout the
    Pallas matching kernel and its parity tests consume."""
    rng = np.random.default_rng(4)
    reach = jnp.asarray(rng.random((5, 16, 16)) < 0.4)
    adj = matching.adjacency_bitmask(reach)
    assert adj.shape == (5, 16) and adj.dtype == jnp.int32
    expect = np.asarray(reach) @ (1 << np.arange(16))
    assert np.array_equal(np.asarray(adj), expect)


def test_sweep_bottleneck_tie_heavy_weights():
    """Quantized weights force massive rank ties: any augmenting-path choice
    must still land on the same (unique) bottleneck value."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(0, 4, (24, 12, 12)).astype(np.float32))
    assert np.array_equal(
        np.asarray(matching._bottleneck_threshold_sweep(w)),
        np.asarray(matching._bottleneck_threshold_kuhn(w)),
    )


# ------------------------------------------------------ hypothesis props ---
# Guarded per-test (not module-level importorskip) so the value pins above
# always run even where hypothesis is absent.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - property tests become skips
    given = None

_N = 12  # > _HALL_MAX_N, small enough for the Kuhn oracle per example


def _existence_case(seed, density):
    rng = np.random.default_rng(seed)
    reach = jnp.asarray(rng.random((8, _N, _N)) < density)
    assert np.array_equal(
        np.asarray(matching.has_perfect_matching(reach)), _kuhn_exists(reach)
    )


def _bottleneck_case(seed, levels):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(0, levels, (6, _N, _N)).astype(np.float32))
    assert np.array_equal(
        np.asarray(matching._bottleneck_threshold_sweep(w)),
        np.asarray(matching._bottleneck_threshold_kuhn(w)),
    )


if given is not None:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.95))
    def test_existence_matches_kuhn_on_random_reach_masks(seed, density):
        _existence_case(seed, density)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 8))
    def test_bottleneck_matches_kuhn_on_random_weights(seed, levels):
        _bottleneck_case(seed, levels)

else:

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_existence_matches_kuhn_on_random_reach_masks(seed):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _existence_case(seed, density=0.1 + 0.2 * seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_bottleneck_matches_kuhn_on_random_weights(seed):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _bottleneck_case(seed, levels=2 + seed)
