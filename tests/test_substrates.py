"""Substrate tests: data pipeline, checkpoint store, optimizer, optics
fabric, and a small end-to-end fault-tolerant training run on host devices."""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_smoke
from repro.configs.wdm import WDM8_G200
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding, steps
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optics import bringup, expected_failure_rates, rearbitrate
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def test_pipeline_determinism_and_shapes():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = next(iter(p1)), next(iter(p2))
    p1.close()
    p2.close()
    assert b1["tokens"].shape == (4, 16)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128


def test_pipeline_host_sharding():
    full = DataConfig(vocab=64, seq_len=8, global_batch=4, seed=9)
    b_full = next(iter(TokenPipeline(full)))
    h0 = DataConfig(vocab=64, seq_len=8, global_batch=4, seed=9, n_hosts=2, host_id=0)
    b0 = next(iter(TokenPipeline(h0)))
    assert b0["tokens"].shape == (2, 8)


def test_checkpoint_roundtrip_and_gc():
    tree = {
        "w": jnp.arange(24.0).reshape(4, 6),
        "blocks": [{"a": jnp.ones((2, 3))}],
    }
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            store.save(d, s, tree, keep=2)
        assert store.latest_step(d) == 5
        kept = sorted(p.name for p in Path(d).iterdir())
        assert len(kept) == 2
        out = store.restore(d, 5, jax.eval_shape(lambda: tree))
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=0, decay_steps=100,
                            weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = adamw.init(cfg, params)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, state, stats = adamw.apply(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 1.0
    assert np.isfinite(float(stats["grad_norm"]))


def test_optics_bringup_and_rearbitration():
    fab = bringup(pods=2, links_per_pod_pair=8, cfg=WDM8_G200, tr_mean=5.0)
    assert len(fab.links) == 8
    assert 0.0 <= fab.bandwidth_fraction <= 1.0
    fab2, _ = rearbitrate(fab, WDM8_G200, seed=11)
    assert fab2.bandwidth_fraction >= fab.bandwidth_fraction
    rates = expected_failure_rates(WDM8_G200, 8.96, n=16)
    assert rates["cafp"] <= 0.05  # VT-RS/SSM ~ ideal at nominal TR


@pytest.mark.slow
def test_trainer_end_to_end_with_restart():
    """Two-phase run: train, 'crash', restore from checkpoint, continue —
    losses finite, checkpoint step honored, fabric arbitrated."""
    cfg = get_smoke("internlm2-1.8b")
    mesh = make_host_mesh()
    opt_cfg = adamw.AdamWConfig(warmup_steps=2, decay_steps=50)
    params_sh = sharding.param_shardings(cfg, mesh)
    opt_sh = sharding.opt_shardings(params_sh, sharding.replicated(mesh))
    step_fn = jax.jit(steps.make_train_step(cfg, opt_cfg, n_microbatch=2),
                      donate_argnums=(0, 1))

    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(total_steps=6, ckpt_every=3, ckpt_dir=d,
                             log_every=2, pods=2, links_per_pod_pair=4,
                             link_failure_prob_per_step=0.5, seed=0)
        tr = Trainer(cfg, tcfg, opt_cfg, mesh, step_fn, params_sh, opt_sh)
        fab = tr.bringup_fabric()
        assert fab is not None and len(fab.links) == 4

        data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=4, seed=1))
        state = tr.init_state()
        state = tr.fit(state, iter(data))
        assert state.step == 6
        losses = [m["loss"] for m in tr.metrics_log]
        assert all(np.isfinite(l) for l in losses)

        # "crash" and restart from latest checkpoint: resumes at step 6
        tr2 = Trainer(cfg, tcfg, opt_cfg, mesh, step_fn, params_sh, opt_sh)
        state2 = tr2.init_state()
        assert state2.step == 6
        data.close()


@pytest.mark.slow
def test_checkpoint_reshard_on_restore():
    """Elastic restart: a checkpoint written under one sharding restores
    onto a different mesh layout (pod-count change)."""
    cfg = get_smoke("internlm2-1.8b")
    mesh1 = make_host_mesh(model_parallel=1)
    params = M.init_params(jax.random.key(7), cfg)
    sh1 = sharding.param_shardings(cfg, mesh1)
    placed = jax.device_put(params, sh1)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 11, placed)
        # restore under a different (trivially different on 1 CPU, but the
        # code path exercises slice reassembly + re-placement) sharding
        mesh2 = make_host_mesh(model_parallel=1)
        sh2 = sharding.param_shardings(cfg, mesh2)
        out = store.restore(d, 11, M.param_shapes(cfg), sh2)
        a = np.asarray(jax.tree.leaves(placed)[0])
        b = np.asarray(jax.tree.leaves(out)[0])
        np.testing.assert_allclose(a, b)
