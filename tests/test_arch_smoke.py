"""Per-architecture smoke tests: reduced same-family configs run one
forward/backward and a prefill->decode step on CPU; output shapes and
finiteness asserted.  Full configs are exercised via the dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import model as M

# Tier-1 keeps two cheap representative architectures; the full matrix is
# minutes of CPU compile time and runs under ``pytest -m slow``.
_FAST_ARCHS = ("mamba2-130m", "internlm2-1.8b")
_ARCH_PARAMS = [
    a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS
]


def _batch(cfg, B=2, L=32):
    key = jax.random.key(0)
    tokens = jax.random.randint(key, (B, L), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend_len:
        batch["extra_embeds"] = (
            jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_forward_and_grad(arch):
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.key(1), cfg)
    batch = _batch(cfg)

    def loss(p):
        l, aux = M.loss_fn(p, cfg, batch)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val)), arch
    # a sane LM at init: loss ~= ln(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(val) < 3.0 * np.log(cfg.vocab) + 1.0
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), arch


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_prefill_decode(arch):
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.key(2), cfg)
    B, L, max_len = 2, 16, 24
    tokens = jax.random.randint(jax.random.key(3), (B, L), 0, cfg.vocab)
    extra = None
    if cfg.frontend_len:
        extra = jax.random.normal(jax.random.key(4), (B, cfg.frontend_len, cfg.d_model)) * 0.02
    logits, state = M.prefill(params, cfg, tokens, max_len, extra_embeds=extra)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    nxt = jnp.argmax(logits, axis=-1)[:, None]
    for _ in range(3):
        logits, state = M.decode_step(params, cfg, state, nxt)
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))
        nxt = jnp.argmax(logits, axis=-1)[:, None]


@pytest.mark.slow
def test_sqrt_remat_parity():
    """scan_levels=2 (sqrt-remat) computes identical loss and gradients."""
    import dataclasses

    cfg1 = dataclasses.replace(get_smoke("internlm2-1.8b"), n_layers=6)
    cfg2 = dataclasses.replace(cfg1, scan_levels=2)
    params = M.init_params(jax.random.key(0), cfg1)
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg1.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    l1, g1 = jax.value_and_grad(lambda p: M.loss_fn(p, cfg1, batch)[0])(params)
    l2, g2 = jax.value_and_grad(lambda p: M.loss_fn(p, cfg2, batch)[0])(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5
        )


@pytest.mark.parametrize("arch", ["mamba2-130m", pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow), "internlm2-1.8b"])
def test_prefill_decode_consistency(arch):
    """Decoding token t+1 after prefill(0..t) must match prefill(0..t+1)'s
    next-token distribution (cache correctness across mixer families)."""
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.key(5), cfg)
    B, L = 1, 16
    tokens = jax.random.randint(jax.random.key(6), (B, L + 1), 0, cfg.vocab)
    extra = None
    if cfg.frontend_len:
        extra = jax.random.normal(jax.random.key(7), (B, cfg.frontend_len, cfg.d_model)) * 0.02

    logits_a, state = M.prefill(params, cfg, tokens[:, :L], L + 8, extra_embeds=extra)
    logits_b, _ = M.decode_step(params, cfg, state, tokens[:, L:L + 1])
    logits_full, _ = M.prefill(params, cfg, tokens, L + 9, extra_embeds=extra)
    np.testing.assert_allclose(
        np.asarray(logits_b), np.asarray(logits_full), rtol=2e-2, atol=2e-2
    )
