"""Fabric chaos invariants: no-fault parity, fault semantics, warm wins.

The load-bearing guarantee is *no-fault parity*: a zero-drift, zero-event
``FabricTimeline`` must reproduce a single-shot ``fabric.bringup`` bit for
bit at step 0 (the all-True visibility mask is ``ok & True`` in the table
builder), keep every lock a zero-cost warm fixed point on later steps, and
report identical ``FabricStats`` — the chaos layer adds faults, never a
different no-fault semantics.  On top of that: killed links are never
re-locked while dead, heal-after-kill recovers pre-fault bandwidth, comb
failure takes a whole comb group down together, warm re-lock beats cold on
probes without locking less, and the link axis is chunk/mesh invariant.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.fabric import CHAOS_SCENARIOS, FABRIC_TINY, chaos_timeline
from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, sweep
from repro.fabric import (
    bringup,
    make_fabric_timeline,
    make_fabric_units,
    run_fabric_timeline,
)
from repro.launch.mesh import make_sweep_mesh

CFG = WDM8_G200
SPEC = FABRIC_TINY
N = CFG.grid.n_ch


def _run(tl, *, warm=True, seed=0, **kw):
    units = make_fabric_units(CFG, SPEC, seed)
    return run_fabric_timeline(CFG, units, SPEC, tl, scheme="vtrs_ssm",
                               warm=warm, **kw)


def test_no_fault_parity_bit_identical():
    tl = make_fabric_timeline(SPEC, 3, N)
    assert not np.asarray(tl.disturbed).any()
    st, cs = _run(tl)
    ref = bringup(CFG, SPEC, scheme="vtrs_ssm", seed=0)
    # step 0 records are the single-shot bring-up, bit for bit
    np.testing.assert_array_equal(np.asarray(cs.wl[0]), np.asarray(ref.ev.wl))
    for field in cs.fabric._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(cs.fabric, field)[0]),
            np.asarray(getattr(ref.stats, field)), err_msg=field)
    # every later step is a zero-cost warm fixed point: no spend, no churn,
    # same locks, same stats
    assert np.asarray(cs.probes[1:]).sum() == 0
    assert np.asarray(cs.broken[1:]).sum() == 0
    assert np.asarray(cs.churn[1:]).sum() == 0
    for s in range(1, 3):
        np.testing.assert_array_equal(np.asarray(cs.wl[s]),
                                      np.asarray(cs.wl[0]))
        for field in cs.fabric._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(cs.fabric, field)[s]),
                np.asarray(getattr(cs.fabric, field)[0]), err_msg=field)
    # the final state is the bring-up state in the (2K, N) handle layout
    np.testing.assert_array_equal(np.asarray(st.lock),
                                  np.asarray(ref.state.lock))


def test_link_kill_isolation_and_heal_recovery():
    tl = make_fabric_timeline(
        SPEC, 5, N, events=((1, "link_kill", 2), (3, "link_heal", 2)))
    _, cs = _run(tl)
    wl = np.asarray(cs.wl)
    # dead steps: the killed link's bus is empty — all locks break, nothing
    # re-locks, no probes are wasted seeking an empty table
    for s in (1, 2):
        assert (wl[s, 2] < 0).all()
        assert not np.asarray(cs.feasible[s, 2])
    assert np.asarray(cs.probes[2, 2]) == 0  # already dead, nothing to do
    # survivors never notice: locks and stats identical to step 0
    other = [k for k in range(SPEC.n_links) if k != 2]
    for s in (1, 2):
        np.testing.assert_array_equal(wl[s, other], wl[0, other])
        assert np.asarray(cs.probes[s, other]).sum() == 0
    # heal: the link re-locks and fabric bandwidth recovers to pre-fault
    assert np.asarray(cs.locked[3, 2]) == 2 * N
    bw = np.asarray(cs.fabric.bandwidth)
    assert bw[1] < bw[0]
    np.testing.assert_allclose(bw[3:], bw[0], rtol=1e-6)


def test_comb_kill_takes_group_down_together():
    # FABRIC_TINY groups by bundle: comb group 0 = both links of pair (0,1)
    tl = make_fabric_timeline(SPEC, 3, N, events=((1, "comb_kill", 0),))
    _, cs = _run(tl)
    group = SPEC.link_group()
    wl = np.asarray(cs.wl)
    dead = np.flatnonzero(group == 0)
    assert len(dead) == SPEC.links_per_pair
    assert (wl[1:, dead] < 0).all()       # every link on the comb, together
    alive = np.flatnonzero(group != 0)
    np.testing.assert_array_equal(wl[1][alive], wl[0][alive])
    # ideal-blind afp is untouched by liveness; feasibility is not
    assert not np.asarray(cs.feasible)[1:, dead].any()
    np.testing.assert_array_equal(np.asarray(cs.fabric.afp[1]),
                                  np.asarray(cs.fabric.afp[0]))


def test_ring_kill_degrades_without_relock_storm():
    tl = make_fabric_timeline(SPEC, 3, N, events=((1, "ring_kill", 0, 1, 4),))
    _, cs = _run(tl)
    wl = np.asarray(cs.wl)
    # only the dead ring's lock breaks; the other 2N-1 rings keep theirs
    assert wl[1, 0, 1, 4] < 0
    keep = wl[0].copy(); keep[0, 1, 4] = -1
    np.testing.assert_array_equal(wl[1], keep)
    assert np.asarray(cs.locked[1, 0]) == 2 * N - 1
    # a dead ring does not make the link infeasible (matching exempts it)
    assert np.asarray(cs.feasible[1, 0])
    # undisturbed links spend nothing
    assert np.asarray(cs.probes[1, 1:]).sum() == 0


def test_disturbed_gating_scopes_spend_to_hot_pods():
    # pod 2 ramps; only links touching pod 2 may spend probes
    sp = CFG.grid.grid_spacing
    tl = make_fabric_timeline(SPEC, 4, N, pod_thermal={2: 0.5 * sp})
    _, cs = _run(tl)
    src, dst = SPEC.link_pods()
    cold_pod = np.flatnonzero((src != 2) & (dst != 2))
    hot = np.flatnonzero((src == 2) | (dst == 2))
    assert np.asarray(cs.probes)[1:, cold_pod].sum() == 0
    assert np.asarray(tl.disturbed)[1:, hot].all()
    # hot links keep full lock counts through the ramp (warm re-lock)
    assert (np.asarray(cs.locked)[1:, hot] == 2 * N).all()


def test_warm_beats_cold_on_chaos_scenario():
    cfg, spec, tl = chaos_timeline("tiny-flap")
    assert (cfg, spec) == (CFG, SPEC)
    units = make_fabric_units(cfg, spec, 0)
    _, w = run_fabric_timeline(cfg, units, spec, tl, scheme="vtrs_ssm",
                               warm=True)
    _, c = run_fabric_timeline(cfg, units, spec, tl, scheme="vtrs_ssm",
                               warm=False)
    feas = np.asarray(w.feasible[1:])
    wp = np.asarray(w.probes[1:], np.float64)
    cp = np.asarray(c.probes[1:], np.float64)
    assert (wp * feas).sum() < (cp * feas).sum()
    assert np.asarray(w.locked[-1]).sum() >= np.asarray(c.locked[-1]).sum()


def test_link_chunk_and_mesh_invariance():
    cfg, spec, tl = chaos_timeline("tiny-flap")
    units = make_fabric_units(cfg, spec, 0)
    ref = run_fabric_timeline(cfg, units, spec, tl, scheme="vtrs_ssm")
    for kw in ({"link_chunk": 1}, {"mesh": make_sweep_mesh()}):
        alt = run_fabric_timeline(cfg, units, spec, tl, scheme="vtrs_ssm",
                                  **kw)
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(alt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chaos_sweep_grid_shapes_and_scenarios_resolve():
    tl = make_fabric_timeline(SPEC, 2, N, events=((1, "link_kill", 0),))
    units = make_fabric_units(CFG, SPEC, 0)
    req = SweepRequest(cfg=CFG, units=units, scheme="vtrs_ssm", fabric=SPEC,
                       timeline=tl, axes={"tr_mean": [4.0, 5.0]})
    res = sweep(req)
    assert res.data.wl is None  # per-step lock maps do not aggregate
    assert np.asarray(res.data.fabric.bandwidth).shape == (2, 2)
    assert np.asarray(res.data.probes).shape == (2, 2)
    assert np.asarray(res.data.feasible).dtype == np.float32  # link means
    # every registered scenario resolves to a consistent (cfg, spec, tl)
    for name in CHAOS_SCENARIOS:
        cfg, spec, stl = chaos_timeline(name)
        assert stl.n_links == spec.n_links
        assert stl.n_ch == cfg.grid.n_ch


def test_timeline_builder_validation():
    with pytest.raises(ValueError, match=">= 1 step"):
        make_fabric_timeline(SPEC, 0, N)
    with pytest.raises(ValueError, match="argument"):
        make_fabric_timeline(SPEC, 2, N, events=((0, "link_kill", 0, 1),))
    with pytest.raises(ValueError, match="down_steps"):
        make_fabric_timeline(SPEC, 2, N, events=((0, "link_flap", 0, 0),))
    with pytest.raises(ValueError, match="outside"):
        make_fabric_timeline(SPEC, 2, N, events=((5, "link_kill", 0),))
    with pytest.raises(ValueError, match="comb group"):
        make_fabric_timeline(SPEC, 2, N, events=((0, "comb_kill", 99),))
    with pytest.raises(ValueError, match="pod_thermal"):
        make_fabric_timeline(SPEC, 2, N, pod_thermal={7: 1.0})
    # a timeline built for one fabric cannot drive another
    other_units = make_fabric_units(CFG, SPEC, 0)
    tl = make_fabric_timeline(SPEC, 2, N + 2)
    with pytest.raises(ValueError, match="channels|needs"):
        run_fabric_timeline(CFG, other_units, SPEC, tl)
