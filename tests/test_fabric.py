"""Fabric-layer invariants: parity, coupling, routes, sharding, warm repair.

The load-bearing guarantee is *constraints-off parity*: with
``comb_coupling = 0`` (or per-link combs) a fabric bring-up must be
bit-identical to independent per-link arbitration through the core path —
``repro.fabric`` adds a network layer, never a different per-link
semantics.  The oracle is a jitted vmap of ``core.sampling.instantiate``
(L=1 laser, R=2 rings per link) feeding one flat ``oblivious_arbitrate``.

As in tests/test_protocol.py the structural invariants run twice:
deterministic parametrized cases (always on) and hypothesis variants when
importable.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on container
    HAVE_HYPOTHESIS = False

from repro.configs.fabric import FABRIC_TINY, ring_routes
from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, sweep
from repro.core.api import oblivious_arbitrate
from repro.core.sampling import SystemBatch, UnitSamples, instantiate
from repro.core.variations import as_variations, axis_names
from repro.fabric import (
    FabricSpec,
    auto_link_chunk,
    bringup,
    instantiate_link,
    make_fabric_units,
    state_from_assignment,
)
from repro.launch.mesh import make_sweep_mesh

SETTINGS = dict(max_examples=6, deadline=None)

CFG = WDM8_G200
TR = 5.0


def _reference_arbitrate(cfg, spec, units, tr, scheme):
    """Independent per-link oracle: vmapped core instantiate -> one flat
    oblivious_arbitrate.  Jitted so XLA fusion matches the fabric path."""
    var = as_variations({})
    k, n = spec.n_links, cfg.grid.n_ch
    su = UnitSamples(
        u_go=units.go[:, None, None], u_llv=units.llv[:, None, :],
        u_rlv=units.rlv, u_fsr=units.fsr, u_tr=units.tr,
    )

    @jax.jit
    def ref(su):
        sysb = jax.vmap(lambda u: instantiate(cfg, u, var))(su)
        flat = SystemBatch(*[a.reshape(2 * k, n) for a in sysb])
        return flat, oblivious_arbitrate(cfg, flat, tr, scheme)

    return ref(su)


def test_spec_validation_and_topology():
    spec = FabricSpec(pods=4, links_per_pair=3, comb_group="pod",
                      routes=((0, 1, 2), (3, 0)))
    assert spec.n_pairs == 6 and spec.n_links == 18
    assert spec.pairs[0] == (0, 1) and spec.pairs[-1] == (2, 3)
    np.testing.assert_array_equal(
        spec.link_pair(), np.repeat(np.arange(6), 3))
    src, dst = spec.link_pods()
    assert np.all(src < dst)
    # pod grouping keys on the lower-numbered pod
    np.testing.assert_array_equal(spec.link_group(), src)
    hops = spec.route_hops()
    assert hops.shape == (2, 2)
    assert hops[0, 0] == spec.pairs.index((0, 1))
    assert hops[1].tolist() == [spec.pairs.index((0, 3)), -1]

    with pytest.raises(ValueError, match="pods"):
        FabricSpec(pods=1)
    with pytest.raises(ValueError, match="comb_group"):
        FabricSpec(comb_group="rack")
    with pytest.raises(ValueError, match="repeats"):
        FabricSpec(pods=3, routes=((0, 0),))
    with pytest.raises(ValueError, match="outside"):
        FabricSpec(pods=3, routes=((0, 7),))
    with pytest.raises(ValueError, match="hops"):
        ring_routes(4, 4)


def test_fallback_validation_and_alternatives():
    spec = FabricSpec(pods=4, routes=((0, 1, 2), (2, 3)),
                      fallbacks=(((0, 3, 2),), ()))
    hops, valid = spec.route_alternatives()
    assert hops.shape == (2, 2, 2) and valid.shape == (2, 2)
    # alternative 0 is always the primary route
    np.testing.assert_array_equal(hops[:, 0], spec.route_hops())
    np.testing.assert_array_equal(valid, [[True, True], [True, False]])
    pi = spec.pairs.index
    assert hops[0, 1].tolist() == [pi((0, 3)), pi((2, 3))]

    with pytest.raises(ValueError, match="one tuple per route"):
        FabricSpec(pods=4, routes=((0, 1, 2), (2, 3)),
                   fallbacks=(((0, 3, 2),),))
    with pytest.raises(ValueError, match="endpoints"):
        FabricSpec(pods=4, routes=((0, 1, 2),), fallbacks=(((0, 3),),))
    with pytest.raises(ValueError, match="repeats"):
        FabricSpec(pods=4, routes=((0, 1, 2),), fallbacks=(((0, 0, 2),),))
    # no fallbacks: every route has exactly its primary
    hops0, valid0 = FABRIC_TINY.route_alternatives()
    assert hops0.shape[1] == 1 and valid0.all()


def test_auto_link_chunk_degenerate():
    with pytest.raises(ValueError, match="n_links"):
        auto_link_chunk(CFG, 0)
    # a single-link fabric always fits trivially
    assert auto_link_chunk(CFG, 1) == 1
    # a budget too small for even one link floors at one link per chunk
    # instead of tripping the bisection's "lo fits" invariant
    assert auto_link_chunk(CFG, 8, budget=1) == 1
    # plenty of budget: the whole fabric is one chunk
    assert auto_link_chunk(CFG, 8) == 8


@pytest.mark.parametrize("scheme", ["vtrs_ssm", "seq_retry"])
@pytest.mark.parametrize("comb_group", ["link", "bundle"])
def test_constraints_off_parity_bit_identical(scheme, comb_group):
    """Zero coupling == independent per-link arbitration, bit for bit."""
    spec = FabricSpec(pods=3, links_per_pair=4, comb_group=comb_group)
    res = bringup(CFG, spec, tr_mean=TR, scheme=scheme, seed=3)
    units = make_fabric_units(CFG, spec, seed=3)
    flat, asg = _reference_arbitrate(CFG, spec, units, TR, scheme)
    k, n = spec.n_links, CFG.grid.n_ch
    for a, b in zip(flat, res.system):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(asg.wl).reshape(k, 2, n), np.asarray(res.ev.wl))
    np.testing.assert_array_equal(
        np.asarray(asg.entry).reshape(k, 2, n), np.asarray(res.ev.entry))


def test_comb_group_correlation():
    """c=1: all links of a comb group see the SAME laser row (and both ends
    of any link always share theirs); c=0 keeps private draws distinct."""
    spec = FabricSpec(pods=2, links_per_pair=4, comb_group="bundle")
    units = make_fabric_units(CFG, spec, seed=9)

    def lasers(coupling):
        var = as_variations({"comb_coupling": coupling})
        sys = jax.vmap(lambda u: instantiate_link(CFG, spec, u, var))(units)
        return np.asarray(sys.laser)  # (K, 2, N)

    full = lasers(1.0)
    np.testing.assert_array_equal(full[:, 0], full[:, 1])  # shared comb
    for k in range(1, spec.n_links):
        np.testing.assert_array_equal(full[0, 0], full[k, 0])  # shared group
    off = lasers(0.0)
    np.testing.assert_array_equal(off[:, 0], off[:, 1])
    assert not np.array_equal(off[0, 0], off[1, 0])  # private draws differ
    # c=0 is bit-identical to the unblended per-link sampler
    link_spec = FabricSpec(pods=2, links_per_pair=4, comb_group="link")
    link_units = make_fabric_units(CFG, link_spec, seed=9)
    var = as_variations({})
    ref = jax.vmap(lambda u: instantiate_link(CFG, link_spec, u, var))(
        link_units)
    np.testing.assert_array_equal(off, np.asarray(ref.laser))


def test_route_metrics_match_numpy_reference():
    spec = FABRIC_TINY
    res = bringup(CFG, spec, tr_mean=4.0, scheme="vtrs_ssm", seed=11)
    alg = np.asarray(res.ev.alg)
    lanes = np.asarray(res.ev.lanes)
    ch_up = np.asarray(res.ev.ch_up)
    lp = spec.link_pair()
    hops = spec.route_hops()
    r_up, r_cont = [], []
    for route in hops:
        hs = [h for h in route if h >= 0]
        r_up.append(all(alg[lp == h].any() for h in hs))
        avail = [
            np.any(ch_up[(lp == h) & (lanes > 0)], axis=0) for h in hs
        ]
        r_cont.append(bool(np.logical_and.reduce(avail).any()))
    assert float(res.stats.route_up) == pytest.approx(np.mean(r_up))
    assert float(res.stats.route_cont) == pytest.approx(np.mean(r_cont))
    # scalar invariants
    up = alg.mean()
    assert float(res.stats.link_up) == pytest.approx(up)
    assert float(res.stats.matched + res.stats.reconciled) <= up + 1e-6
    assert float(res.stats.bandwidth) >= float(
        res.stats.link_up) - 1e-6  # up links run all lanes


def test_fabric_sweep_grid_mesh_and_chunking():
    spec = FABRIC_TINY
    units = make_fabric_units(CFG, spec, seed=3)
    req = SweepRequest(
        cfg=CFG, units=units, scheme="vtrs_ssm", fabric=spec,
        axes={"comb_coupling": [0.0, 1.0], "tr_mean": [4.0, 5.0]},
    )
    res = sweep(req)
    assert res.axis_names == ("comb_coupling", "tr_mean")
    for leaf in jax.tree_util.tree_leaves(res.data):
        assert leaf.shape == (2, 2)
    link_up = np.asarray(res.data.link_up)
    assert np.all((link_up >= 0) & (link_up <= 1))
    # grid point (coupling=0, tr) equals a standalone bring-up's stats
    ref = bringup(CFG, spec, tr_mean=4.0, scheme="vtrs_ssm", seed=3)
    for field, grid in res.data._asdict().items():
        assert float(np.asarray(grid)[0, 0]) == float(
            getattr(ref.stats, field)), field
    # mesh-sharded and point-chunked runs are bit-identical
    for variant in (req.replace(mesh=make_sweep_mesh()),
                    req.replace(chunk_size=1)):
        alt = sweep(variant)
        for a, b in zip(jax.tree_util.tree_leaves(res.data),
                        jax.tree_util.tree_leaves(alt.data)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # internal link chunking is invariant too
    r1 = bringup(CFG, spec, tr_mean=4.0, scheme="vtrs_ssm", seed=3,
                 link_chunk=1)
    np.testing.assert_array_equal(np.asarray(r1.ev.wl), np.asarray(ref.ev.wl))
    # ... and mesh-sharded standalone bring-up as well
    rm = bringup(CFG, spec, tr_mean=4.0, scheme="vtrs_ssm", seed=3,
                 mesh=make_sweep_mesh())
    np.testing.assert_array_equal(np.asarray(rm.ev.wl), np.asarray(ref.ev.wl))


def test_sweep_request_fabric_validation():
    spec = FABRIC_TINY
    units = make_fabric_units(CFG, spec, seed=0)
    ok = dict(cfg=CFG, units=units, fabric=spec, axes={"tr_mean": [5.0]})
    assert "comb_coupling" in axis_names()
    with pytest.raises(ValueError, match="scheme"):
        SweepRequest(policy="ltc", **ok)
    with pytest.raises(ValueError, match="metric"):
        SweepRequest(scheme="vtrs_ssm", metric="min_tr", cfg=CFG,
                     units=units, fabric=spec, axes={"sigma_rlv": [1.0]})
    with pytest.raises(ValueError, match="FabricUnits"):
        SweepRequest(scheme="vtrs_ssm", cfg=CFG, fabric=spec,
                     units=jnp.zeros(3), axes={"tr_mean": [5.0]})
    other = FabricSpec(pods=2, links_per_pair=1)
    with pytest.raises(ValueError, match="links"):
        SweepRequest(scheme="vtrs_ssm", cfg=CFG, units=units, fabric=other,
                     axes={"tr_mean": [5.0]})

    # --- fabric x timeline composition rules -----------------------------
    from repro.core.temporal import make_timeline
    from repro.fabric.chaos import make_fabric_timeline

    n = CFG.grid.n_ch
    ftl = make_fabric_timeline(spec, 2, n)
    # the valid composition constructs
    SweepRequest(scheme="vtrs_ssm", timeline=ftl, **ok)
    # a per-transceiver Timeline has no link addressing at fabric scale
    with pytest.raises(ValueError, match="FabricTimeline"):
        SweepRequest(scheme="vtrs_ssm", timeline=make_timeline(2, n), **ok)
    # a FabricTimeline without the topology it indexes into
    with pytest.raises(ValueError, match="topology"):
        SweepRequest(scheme="vtrs_ssm", cfg=CFG, units=units,
                     axes={"tr_mean": [5.0]}, timeline=ftl)
    # link-count and channel-count mismatches name both sides
    with pytest.raises(ValueError, match="links"):
        SweepRequest(
            scheme="vtrs_ssm", timeline=make_fabric_timeline(
                FabricSpec(pods=2, links_per_pair=1), 2, n), **ok)
    with pytest.raises(ValueError, match="channels"):
        SweepRequest(scheme="vtrs_ssm",
                     timeline=make_fabric_timeline(spec, 2, n + 1), **ok)
    # events cannot reference lanes/links absent from the fabric spec
    with pytest.raises(ValueError, match="outside"):
        make_fabric_timeline(spec, 2, n,
                             events=((0, "link_kill", spec.n_links),))
    with pytest.raises(ValueError, match="outside"):
        make_fabric_timeline(spec, 2, n, events=((0, "lane_kill", 0, n),))
    with pytest.raises(ValueError, match="unknown event"):
        make_fabric_timeline(spec, 2, n, events=((0, "pod_kill", 0),))


def test_state_from_assignment_sanitizes_dups():
    wl = jnp.asarray([[2, 2, -1, 3], [1, 3, 3, 3]], jnp.int32)
    entry = jnp.asarray([[0, 1, -1, 2], [4, 0, 1, 2]], jnp.int32)
    st = state_from_assignment(wl, entry)
    np.testing.assert_array_equal(
        np.asarray(st.lock), [[2, -1, -1, 3], [1, 3, -1, -1]])
    np.testing.assert_array_equal(
        np.asarray(st.entry), [[0, -1, -1, 2], [4, 0, -1, -1]])
    assert np.all(np.asarray(st.cursor) >= 0)
    np.testing.assert_array_equal(np.asarray(st.probes), [0, 0])


def test_interconnect_warm_rearbitrate_monotone_and_heals():
    from repro.optics.interconnect import bringup as ic_bringup
    from repro.optics.interconnect import rearbitrate

    fab = ic_bringup(2, 8, CFG, tr_mean=4.6, scheme="vtrs_ssm", seed=0)
    assert fab.handle is not None and len(fab.links) == 8
    healthy = {
        i: (l.lanes_up, l.spectral_shift)
        for i, l in enumerate(fab.links) if not l.degraded
    }
    fab2, rounds = rearbitrate(fab, CFG, seed=1)
    assert fab2.bandwidth_fraction >= fab.bandwidth_fraction
    assert rounds <= 3
    for i, (lanes, shift) in healthy.items():
        # warm repair never touches healthy links (no spectral churn)
        assert (fab2.links[i].lanes_up, fab2.links[i].spectral_shift) \
            == (lanes, shift)
    # injected record-level degradation (the trainer's link-event pattern)
    # heals from the carried live state
    l = fab2.links[0]
    fab2.links[0] = dataclasses.replace(
        l, lanes_up=max(0, l.lanes_up - 2), failure="zero_lock")
    fab3, _ = rearbitrate(fab2, CFG, seed=2)
    assert fab3.links[0].lanes_up >= l.lanes_up
    # handle-less states fall back to the legacy cold path and stay monotone
    cold = dataclasses.replace(fab, handle=None)
    cold2, _ = rearbitrate(cold, CFG, seed=5)
    assert cold2.bandwidth_fraction >= cold.bandwidth_fraction
    assert cold2.handle is None


def test_interconnect_rearbitrate_under_link_death():
    from repro.optics.interconnect import bringup as ic_bringup
    from repro.optics.interconnect import inject_link_failure, rearbitrate

    fab = ic_bringup(2, 6, CFG, tr_mean=4.6, scheme="vtrs_ssm", seed=0)
    with pytest.raises(ValueError, match="outside"):
        inject_link_failure(fab, [6])
    with pytest.raises(ValueError, match="handle"):
        inject_link_failure(dataclasses.replace(fab, handle=None), [0])

    hurt = inject_link_failure(fab, [2])
    assert hurt.links[2].lanes_up == 0
    assert hurt.links[2].failure == "link_down"
    assert not hurt.handle.link_alive[2] and hurt.handle.link_alive[[0, 1]].all()
    before = {i: l.lanes_up for i, l in enumerate(fab.links)}

    fab2, _ = rearbitrate(hurt, CFG, seed=1)
    # the killed link is never re-locked: record still down, and its carried
    # endpoint lock rows are fully broken (empty masked bus)
    assert fab2.links[2].lanes_up == 0
    assert fab2.links[2].failure == "link_down"
    lock = np.asarray(fab2.handle.state.lock).reshape(-1, 2, CFG.grid.n_ch)
    assert (lock[2] < 0).all()
    # survivors repair monotonically and keep at least their old lanes
    for i, l in enumerate(fab2.links):
        if i != 2:
            assert l.lanes_up >= before[i]

    # the handle stays reusable: a second injection + repair round composes
    hurt2 = inject_link_failure(fab2, [4])
    assert not hurt2.handle.link_alive[2]  # first failure persists
    fab3, _ = rearbitrate(hurt2, CFG, seed=2)
    assert fab3.links[4].lanes_up == 0 and fab3.links[2].lanes_up == 0
    for i, l in enumerate(fab3.links):
        if i not in (2, 4):
            assert l.lanes_up >= fab2.links[i].lanes_up
    # injection is idempotent
    again = inject_link_failure(fab3, [2])
    assert again.links[2].lanes_up == 0
    np.testing.assert_array_equal(again.handle.link_alive,
                                  fab3.handle.link_alive)


# --------------------------------------------------- property-check layer --
# Structural invariants shared by the deterministic parametrized tests
# below and the hypothesis layer (when installed): degraded-mode route
# metrics always dominate the primary-only ones, and the fallback table is
# primary-first by construction.

def check_degraded_metrics_dominate(pods, links_per_pair, seed, tr_mean):
    routes = ring_routes(pods, 1)
    fallbacks = tuple(
        (tuple((i + j) % pods for j in (0, pods - 1, 1)),) if pods > 2 else ()
        for i in range(len(routes))
    )
    spec = FabricSpec(pods=pods, links_per_pair=links_per_pair,
                      comb_group="bundle", routes=routes,
                      fallbacks=fallbacks if pods > 2 else ())
    res = bringup(CFG, spec, tr_mean=tr_mean, scheme="vtrs_ssm", seed=seed)
    s = res.stats
    assert float(s.route_served) >= float(s.route_up) - 1e-6
    assert float(s.route_cont_served) >= float(s.route_cont) - 1e-6
    assert 0.0 <= float(s.route_bandwidth) <= 1.0 + 1e-6
    # no-fallback spec: served metrics coincide with the primary-only ones
    bare = dataclasses.replace(spec, fallbacks=())
    ref = bringup(CFG, bare, tr_mean=tr_mean, scheme="vtrs_ssm", seed=seed)
    assert float(ref.stats.route_served) == float(ref.stats.route_up)
    assert float(ref.stats.route_cont_served) == float(ref.stats.route_cont)


def check_alternatives_primary_first(pods, n_fallbacks):
    route = tuple(range(pods))
    alts = tuple(
        (0,) + tuple(range(pods - 2, 0, -1)) + (pods - 1,)
        for _ in range(n_fallbacks)
    )
    spec = FabricSpec(pods=pods, routes=(route,), fallbacks=(alts,))
    hops, valid = spec.route_alternatives()
    assert hops.shape[:2] == (1, 1 + n_fallbacks)
    np.testing.assert_array_equal(hops[:, 0, : pods - 1],
                                  spec.route_hops()[:, : pods - 1])
    assert valid.all()


@pytest.mark.parametrize("pods,links_per_pair,seed,tr_mean", [
    (2, 2, 0, 4.0), (3, 2, 7, 5.0), (4, 1, 3, 4.5),
])
def test_degraded_metrics_dominate(pods, links_per_pair, seed, tr_mean):
    check_degraded_metrics_dominate(pods, links_per_pair, seed, tr_mean)


@pytest.mark.parametrize("pods,n_fallbacks", [(3, 1), (4, 2), (5, 3)])
def test_alternatives_primary_first(pods, n_fallbacks):
    check_alternatives_primary_first(pods, n_fallbacks)


# ------------------------------------------------------ hypothesis layer --

if HAVE_HYPOTHESIS:

    @given(pods=st.integers(2, 4), links_per_pair=st.integers(1, 3),
           seed=st.integers(0, 31), tr_mean=st.floats(3.0, 7.0))
    @settings(**SETTINGS)
    def test_hypo_degraded_metrics_dominate(pods, links_per_pair, seed,
                                            tr_mean):
        check_degraded_metrics_dominate(pods, links_per_pair, seed, tr_mean)

    @given(pods=st.integers(3, 6), n_fallbacks=st.integers(1, 3))
    @settings(**SETTINGS)
    def test_hypo_alternatives_primary_first(pods, n_fallbacks):
        check_alternatives_primary_first(pods, n_fallbacks)
