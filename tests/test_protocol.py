"""Protocol-engine invariants (repro.core.protocol).

The engine's termination argument rests on mechanical invariants, probed
over random *and* tie-heavy grid-quantized systems:

  * red-ward monotonicity: within a round (release -> probe -> augment) no
    ring's tuner cursor ever decreases, and a ring locked at both phase
    boundaries never moved to an earlier entry; only the release phase may
    rewind, and only for starved rings;
  * static termination: complete trials are fixed points — once every ring
    holds a line, later rounds change nothing;
  * dup-lock freedom: a searcher can only lock a *visible* line and donor
    hand-offs are atomic, so ``outcomes.classify`` must never see a
    duplicate lock (nor an out-of-table one);
  * soundness: protocol success implies ideal LtA success (every lock is a
    reach-graph edge, so a completed protocol is a perfect matching).

The checks run twice: a deterministic parametrized sweep (always on, so
tier-1 really exercises them — hypothesis is not installed in every CI
container) and, when hypothesis is importable, the same invariants under
randomized @given search.
"""
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on container
    HAVE_HYPOTHESIS = False

from repro.core import ArbitrationConfig, DWDMGrid, ideal, make_units
from repro.core.outcomes import classify
from repro.core.protocol import (
    masked_first_entry,
    run_protocol,
    run_protocol_trace,
)
from repro.core.relation import chain_spec
from repro.core.sampling import SystemBatch, instantiate
from repro.core.search_table import build_search_tables

SETTINGS = dict(max_examples=10, deadline=None)

#: deterministic (n_ch, seed, tr_mean, quantized) grid for the always-on runs
CASES = [
    (4, 0, 2.5, False),
    (4, 3, 6.0, True),
    (8, 1, 1.0, False),
    (8, 2, 4.5, False),
    (8, 5, 3.0, True),
    (8, 7, 9.0, True),
]


def _random_system(n_ch, seed, quantized):
    """Either a sampled paper system or a tie-heavy grid-quantized batch."""
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=n_ch))
    if not quantized:
        return cfg, instantiate(cfg, make_units(cfg, seed, 3, 3))
    rng = np.random.default_rng(seed)
    t = 9
    sys = SystemBatch(
        laser=jnp.asarray(rng.integers(0, n_ch, (t, n_ch)).astype(np.float32) * 0.25),
        ring=jnp.asarray(rng.integers(-4, 4, (t, n_ch)).astype(np.float32) * 0.25),
        fsr=jnp.asarray(rng.integers(1, 4, (t, n_ch)).astype(np.float32) * 0.25),
        tr_unit=jnp.ones((t, n_ch), jnp.float32),
    )
    return cfg, sys


def _tables_spec(cfg, sys, tr_mean):
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    return tables, chain_spec(cfg.s)


# ------------------------------------------------------ invariant checkers --

def check_no_dup_lock_and_locks_in_table(n_ch, seed, tr_mean, quantized,
                                         depth=None):
    """classify must never see a duplicate or out-of-table lock."""
    cfg, sys = _random_system(n_ch, seed, quantized)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    asg = run_protocol(tables, spec, depth=depth)
    out = classify(asg, jnp.asarray(cfg.s), policy="lta")
    assert not np.any(np.asarray(out.dup_lock))
    wl = np.asarray(asg.wl)
    entry = np.asarray(asg.entry)
    locked = wl >= 0
    assert np.all(wl[locked] < n_ch)
    # the locked entry really is that line in the ring's table
    twl = np.asarray(tables.wl)
    rows, rings = np.nonzero(locked)
    assert np.all(twl[rows, rings, entry[locked]] == wl[locked])


def check_redward_monotone_within_round(n_ch, seed, tr_mean, quantized):
    """Cursors never decrease inside a round; locked rings never move to an
    earlier entry between phase boundaries; release rewinds starved only."""
    cfg, sys = _random_system(n_ch, seed, quantized)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    _, snaps = run_protocol_trace(tables, spec, n_rounds=5)
    by_round = {}
    for rnd, phase, state in snaps:
        by_round.setdefault(rnd, {})[phase] = state
    prev_release = None
    for rnd in sorted(by_round):
        probe, augment, release = (
            by_round[rnd]["probe"], by_round[rnd]["augment"],
            by_round[rnd]["release"],
        )
        if prev_release is not None:  # release of round r-1 opens round r
            assert np.all(probe.cursor >= prev_release.cursor)
        assert np.all(augment.cursor >= probe.cursor)
        both = (probe.entry >= 0) & (augment.entry >= 0)
        assert np.all(augment.entry[both] >= probe.entry[both])
        # release only rewinds cursors, and only for starved rings
        rewound = release.cursor < augment.cursor
        assert np.all(release.lock[rewound] < 0)
        assert np.all(release.cursor[rewound] == 0)
        prev_release = release


def check_complete_trials_are_fixed_points(n_ch, seed, tr_mean, quantized):
    """Termination: once a trial is fully locked, no later phase changes it
    (so the while_loop bound in run_protocol is an upper bound, not a cap
    on useful work)."""
    cfg, sys = _random_system(n_ch, seed, quantized)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    _, snaps = run_protocol_trace(tables, spec, n_rounds=4)
    states = [s for _, _, s in snaps]
    for i, state in enumerate(states[:-1]):
        complete = np.all(state.lock >= 0, axis=1)
        for later in states[i + 1:]:
            assert np.array_equal(later.lock[complete], state.lock[complete])


def check_protocol_success_implies_ideal_lta(n_ch, seed, tr_mean, quantized):
    """A completed protocol run IS a perfect matching in the reach graph."""
    cfg, sys = _random_system(n_ch, seed, quantized)
    tables, spec = _tables_spec(cfg, sys, tr_mean)
    asg = run_protocol(tables, spec)
    out = classify(asg, jnp.asarray(cfg.s), policy="lta")
    ideal_ok = np.asarray(
        ideal.success(sys, "lta", jnp.asarray(cfg.s), tr_mean)
    )
    assert not np.any(np.asarray(out.success) & ~ideal_ok)


# ------------------------------------------------ always-on deterministic --

@pytest.mark.parametrize("n_ch,seed,tr_mean,quantized", CASES)
def test_no_dup_lock_and_locks_in_table(n_ch, seed, tr_mean, quantized):
    for depth in (0, 1, None):
        check_no_dup_lock_and_locks_in_table(
            n_ch, seed, tr_mean, quantized, depth=depth
        )


@pytest.mark.parametrize("n_ch,seed,tr_mean,quantized", CASES)
def test_redward_monotone_within_round(n_ch, seed, tr_mean, quantized):
    check_redward_monotone_within_round(n_ch, seed, tr_mean, quantized)


@pytest.mark.parametrize("n_ch,seed,tr_mean,quantized", CASES[:3])
def test_complete_trials_are_fixed_points(n_ch, seed, tr_mean, quantized):
    check_complete_trials_are_fixed_points(n_ch, seed, tr_mean, quantized)


@pytest.mark.parametrize("n_ch,seed,tr_mean,quantized", CASES)
def test_protocol_success_implies_ideal_lta(n_ch, seed, tr_mean, quantized):
    check_protocol_success_implies_ideal_lta(n_ch, seed, tr_mean, quantized)


# ----------------------------------------------------- hypothesis variants --

if HAVE_HYPOTHESIS:
    _args = dict(
        n_ch=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**16),
        tr_mean=st.floats(0.5, 10.0),
        quantized=st.booleans(),
    )

    @given(depth=st.sampled_from([0, 1, None]), **_args)
    @settings(**SETTINGS)
    def test_hyp_no_dup_lock(n_ch, seed, tr_mean, quantized, depth):
        check_no_dup_lock_and_locks_in_table(
            n_ch, seed, tr_mean, quantized, depth=depth
        )

    @given(**_args)
    @settings(**SETTINGS)
    def test_hyp_redward_monotone(n_ch, seed, tr_mean, quantized):
        check_redward_monotone_within_round(n_ch, seed, tr_mean, quantized)

    @given(**_args)
    @settings(**SETTINGS)
    def test_hyp_fixed_points(n_ch, seed, tr_mean, quantized):
        check_complete_trials_are_fixed_points(n_ch, seed, tr_mean, quantized)

    @given(**_args)
    @settings(**SETTINGS)
    def test_hyp_success_implies_ideal(n_ch, seed, tr_mean, quantized):
        check_protocol_success_implies_ideal_lta(n_ch, seed, tr_mean, quantized)


# ------------------------------------------------- masked re-search kernel --

@partial(jax.jit, static_argnames=("backend",))
def _research_via_ops(wl, taken, floor, backend):
    from repro.kernels import ops

    return ops.masked_research(wl, taken, floor, backend=backend)


@pytest.mark.parametrize("seed,c,e,n_lines,t", [
    (0, 1, 8, 8, 7),
    (1, 5, 24, 8, 130),
    (2, 16, 24, 16, 64),
    (3, 4, 12, 16, 128),
])
def test_masked_research_kernel_parity(seed, c, e, n_lines, t):
    """ops.masked_research (jnp + pallas-interpret) is bit-identical to the
    core primitive the protocol engine runs on, including trial padding."""
    rng = np.random.default_rng(seed)
    wl = rng.integers(-1, n_lines, (t, c, e)).astype(np.int32)
    taken = rng.random((t, n_lines)) < 0.4
    floor = rng.integers(0, e + 1, (t, c)).astype(np.int32)
    first0, found0 = masked_first_entry(
        jnp.asarray(wl), jnp.asarray(taken), jnp.asarray(floor)
    )
    for backend in ("jnp", "interpret"):
        first, found = _research_via_ops(wl, taken, floor, backend)
        np.testing.assert_array_equal(np.asarray(first0), np.asarray(first))
        np.testing.assert_array_equal(np.asarray(found0), np.asarray(found))


def test_protocol_engine_backend_parity():
    """run_protocol routed through the kernel wrappers (interpret) matches
    the core jnp path bit-for-bit."""
    cfg = ArbitrationConfig()
    sys = instantiate(cfg, make_units(cfg, 7, 3, 3))
    tables, spec = _tables_spec(cfg, sys, 5.0)
    a0 = run_protocol(tables, spec)
    for backend in ("jnp", "interpret"):
        a1 = run_protocol(tables, spec, backend=backend)
        np.testing.assert_array_equal(np.asarray(a0.entry), np.asarray(a1.entry))
        np.testing.assert_array_equal(np.asarray(a0.wl), np.asarray(a1.wl))


def test_protocol_schemes_registered():
    """The protocol family rides the ordinary scheme registry."""
    from repro.core import SCHEME_POLICY, registered_schemes, scheme_spec

    names = registered_schemes()
    for name in ("protocol_lta", "protocol_lta_h1", "protocol_lta_h2",
                 "protocol_lta_h4", "protocol_ltd"):
        assert name in names
    assert SCHEME_POLICY["protocol_lta"] == "lta"
    assert SCHEME_POLICY["protocol_ltd"] == "ltd"
    assert dict(scheme_spec("protocol_lta_h2").params) == {"depth": 2}


def test_probe_counts_batch_independent():
    """A trial's probe count must not depend on which other trials share
    the batched round loop: running each trial alone gives the same stats
    as running the whole batch (hopeless/complete trials stop spending
    probes even while slower co-batched trials keep the while_loop alive)."""
    cfg = ArbitrationConfig()
    sys = instantiate(cfg, make_units(cfg, 11, 4, 4))
    # low TR: a mix of complete, live-starved and hopeless trials
    for tr in (1.5, 3.0, 6.0):
        tables, spec = _tables_spec(cfg, sys, tr)
        _, full = run_protocol(tables, spec, with_stats=True)
        for t in range(0, tables.wl.shape[0], 5):
            sub = jax.tree_util.tree_map(lambda a: a[t:t + 1], tables)
            _, solo = run_protocol(sub, spec, with_stats=True)
            assert int(solo.probes[0]) == int(full.probes[t]), (tr, t)
            assert int(solo.locked[0]) == int(full.locked[t]), (tr, t)


def test_protocol_stats_accounting():
    """with_stats returns probe/round accounting consistent with the run."""
    cfg = ArbitrationConfig()
    sys = instantiate(cfg, make_units(cfg, 3, 4, 4))
    tables, spec = _tables_spec(cfg, sys, 6.0)
    asg, stats = run_protocol(tables, spec, with_stats=True)
    locked = np.asarray((asg.wl >= 0).sum(axis=1))
    assert np.array_equal(np.asarray(stats.locked), locked)
    assert np.all(np.asarray(stats.probes) >= cfg.grid.n_ch)  # >= 1/ring
    assert np.all(np.asarray(stats.rounds) >= 1)


def test_protocol_closes_seq_retry_residual():
    """The headline: at TR points where depth-1 retry (seq_retry) leaves
    residual CAFP vs the ideal LtA arbiter, full multi-hop augmenting is
    ideal (CAFP == 0 on this seed — the fig19 acceptance in miniature)."""
    from repro.configs.wdm import WDM8_G200
    from repro.core import SweepRequest, sweep

    cfg = WDM8_G200
    units = make_units(cfg, seed=21, n_laser=10, n_ring=10)  # 100 trials
    trs = np.linspace(0.28, 9.0, 6).astype(np.float32)
    cafp = {}
    for scheme in ("seq_retry", "protocol_lta"):
        res = sweep(SweepRequest(cfg=cfg, units=units, scheme=scheme,
                                 axes={"tr_mean": trs}))
        cafp[scheme] = np.asarray(res.data.cafp)
    residual = cafp["seq_retry"] > 0.0
    assert residual.any(), "expected seq_retry residual on this grid"
    assert float(cafp["protocol_lta"][residual].max()) <= 1e-3
