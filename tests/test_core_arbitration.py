"""Cross-validation of the vectorized arbitration core against the
pure-Python reference oracle, plus paper-semantics unit tests."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    ArbitrationConfig,
    make_units,
)
from repro.core import reference as ref
from repro.core import ideal
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables
from repro.core.relation import RI_PHI, chain_spec, relation_search
from repro.core.sequential import sequential_tuning
from repro.core.ssm import single_step_matching
from repro.core.outcomes import classify


def _systems(kind="natural", seed=0, n=6):
    cfg = ArbitrationConfig().with_orders(kind)
    units = make_units(cfg, seed=seed, n_laser=n, n_ring=n)
    sys = instantiate(cfg, units)
    arrs = tuple(map(np.asarray, (sys.laser, sys.ring, sys.fsr, sys.tr_unit)))
    return cfg, sys, arrs


def _trial(arrs, t, tr_mean):
    laser, ring, fsr, tru = arrs
    return ref.Trial(laser=laser[t], ring=ring[t], fsr=fsr[t], tr=tr_mean * tru[t])


@pytest.mark.parametrize("kind", ["natural", "permuted"])
def test_ideal_min_tr_matches_oracle(kind):
    cfg, sys, arrs = _systems(kind)
    s = jnp.asarray(cfg.s)
    mt = {
        "ltd": np.asarray(ideal.ltd_min_tr(sys, s)),
        "ltc": np.asarray(ideal.ltc_min_tr(sys, s)),
        "lta": np.asarray(ideal.lta_min_tr(sys)),
    }
    tru = arrs[3]
    for t in range(min(sys.n_trials, 15)):
        trial = _trial(arrs, t, 1.0)
        for pol in ("ltd", "ltc", "lta"):
            want = ref.min_tr(trial, pol, list(cfg.s), tru[t])
            np.testing.assert_allclose(mt[pol][t], want, rtol=1e-5, atol=1e-5)


def test_policy_inclusion():
    """LtA <= LtC <= LtD minimum tuning range, per trial (policy nesting)."""
    cfg, sys, _ = _systems(n=10)
    s = jnp.asarray(cfg.s)
    lta = np.asarray(ideal.lta_min_tr(sys))
    ltc = np.asarray(ideal.ltc_min_tr(sys, s))
    ltd = np.asarray(ideal.ltd_min_tr(sys, s))
    assert np.all(lta <= ltc + 1e-5)
    assert np.all(ltc <= ltd + 1e-5)


@pytest.mark.parametrize("kind", ["natural", "permuted"])
@pytest.mark.parametrize("tr_mean", [3.0, 6.0, 9.5])
def test_search_tables_match_oracle(kind, tr_mean):
    cfg, sys, arrs = _systems(kind)
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    dj = np.asarray(tables.delta)
    wj = np.asarray(tables.wl)
    nv = np.asarray(tables.n_valid)
    for t in range(min(sys.n_trials, 12)):
        trial = _trial(arrs, t, tr_mean)
        for i in range(sys.n_ch):
            st = ref.search_table(trial, i)
            assert len(st) == nv[t, i]
            for e, (d, k) in enumerate(st):
                assert wj[t, i, e] == k
                np.testing.assert_allclose(dj[t, i, e], d, atol=1e-5)


@pytest.mark.parametrize("kind", ["natural", "permuted"])
@pytest.mark.parametrize("vt", [False, True])
def test_relation_search_matches_oracle(kind, vt):
    cfg, sys, arrs = _systems(kind, seed=1)
    tr_mean = 5.0
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    ri_j = np.asarray(relation_search(tables, spec, variation_tolerant=vt))
    for t in range(min(sys.n_trials, 20)):
        trial = _trial(arrs, t, tr_mean)
        ri_r = ref.relation_search(trial, list(cfg.s), variation_tolerant=vt)
        for pos in range(sys.n_ch):
            want = RI_PHI if ri_r[pos] is None else ri_r[pos]
            assert ri_j[t, pos] == want, (t, pos)


@pytest.mark.parametrize("kind", ["natural", "permuted"])
@pytest.mark.parametrize("tr_mean", [3.0, 5.0, 7.0, 9.5])
def test_ssm_matches_oracle(kind, tr_mean):
    cfg, sys, arrs = _systems(kind, seed=2)
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    ri = relation_search(tables, spec)
    asg = single_step_matching(tables, ri, spec)
    aw, ad = np.asarray(asg.wl), np.asarray(asg.delta)
    for t in range(min(sys.n_trials, 20)):
        trial = _trial(arrs, t, tr_mean)
        rr = ref.relation_search(trial, list(cfg.s))
        locks = ref.single_step_matching(trial, list(cfg.s), rr)
        for i in range(sys.n_ch):
            if locks[i] is None:
                assert aw[t, i] == -1
            else:
                assert locks[i][1] == aw[t, i]
                np.testing.assert_allclose(ad[t, i], locks[i][0], atol=1e-5)


@pytest.mark.parametrize("kind", ["natural", "permuted"])
def test_sequential_matches_oracle(kind):
    cfg, sys, arrs = _systems(kind, seed=3)
    tr_mean = 5.0
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    seq = sequential_tuning(tables, spec)
    sw, sd = np.asarray(seq.wl), np.asarray(seq.delta)
    for t in range(min(sys.n_trials, 20)):
        trial = _trial(arrs, t, tr_mean)
        locks = ref.sequential_tuning(trial, list(cfg.s))
        for i in range(sys.n_ch):
            if locks[i] is None:
                assert sw[t, i] == -1
            else:
                assert locks[i][1] == sw[t, i]
                np.testing.assert_allclose(sd[t, i], locks[i][0], atol=1e-5)


@pytest.mark.parametrize("tr_mean", [4.0, 6.0, 9.5])
def test_classify_matches_oracle(tr_mean):
    cfg, sys, arrs = _systems("natural", seed=4)
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    seq = sequential_tuning(tables, spec)
    out = classify(seq, jnp.asarray(cfg.s), policy="ltc")
    succ, zl, dl, oe = map(np.asarray, out)
    for t in range(min(sys.n_trials, 25)):
        trial = _trial(arrs, t, tr_mean)
        locks = ref.sequential_tuning(trial, list(cfg.s))
        want = ref.classify(locks, list(cfg.s))
        got = {
            (True, False, False, False): "success",
            (False, True, False, False): "zero_lock",
            (False, False, True, False): "dup_lock",
            (False, False, False, True): "order_err",
        }[(bool(succ[t]), bool(zl[t]), bool(dl[t]), bool(oe[t]))]
        # Oracle reports zero before dup; vectorized flags can overlap there.
        if want == "zero_lock":
            assert zl[t]
        elif want == "dup_lock":
            assert dl[t]
        else:
            assert got == want
