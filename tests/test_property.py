"""Hypothesis property tests on system-level arbitration invariants."""
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ArbitrationConfig, DWDMGrid, VariationModel, make_units
from repro.core import ideal
from repro.core.sampling import SystemBatch, instantiate
from repro.core.reach import tuning_residual
from repro.core.search_table import build_search_tables, build_search_tables_dense
from repro.core.relation import chain_spec, relation_search
from repro.core.ssm import single_step_matching
from repro.core.outcomes import classify

SETTINGS = dict(max_examples=15, deadline=None)


def _cfg(n_ch, sigma_rlv, sigma_go, order_kind):
    grid = DWDMGrid(n_ch=n_ch)
    var = VariationModel(sigma_rlv=sigma_rlv, sigma_go=sigma_go)
    return ArbitrationConfig(grid=grid, var=var).with_orders(order_kind)


@given(
    n_ch=st.sampled_from([4, 8]),
    sigma_rlv=st.floats(0.0, 6.0),
    sigma_go=st.floats(0.0, 15.0),
    order_kind=st.sampled_from(["natural", "permuted"]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_residual_bounds(n_ch, sigma_rlv, sigma_go, order_kind, seed):
    """The tuning residual is a red-shift within one FSR."""
    cfg = _cfg(n_ch, sigma_rlv, sigma_go, order_kind)
    sys = instantiate(cfg, make_units(cfg, seed, 3, 3))
    res = np.asarray(tuning_residual(sys))
    fsr = np.asarray(sys.fsr)[:, :, None]
    assert np.all(res >= 0.0)
    assert np.all(res < fsr + 1e-5)


@given(
    n_ch=st.sampled_from([4, 8]),
    sigma_rlv=st.floats(0.0, 6.0),
    sigma_go=st.floats(0.0, 15.0),
    order_kind=st.sampled_from(["natural", "permuted"]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_policy_nesting(n_ch, sigma_rlv, sigma_go, order_kind, seed):
    """LtD success => LtC success => LtA success (enforcement inclusion)."""
    cfg = _cfg(n_ch, sigma_rlv, sigma_go, order_kind)
    sys = instantiate(cfg, make_units(cfg, seed, 3, 3))
    s = jnp.asarray(cfg.s)
    lta = np.asarray(ideal.lta_min_tr(sys))
    ltc = np.asarray(ideal.ltc_min_tr(sys, s))
    ltd = np.asarray(ideal.ltd_min_tr(sys, s))
    assert np.all(lta <= ltc + 1e-5)
    assert np.all(ltc <= ltd + 1e-5)


@given(seed=st.integers(0, 2**16), shift_mult=st.integers(1, 3))
@settings(**SETTINGS)
def test_barrel_shift_invariance(seed, shift_mult):
    """Grid offsets of exact multiples of the grid spacing are cancelled by
    cyclic reordering for LtC/LtA (paper §IV-C, Fig. 7(a)) when FSR has no
    variation, FSR == N * spacing, and laser lines sit on the exact grid
    (local laser variation breaks per-trial exactness, leaving only the
    statistical flatness the paper reports)."""
    grid = DWDMGrid(n_ch=8)
    var = VariationModel(sigma_fsr_frac=0.0, sigma_go=0.0, sigma_llv_frac=0.0)
    cfg = ArbitrationConfig(grid=grid, var=var)
    units = make_units(cfg, seed, 4, 4)
    base = instantiate(cfg, units)
    shifted = base._replace(laser=base.laser + shift_mult * grid.grid_spacing)
    s = jnp.asarray(cfg.s)
    np.testing.assert_allclose(
        np.asarray(ideal.ltc_min_tr(base, s)),
        np.asarray(ideal.ltc_min_tr(shifted, s)),
        atol=2e-4,
    )
    np.testing.assert_allclose(
        np.asarray(ideal.lta_min_tr(base)),
        np.asarray(ideal.lta_min_tr(shifted)),
        atol=2e-4,
    )


@given(
    seed=st.integers(0, 2**16),
    tr_mean=st.floats(1.5, 10.0),
    order_kind=st.sampled_from(["natural", "permuted"]),
)
@settings(**SETTINGS)
def test_ssm_assignment_physical(seed, tr_mean, order_kind):
    """Whatever SSM assigns must be physically lockable: the tuning distance
    is within the ring's actual tuning range, and the line id valid."""
    cfg = ArbitrationConfig().with_orders(order_kind)
    sys = instantiate(cfg, make_units(cfg, seed, 4, 4))
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    ri = relation_search(tables, spec, variation_tolerant=True)
    asg = single_step_matching(tables, ri, spec)
    wl = np.asarray(asg.wl)
    delta = np.asarray(asg.delta)
    tr = tr_mean * np.asarray(sys.tr_unit)
    locked = wl >= 0
    assert np.all(delta[locked] <= tr[locked] + 1e-5)
    assert np.all(wl[locked] < cfg.grid.n_ch)


# --------------------------------------------- streaming table builder ---

@partial(jax.jit, static_argnames=("max_alias", "has_vis"))
def _both_builders(sys, tr_mean, vis, max_alias, has_vis):
    # Jitted together: the engine always runs the builder under jit, and
    # XLA's fusion (FMA formation) differs between eager and compiled —
    # bit-identity is contracted where production runs.
    v = vis if has_vis else None
    return (
        build_search_tables(sys, tr_mean, visible=v, max_alias=max_alias),
        build_search_tables_dense(sys, tr_mean, visible=v, max_alias=max_alias),
    )


def _assert_tables_identical(sys, tr_mean, vis=None, max_alias=8):
    stream, dense = _both_builders(
        sys, tr_mean, vis if vis is not None else jnp.zeros(()),
        max_alias, vis is not None,
    )
    assert stream.delta.shape == dense.delta.shape
    np.testing.assert_array_equal(np.asarray(stream.wl), np.asarray(dense.wl))
    np.testing.assert_array_equal(
        np.asarray(stream.n_valid), np.asarray(dense.n_valid)
    )
    assert np.array_equal(
        np.asarray(stream.delta), np.asarray(dense.delta), equal_nan=True
    )


@given(
    n_ch=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
    tr_mean=st.floats(0.5, 30.0),     # up to TR >> FSR: multi-alias tables
    max_alias=st.sampled_from([0, 1, 2, 8]),
    vis_kind=st.sampled_from(["none", "2d", "3d", "dead_rings"]),
)
@settings(**SETTINGS)
def test_streaming_tables_match_dense_oracle(n_ch, seed, tr_mean, max_alias, vis_kind):
    """The streaming top-E builder is bit-identical to the dense full-sort
    oracle — entries, tie order, sentinels and n_valid — on random systems,
    with 2-D/3-D visibility masks and with fully-masked rings (n_valid=0)."""
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=n_ch))
    sys = instantiate(cfg, make_units(cfg, seed, 4, 4))
    T, N = sys.laser.shape
    vis = None
    if vis_kind == "2d":
        vis = jax.random.bernoulli(jax.random.key(seed), 0.6, (T, N))
    elif vis_kind == "3d":
        vis = jax.random.bernoulli(jax.random.key(seed), 0.5, (T, N, N))
    elif vis_kind == "dead_rings":
        vis = jax.random.bernoulli(jax.random.key(seed), 0.5, (T, N, N))
        vis = vis.at[: T // 2].set(False)  # whole rings with n_valid == 0
    _assert_tables_identical(sys, tr_mean, vis, max_alias)


@given(seed=st.integers(0, 2**16), max_alias=st.sampled_from([1, 3]))
@settings(**SETTINGS)
def test_streaming_tables_match_dense_oracle_on_ties(seed, max_alias):
    """Grid-quantized systems make many candidate deltas *exactly* equal
    across (line, alias) pairs; the merge must reproduce the dense stable
    argsort's tie order (flat candidate index) bit-for-bit."""
    rng = np.random.default_rng(seed)
    T, N = 12, 8
    sys = SystemBatch(
        laser=jnp.asarray(rng.integers(0, 8, (T, N)).astype(np.float32) * 0.25),
        ring=jnp.asarray(rng.integers(-4, 4, (T, N)).astype(np.float32) * 0.25),
        fsr=jnp.asarray(rng.integers(1, 4, (T, N)).astype(np.float32) * 0.25),
        tr_unit=jnp.ones((T, N), jnp.float32),
    )
    _assert_tables_identical(sys, 3.0, None, max_alias)


@given(seed=st.integers(0, 2**16), tr_mean=st.floats(2.0, 9.0))
@settings(**SETTINGS)
def test_oblivious_success_implies_ideal_when_anchored(seed, tr_mean):
    """An LtC-classified success of the oblivious algorithm is a valid cyclic
    assignment — therefore the ideal LtC arbiter must also succeed."""
    cfg = ArbitrationConfig()
    sys = instantiate(cfg, make_units(cfg, seed, 4, 4))
    s = jnp.asarray(cfg.s)
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    ri = relation_search(tables, spec, variation_tolerant=True)
    asg = single_step_matching(tables, ri, spec)
    out = classify(asg, s, policy="ltc")
    ideal_ok = np.asarray(ideal.ltc_min_tr(sys, s) <= tr_mean)
    alg_ok = np.asarray(out.success)
    assert not np.any(alg_ok & ~ideal_ok)
