"""Hypothesis property tests on system-level arbitration invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ArbitrationConfig, DWDMGrid, VariationModel, make_units
from repro.core import ideal
from repro.core.sampling import instantiate
from repro.core.reach import tuning_residual
from repro.core.search_table import build_search_tables
from repro.core.relation import chain_spec, relation_search
from repro.core.ssm import single_step_matching
from repro.core.outcomes import classify

SETTINGS = dict(max_examples=15, deadline=None)


def _cfg(n_ch, sigma_rlv, sigma_go, order_kind):
    grid = DWDMGrid(n_ch=n_ch)
    var = VariationModel(sigma_rlv=sigma_rlv, sigma_go=sigma_go)
    return ArbitrationConfig(grid=grid, var=var).with_orders(order_kind)


@given(
    n_ch=st.sampled_from([4, 8]),
    sigma_rlv=st.floats(0.0, 6.0),
    sigma_go=st.floats(0.0, 15.0),
    order_kind=st.sampled_from(["natural", "permuted"]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_residual_bounds(n_ch, sigma_rlv, sigma_go, order_kind, seed):
    """The tuning residual is a red-shift within one FSR."""
    cfg = _cfg(n_ch, sigma_rlv, sigma_go, order_kind)
    sys = instantiate(cfg, make_units(cfg, seed, 3, 3))
    res = np.asarray(tuning_residual(sys))
    fsr = np.asarray(sys.fsr)[:, :, None]
    assert np.all(res >= 0.0)
    assert np.all(res < fsr + 1e-5)


@given(
    n_ch=st.sampled_from([4, 8]),
    sigma_rlv=st.floats(0.0, 6.0),
    sigma_go=st.floats(0.0, 15.0),
    order_kind=st.sampled_from(["natural", "permuted"]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_policy_nesting(n_ch, sigma_rlv, sigma_go, order_kind, seed):
    """LtD success => LtC success => LtA success (enforcement inclusion)."""
    cfg = _cfg(n_ch, sigma_rlv, sigma_go, order_kind)
    sys = instantiate(cfg, make_units(cfg, seed, 3, 3))
    s = jnp.asarray(cfg.s)
    lta = np.asarray(ideal.lta_min_tr(sys))
    ltc = np.asarray(ideal.ltc_min_tr(sys, s))
    ltd = np.asarray(ideal.ltd_min_tr(sys, s))
    assert np.all(lta <= ltc + 1e-5)
    assert np.all(ltc <= ltd + 1e-5)


@given(seed=st.integers(0, 2**16), shift_mult=st.integers(1, 3))
@settings(**SETTINGS)
def test_barrel_shift_invariance(seed, shift_mult):
    """Grid offsets of exact multiples of the grid spacing are cancelled by
    cyclic reordering for LtC/LtA (paper §IV-C, Fig. 7(a)) when FSR has no
    variation, FSR == N * spacing, and laser lines sit on the exact grid
    (local laser variation breaks per-trial exactness, leaving only the
    statistical flatness the paper reports)."""
    grid = DWDMGrid(n_ch=8)
    var = VariationModel(sigma_fsr_frac=0.0, sigma_go=0.0, sigma_llv_frac=0.0)
    cfg = ArbitrationConfig(grid=grid, var=var)
    units = make_units(cfg, seed, 4, 4)
    base = instantiate(cfg, units)
    shifted = base._replace(laser=base.laser + shift_mult * grid.grid_spacing)
    s = jnp.asarray(cfg.s)
    np.testing.assert_allclose(
        np.asarray(ideal.ltc_min_tr(base, s)),
        np.asarray(ideal.ltc_min_tr(shifted, s)),
        atol=2e-4,
    )
    np.testing.assert_allclose(
        np.asarray(ideal.lta_min_tr(base)),
        np.asarray(ideal.lta_min_tr(shifted)),
        atol=2e-4,
    )


@given(
    seed=st.integers(0, 2**16),
    tr_mean=st.floats(1.5, 10.0),
    order_kind=st.sampled_from(["natural", "permuted"]),
)
@settings(**SETTINGS)
def test_ssm_assignment_physical(seed, tr_mean, order_kind):
    """Whatever SSM assigns must be physically lockable: the tuning distance
    is within the ring's actual tuning range, and the line id valid."""
    cfg = ArbitrationConfig().with_orders(order_kind)
    sys = instantiate(cfg, make_units(cfg, seed, 4, 4))
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    ri = relation_search(tables, spec, variation_tolerant=True)
    asg = single_step_matching(tables, ri, spec)
    wl = np.asarray(asg.wl)
    delta = np.asarray(asg.delta)
    tr = tr_mean * np.asarray(sys.tr_unit)
    locked = wl >= 0
    assert np.all(delta[locked] <= tr[locked] + 1e-5)
    assert np.all(wl[locked] < cfg.grid.n_ch)


@given(seed=st.integers(0, 2**16), tr_mean=st.floats(2.0, 9.0))
@settings(**SETTINGS)
def test_oblivious_success_implies_ideal_when_anchored(seed, tr_mean):
    """An LtC-classified success of the oblivious algorithm is a valid cyclic
    assignment — therefore the ideal LtC arbiter must also succeed."""
    cfg = ArbitrationConfig()
    sys = instantiate(cfg, make_units(cfg, seed, 4, 4))
    s = jnp.asarray(cfg.s)
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    ri = relation_search(tables, spec, variation_tolerant=True)
    asg = single_step_matching(tables, ri, spec)
    out = classify(asg, s, policy="ltc")
    ideal_ok = np.asarray(ideal.ltc_min_tr(sys, s) <= tr_mean)
    alg_ok = np.asarray(out.success)
    assert not np.any(alg_ok & ~ideal_ok)
