"""Always-on deterministic oracle tests for the rank-merge streaming builder.

``tests/test_property.py`` carries the hypothesis variants of these checks,
but that module skips wholesale when hypothesis is not installed — the
bit-exactness contract of ``build_search_tables`` vs the dense oracle
(entries, tie order, sentinels, n_valid) must hold in every environment, so
the representative cases live here as plain parametrized tests: tie-heavy
grid-quantized systems, TR > FSR multi-alias tables, fully-masked (dead)
rings, 2-D/3-D visibility masks, the degenerate FSR == 0 system, and the
forced single-line (L=1) tiling of paper-scale batches.
"""
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ArbitrationConfig, DWDMGrid, make_units
from repro.core.sampling import SystemBatch, instantiate
from repro.core.search_table import (
    build_search_tables,
    build_search_tables_dense,
    merge_plan,
)


@partial(jax.jit, static_argnames=("max_alias", "has_vis"))
def _both_builders(sys, tr_mean, vis, max_alias, has_vis):
    # Jitted together: the engine always runs the builder under jit, and
    # XLA's fusion (FMA formation) differs between eager and compiled —
    # bit-identity is contracted where production runs.
    v = vis if has_vis else None
    return (
        build_search_tables(sys, tr_mean, visible=v, max_alias=max_alias),
        build_search_tables_dense(sys, tr_mean, visible=v, max_alias=max_alias),
    )


def _assert_tables_identical(sys, tr_mean, vis=None, max_alias=8):
    stream, dense = _both_builders(
        sys, tr_mean, vis if vis is not None else jnp.zeros(()),
        max_alias, vis is not None,
    )
    assert stream.delta.shape == dense.delta.shape
    np.testing.assert_array_equal(np.asarray(stream.wl), np.asarray(dense.wl))
    np.testing.assert_array_equal(
        np.asarray(stream.n_valid), np.asarray(dense.n_valid)
    )
    assert np.array_equal(
        np.asarray(stream.delta), np.asarray(dense.delta), equal_nan=True
    )


def _vis(kind, key, T, N):
    if kind == "none":
        return None
    if kind == "2d":
        return jax.random.bernoulli(key, 0.6, (T, N))
    if kind == "3d":
        return jax.random.bernoulli(key, 0.5, (T, N, N))
    assert kind == "dead_rings", kind
    # dead_rings: whole rings see nothing -> n_valid == 0 rows
    vis = jax.random.bernoulli(key, 0.5, (T, N, N))
    return vis.at[: T // 2].set(False)


@pytest.mark.parametrize(
    "n_ch,max_alias,tr_mean,vis_kind",
    [
        (4, 8, 9.5, "none"),
        (8, 0, 3.0, "none"),       # no aliasing at all
        (8, 8, 5.0, "2d"),
        (8, 8, 9.5, "3d"),
        (8, 8, 9.5, "dead_rings"),
        (8, 3, 30.0, "none"),      # TR >> FSR: multi-alias entries
        (16, 2, 5.0, "none"),
        (16, 8, 30.0, "3d"),       # multi-alias + per-ring masking
    ],
)
def test_rank_merge_matches_dense_oracle(n_ch, max_alias, tr_mean, vis_kind):
    """Streaming rank-merge == dense full-sort oracle, bit for bit."""
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=n_ch))
    sys = instantiate(cfg, make_units(cfg, seed=7, n_laser=4, n_ring=4))
    T, N = sys.laser.shape
    vis = _vis(vis_kind, jax.random.key(3), T, N)
    _assert_tables_identical(sys, tr_mean, vis, max_alias)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("max_alias", [1, 3])
def test_rank_merge_tie_order_on_quantized_systems(seed, max_alias):
    """Grid-quantized systems make many candidate deltas *exactly* equal
    across (line, alias) pairs; the rank pass must reproduce the dense
    stable argsort's tie order (flat candidate index) bit-for-bit."""
    rng = np.random.default_rng(seed)
    T, N = 12, 8
    sys = SystemBatch(
        laser=jnp.asarray(rng.integers(0, 8, (T, N)).astype(np.float32) * 0.25),
        ring=jnp.asarray(rng.integers(-4, 4, (T, N)).astype(np.float32) * 0.25),
        fsr=jnp.asarray(rng.integers(1, 4, (T, N)).astype(np.float32) * 0.25),
        tr_unit=jnp.ones((T, N), jnp.float32),
    )
    _assert_tables_identical(sys, 3.0, None, max_alias)


def test_rank_merge_degenerate_fsr_zero():
    """FSR == 0 collapses every alias of a line onto one delta — the
    maximal tie pile-up; the first J' aliases of each reachable line must
    surface in flat order exactly as the dense stable argsort emits them."""
    T, N = 8, 4
    rng = np.random.default_rng(11)
    sys = SystemBatch(
        laser=jnp.asarray(rng.integers(0, 6, (T, N)).astype(np.float32) * 0.5),
        ring=jnp.asarray(rng.integers(-3, 3, (T, N)).astype(np.float32) * 0.5),
        fsr=jnp.zeros((T, N), jnp.float32),
        tr_unit=jnp.ones((T, N), jnp.float32),
    )
    _assert_tables_identical(sys, 4.0, None, 8)


def test_rank_merge_forced_single_line_tiling():
    """Large trial counts force the L=1 plan (the paper-scale tiling whose
    sort-free rotation + fused rank path is the tentpole's hot loop)."""
    cfg = ArbitrationConfig(grid=DWDMGrid(n_ch=8))
    sys = instantiate(cfg, make_units(cfg, seed=5, n_laser=100, n_ring=200))
    T, N = sys.laser.shape
    plan = merge_plan(T, N)
    assert plan.line_block == 1, plan  # the test exists to cover this path
    _assert_tables_identical(sys, 5.0, None, 8)
