"""Validation of the paper's headline claims against our implementation
(EXPERIMENTS.md §Repro).  Monte-Carlo sizes are CPU-scaled; the full-size
runs live in benchmarks/ (--full)."""
import numpy as np
import pytest

from repro.configs.wdm import WDM8_G200
from repro.core import Variations, evaluate_scheme, make_units, policy_min_tr


@pytest.fixture(scope="module")
def units():
    return make_units(WDM8_G200, seed=42, n_laser=32, n_ring=32)


def test_vt_rs_ssm_tracks_ideal(units):
    """Fig. 14: VT-RS/SSM closely approximates ideal LtC arbitration."""
    for tr in (3.0, 5.0, 7.0, 8.96):
        r = evaluate_scheme(WDM8_G200, units, "vtrs_ssm", tr)
        assert float(r.cafp) <= 0.01, tr


def test_schemes_beat_sequential(units):
    """Fig. 14: proposed schemes outperform sequential tuning everywhere."""
    for tr in (4.0, 6.0, 8.0):
        seq = float(evaluate_scheme(WDM8_G200, units, "seq", tr).cafp)
        rs = float(evaluate_scheme(WDM8_G200, units, "rs_ssm", tr).cafp)
        vt = float(evaluate_scheme(WDM8_G200, units, "vtrs_ssm", tr).cafp)
        assert vt <= rs + 1e-6
        assert rs < seq
        assert seq > 0.3  # the baseline really does fail on most trials


def test_rs_ssm_errors_at_large_tr(units):
    """Fig. 14: RS/SSM residual errors appear around TR ~ 8 nm (10% TR
    variation corrupts Lock-to-Last relation searches)."""
    lo = float(evaluate_scheme(WDM8_G200, units, "rs_ssm", 4.0).cafp)
    hi = float(evaluate_scheme(WDM8_G200, units, "rs_ssm", 8.0).cafp)
    assert hi > lo


def test_ltc_ramp_slope_two(units):
    """§IV-A: min tuning range ramps at slope ~2 in sigma_rLV for LtC."""
    rlvs = np.array([0.28, 0.56, 1.12, 1.68])
    mt = [float(policy_min_tr(WDM8_G200, units, "ltc",
                              Variations(sigma_rlv=float(s))))
          for s in rlvs]
    slope = np.polyfit(rlvs, mt, 1)[0]
    assert 1.5 <= slope <= 2.5, slope


def test_ltd_slope_one_and_impractical(units):
    """§IV-B: LtD ramps at slope ~1; grid offsets >= 4 nm push the
    requirement beyond the FSR."""
    rlvs = np.array([0.28, 0.56, 1.12, 2.24])
    mt = [float(policy_min_tr(WDM8_G200, units, "ltd",
                              Variations(sigma_rlv=float(s), sigma_go=0.0)))
          for s in rlvs]
    slope = np.polyfit(rlvs, mt, 1)[0]
    assert 0.7 <= slope <= 1.4, slope
    mt4 = float(policy_min_tr(WDM8_G200, units, "ltd", Variations(sigma_go=4.0)))
    assert mt4 > WDM8_G200.grid.fsr


def test_ordering_invariance_of_ideal_min_tr(units):
    """§IV-A: pre-fab/post-arb ordering choice does not change the ideal
    minimum tuning range (N/N vs P/P)."""
    for policy in ("lta", "ltc"):
        nat = float(policy_min_tr(WDM8_G200.with_orders("natural"),
                                  units, policy))
        per = float(policy_min_tr(WDM8_G200.with_orders("permuted"),
                                  units, policy))
        assert abs(nat - per) / nat < 0.15, (policy, nat, per)


@pytest.mark.xfail(
    reason="pre-existing seed calibration gap: with sigma_rLV = 2.24 nm the "
    "LtC minimum TR saturates near the *under-designed* FSR itself, so the "
    "under-design penalty stays < 0.5 nm at these sizes (fails on the seed "
    "checkout with identical values)",
    strict=False,
)
def test_fsr_design_guideline(units):
    """§IV-D: the nominal FSR (N_ch * gS) is near-optimal; under-design
    degrades sharply, over-design gradually."""
    mt_nom = float(policy_min_tr(WDM8_G200, units, "ltc", Variations(fsr_mean=8.96)))
    mt_under = float(policy_min_tr(WDM8_G200, units, "ltc", Variations(fsr_mean=6.72)))
    mt_over = float(policy_min_tr(WDM8_G200, units, "ltc", Variations(fsr_mean=15.68)))
    assert mt_under > mt_nom + 0.5
    assert mt_over > mt_nom + 0.5


def test_policy_tuning_range_ordering(units):
    """Fig. 4: LtA needs the least tuning range, then LtC, then LtD."""
    lta = float(policy_min_tr(WDM8_G200, units, "lta"))
    ltc = float(policy_min_tr(WDM8_G200, units, "ltc"))
    ltd = float(policy_min_tr(WDM8_G200, units, "ltd"))
    assert lta <= ltc <= ltd


@pytest.mark.slow
def test_beyond_lta_oblivious_arbiter(units):
    """Beyond-paper (§V-E future work): the oblivious LtA arbiter
    (sequential-retry + depth-1 augmenting) far outperforms naive
    sequential against the ideal LtA matcher, and is near-exact at the
    operating extremes."""
    lo = float(evaluate_scheme(WDM8_G200, units, "seq_retry", 2.0).cafp)
    hi = float(evaluate_scheme(WDM8_G200, units, "seq_retry", 8.96).cafp)
    mid = float(evaluate_scheme(WDM8_G200, units, "seq_retry", 4.0).cafp)
    assert lo <= 0.05 and hi <= 0.05
    # mid-TR starvation gap persists but stays far below the naive
    # baseline's ~0.9 failure plateau; documented in EXPERIMENTS.
    assert mid <= 0.6
