"""Tier-1-safe self-test for benchmarks.check_regression — synthetic BENCH
payloads only, no jax, no benchmark execution."""
import json

import pytest

from benchmarks.check_regression import compare, main


def _payload(full=False, **figure_times):
    """figure_times: name -> (module_wall_ms, engine_ms | None[, phases])."""
    records = []
    for fig, times in figure_times.items():
        wall, engine = times[0], times[1]
        derived = {} if engine is None else {"engine_ms": engine}
        if len(times) > 2:
            derived.update(times[2])  # per-phase *_ms breakdown fields
        records.append(
            {"figure": fig, "name": f"{fig}/row", "module_wall_ms": wall,
             "derived": derived}
        )
    return {"schema": "bench.v1", "full": full, "records": records}


def test_no_regression_within_threshold():
    old = _payload(fig4=(1000.0, 100.0), fig5=(500.0, None))
    new = _payload(fig4=(1150.0, 110.0), fig5=(550.0, None))  # <= +20%
    regressions, _ = compare(old, new)
    assert regressions == []


def test_figure_and_record_regressions_flagged():
    old = _payload(fig4=(1000.0, 100.0))
    new = _payload(fig4=(1500.0, 200.0))
    regressions, _ = compare(old, new)
    kinds = {(r["kind"], r["name"]) for r in regressions}
    assert ("figure", "fig4") in kinds
    assert ("record", "fig4/row") in kinds
    ratios = {r["name"]: r["ratio"] for r in regressions}
    assert ratios["fig4"] == pytest.approx(1.5)


def test_added_and_removed_figures_never_fail():
    old = _payload(fig4=(1000.0, None), old_only=(100.0, None))
    new = _payload(fig4=(1000.0, None), new_only=(99999.0, None))
    regressions, notes = compare(old, new)
    assert regressions == []
    assert any("new_only" in n for n in notes)
    assert any("old_only" in n for n in notes)


def test_phase_breakdown_fields_gated():
    """Shared *_ms phase fields gate like engine_ms, keyed name:field."""
    old = _payload(fig18=(1000.0, 100.0, {"table_ms": 50.0, "score_ms": 10.0}))
    new = _payload(fig18=(1000.0, 100.0, {"table_ms": 120.0, "score_ms": 11.0}))
    regressions, _ = compare(old, new)
    assert [(r["kind"], r["name"]) for r in regressions] == [
        ("record", "fig18/row:table_ms")
    ]


def test_phase_breakdown_missing_on_old_baseline_is_graceful():
    """Old baselines without the breakdown produce notes, never failures,
    and engine_ms keeps gating under its plain record name."""
    old = _payload(fig18=(1000.0, 100.0))
    new = _payload(fig18=(1000.0, 500.0, {"table_ms": 9e9}))
    regressions, notes = compare(old, new)
    assert [(r["kind"], r["name"]) for r in regressions] == [
        ("record", "fig18/row")
    ]
    assert any("table_ms" in n and "only in new" in n for n in notes)


def test_threshold_is_configurable():
    old = _payload(fig4=(1000.0, None))
    new = _payload(fig4=(1100.0, None))
    assert compare(old, new, threshold=0.20)[0] == []
    assert len(compare(old, new, threshold=0.05)[0]) == 1


def test_main_exit_codes(tmp_path):
    ok_old = tmp_path / "old.json"
    ok_new = tmp_path / "new.json"
    ok_old.write_text(json.dumps(_payload(fig4=(1000.0, 100.0))))
    ok_new.write_text(json.dumps(_payload(fig4=(1010.0, 101.0))))
    assert main([str(ok_old), str(ok_new)]) == 0

    bad_new = tmp_path / "bad.json"
    bad_new.write_text(json.dumps(_payload(fig4=(2000.0, 100.0))))
    assert main([str(ok_old), str(bad_new)]) == 1

    full_new = tmp_path / "full.json"
    full_new.write_text(json.dumps(_payload(full=True, fig4=(1000.0, 100.0))))
    assert main([str(ok_old), str(full_new)]) == 2


def test_main_schema_mismatch_is_incomparable(tmp_path):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_payload(fig4=(1000.0, None))))
    v2 = _payload(fig4=(1000.0, None))
    v2["schema"] = "bench.v2"
    b.write_text(json.dumps(v2))
    assert main([str(a), str(b)]) == 2
