"""Per-link Monte-Carlo sampling for fabric bring-up.

One fabric draw is *per-link*, not a laser x ring cross product: link k has
its own comb sample (grid offset + per-line local variation, shared by both
endpoint transceivers — the two ends see the same physical light) and two
independent ring-row samples (one per endpoint).  ``instantiate_link``
reproduces ``repro.core.sampling.instantiate``'s Eq. 3-4 math exactly for
an (L=1 laser, R=2 rings) cross product, which is what makes constraints-off
fabric bring-up bit-identical to independent per-link arbitration (the
fig21 acceptance parity; asserted in tests/test_fabric.py).

Shared-comb coupling blends each link's private laser draws with its comb
group's draws: ``u_eff = (1 - c) * u_private + c * u_group`` with ``c`` the
``comb_coupling`` variation axis.  Both endpoints are exact by construction:
c = 0 reproduces the private draw bit-for-bit (``1*u + 0*g``), c = 1 the
group draw (``0*u + 1*g``) — so links in a comb group degrade *together*
at full coupling, and the uncoupled limit stays a valid independence
baseline.  For ``comb_group="link"`` the group draws alias the private
draws and the blend is skipped entirely (spec is jit-static).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.grid import ArbitrationConfig
from repro.core.sampling import SystemBatch
from repro.core.variations import Variations, apply_axis_transforms

from .spec import FabricSpec


class FabricUnits(NamedTuple):
    """Unit uniform deviates in [-1, 1] for every link of a fabric.

    Laser draws are per link (both endpoints share the comb); ring draws
    are per endpoint (axis 1: 0 = tx-side transceiver, 1 = rx-side).
    ``g_go``/``g_llv`` are the link's comb-*group* draws, pre-gathered to
    link order (for ``comb_group="link"`` they alias ``go``/``llv``).
    """

    go: jax.Array     # (K,)       grid offset per link comb
    llv: jax.Array    # (K, N)     laser local variation per link comb
    g_go: jax.Array   # (K,)       comb-group grid offset, gathered per link
    g_llv: jax.Array  # (K, N)     comb-group local variation, per link
    rlv: jax.Array    # (K, 2, N)  ring local variation per endpoint
    fsr: jax.Array    # (K, 2, N)  FSR variation per endpoint
    tr: jax.Array     # (K, 2, N)  tuning-range variation per endpoint

    @property
    def n_links(self) -> int:
        return self.go.shape[0]


def make_fabric_units(
    cfg: ArbitrationConfig, spec: FabricSpec, seed: int
) -> FabricUnits:
    """Draw genuinely independent per-link/per-endpoint unit samples.

    (This replaces the old interconnect ``seed``/``seed+1`` re-draw splice,
    which crossed an n_links-laser batch with an n_links-ring batch and kept
    only the first n_links of the n_links^2 trials — every link shared
    laser sample 0.)
    """
    n = cfg.grid.n_ch
    k = spec.n_links
    ks = jax.random.split(jax.random.key(seed), 7)
    u = lambda key, shape: jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0)
    go = u(ks[0], (k,))
    llv = u(ks[1], (k, n))
    if spec.comb_group == "link":
        g_go, g_llv = go, llv  # blend is the identity; see instantiate_link
    else:
        group = jnp.asarray(spec.link_group())
        g_go = u(ks[2], (spec.n_groups,))[group]
        g_llv = u(ks[3], (spec.n_groups, n))[group]
    return FabricUnits(
        go=go, llv=llv, g_go=g_go, g_llv=g_llv,
        rlv=u(ks[4], (k, 2, n)),
        fsr=u(ks[5], (k, 2, n)),
        tr=u(ks[6], (k, 2, n)),
    )


def instantiate_link(
    cfg: ArbitrationConfig,
    spec: FabricSpec,
    units: FabricUnits,
    variations: Variations,
) -> SystemBatch:
    """One link's unit draws -> a T=2 ``SystemBatch`` (one trial per end).

    ``units`` here is a single-link slice (leading K axis removed — the
    bring-up engine vmaps this over link chunks).  Math is Eq. 3-4 exactly
    as ``core.sampling.instantiate`` computes it for L=1, R=2: both trials
    share the link's one laser row, each gets its own ring row.
    """
    grid = cfg.grid
    s_go = variations.resolve("sigma_go", cfg)
    s_llv = variations.resolve("sigma_llv_frac", cfg) * grid.grid_spacing
    s_rlv = variations.resolve("sigma_rlv", cfg)
    s_fsr = variations.resolve("sigma_fsr_frac", cfg)
    s_tr = variations.resolve("sigma_tr_frac", cfg)
    fsr0 = variations.resolve("fsr_mean", cfg)

    if spec.comb_group == "link":
        u_go, u_llv = units.go, units.llv
    else:
        c = variations.resolve("comb_coupling", cfg)
        u_go = (1.0 - c) * units.go + c * units.g_go
        u_llv = (1.0 - c) * units.llv + c * units.g_llv

    # Lasers: lambda_i = grid_i + Delta_gO + Delta_lLV,i           (Eq. 3)
    laser = (
        jnp.asarray(grid.laser_grid())[None, :]
        + s_go * u_go
        + s_llv * u_llv[None, :]
    )  # (1, N); u_go is scalar here (the link's comb offset)
    # Rings: lambda_i = grid(r_i) - lambda_rB + Delta_rLV,i        (Eq. 4)
    ring = jnp.asarray(grid.ring_grid(cfg.r))[None, :] + s_rlv * units.rlv
    fsr = fsr0 * (1.0 + s_fsr * units.fsr)       # (2, N)
    tr_unit = 1.0 + s_tr * units.tr              # (2, N)

    n = laser.shape[1]
    sys = SystemBatch(
        laser=jnp.broadcast_to(laser, (2, n)),
        ring=ring,
        fsr=fsr,
        tr_unit=tr_unit,
    )
    return apply_axis_transforms(sys, variations, cfg)
