"""Fabric-scale arbitration: per-link schemes + network-level constraints.

See ``spec`` (topology), ``sampling`` (per-link draws, comb coupling),
``bringup`` (chunked/sharded bring-up, ``FabricStats``).  Sweep whole
fabrics over variation grids with ``SweepRequest(fabric=...)``.
"""
from .bringup import (
    FabricResult,
    FabricStats,
    LinkEval,
    aggregate_stats,
    auto_link_chunk,
    bringup,
    fabric_stats_impl,
    state_from_assignment,
)
from .sampling import FabricUnits, instantiate_link, make_fabric_units
from .spec import FabricSpec

__all__ = [
    "FabricResult",
    "FabricSpec",
    "FabricStats",
    "FabricUnits",
    "LinkEval",
    "aggregate_stats",
    "auto_link_chunk",
    "bringup",
    "fabric_stats_impl",
    "instantiate_link",
    "make_fabric_units",
    "state_from_assignment",
]
