"""Fabric-scale arbitration: per-link schemes + network-level constraints.

See ``spec`` (topology, routes + fallbacks), ``sampling`` (per-link draws,
comb coupling), ``bringup`` (chunked/sharded bring-up, ``FabricStats``),
``chaos`` (fault-injection timelines + warm re-lock across the fabric).
Sweep whole fabrics over variation grids with ``SweepRequest(fabric=...)``;
compose drift/fault timelines with ``SweepRequest(fabric=..., timeline=...)``.
"""
from .bringup import (
    FabricResult,
    FabricStats,
    LinkEval,
    aggregate_stats,
    auto_link_chunk,
    bringup,
    fabric_stats_impl,
    link_record,
    state_from_assignment,
)
from .chaos import (
    FabricChaosStats,
    FabricTimeline,
    make_fabric_timeline,
    run_fabric_timeline,
    run_fabric_timeline_impl,
    summarize_chaos,
)
from .sampling import FabricUnits, instantiate_link, make_fabric_units
from .spec import FabricSpec

__all__ = [
    "FabricChaosStats",
    "FabricResult",
    "FabricSpec",
    "FabricStats",
    "FabricTimeline",
    "FabricUnits",
    "LinkEval",
    "aggregate_stats",
    "auto_link_chunk",
    "bringup",
    "fabric_stats_impl",
    "instantiate_link",
    "link_record",
    "make_fabric_timeline",
    "make_fabric_units",
    "run_fabric_timeline",
    "run_fabric_timeline_impl",
    "state_from_assignment",
    "summarize_chaos",
]
