"""Declarative fabric topology: pods, link bundles, comb groups, routes.

A ``FabricSpec`` describes a DWDM fabric the way the network-level related
work frames it (*Scheduling Light-trails on WDM Rings*, *Multi-Path RWA* —
PAPERS.md): pods connected by *bundles* of point-to-point DWDM links, each
link a pair of N-ring transceivers sharing one comb's light, with routes as
pod sequences subject to per-hop availability and wavelength-continuity
constraints.  The spec is a frozen, hashable dataclass — it rides the sweep
engine's jit-static argument tuple exactly like ``ArbitrationConfig`` — and
all derived topology arrays (link -> pod pair, comb group, route hop maps)
are host-side numpy, computed once and cached on first use.

Comb-source sharing is the fabric-level coupling knob: links in one comb
group draw *correlated* laser variations, blended by the ``comb_coupling``
variation axis registered below (0 = fully private draws, the constraints-
off limit that is bit-identical to independent per-link arbitration; 1 =
identical group draws).  ``comb_group`` picks the sharing topology:

  "link"    one comb per link (no coupling; mixing is the identity)
  "bundle"  all links of a pod pair share one comb
  "pod"     all bundles out of the lower-numbered pod share one comb
  "fabric"  a single comb bank drives every link
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.variations import axis_names, register_axis

_COMB_GROUPS = ("link", "bundle", "pod", "fabric")


def _coupling_check(v: float) -> None:
    if not 0.0 <= v <= 1.0:
        raise ValueError(
            f"axis 'comb_coupling' must be in [0, 1], got {v}"
        )


# Fabric-level variation axis, registered through the PR-3 extension
# contract: one call makes it a valid ``Variations`` key and a sweepable
# ``SweepRequest`` axis with no engine edits.  No ``transform`` hook — the
# fabric sampler consumes it directly when blending comb-group draws
# (a per-link quantity, invisible to the single-transceiver sampler).
if "comb_coupling" not in axis_names():  # idempotent under module reload
    register_axis(
        "comb_coupling", lambda cfg: 0.0,
        doc=("shared-comb coupling strength in [0, 1]: laser variation "
             "draws blend (1-c)*private + c*group within a comb group"),
        validate=_coupling_check,
    )


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """A complete fabric topology description (hashable, jit-static).

    pods:           number of pods; every unordered pod pair gets a bundle.
    links_per_pair: links (transceiver pairs) per pod-pair bundle.
    comb_group:     comb-source sharing topology (see module docstring).
    routes:         tuple of routes, each a tuple of >= 2 pod ids whose
                    consecutive pairs name the bundles the route traverses.
                    Route metrics (``FabricStats.route_up`` /
                    ``route_cont``) are vacuously 1.0 when empty.
    fallbacks:      optional per-route alternatives for graceful
                    degradation: empty, or one tuple per primary route,
                    each a (possibly empty) tuple of alternative routes
                    sharing the primary's endpoints.  The degraded-mode
                    metrics (``FabricStats.route_served`` /
                    ``route_cont_served`` / ``route_bandwidth``) score a
                    route by its best alternative; the primary-only
                    ``route_up`` / ``route_cont`` metrics ignore them.
    """

    pods: int = 2
    links_per_pair: int = 8
    comb_group: str = "link"
    routes: tuple = ()
    fallbacks: tuple = ()

    def _check_route(self, route) -> None:
        if len(route) < 2:
            raise ValueError(f"route {route} needs >= 2 pods")
        for a, b in zip(route, route[1:]):
            if a == b:
                raise ValueError(f"route {route} repeats pod {a}")
            if not (0 <= a < self.pods and 0 <= b < self.pods):
                raise ValueError(
                    f"route {route} names a pod outside 0..{self.pods - 1}"
                )

    def __post_init__(self):
        object.__setattr__(self, "routes",
                           tuple(tuple(int(p) for p in r) for r in self.routes))
        object.__setattr__(self, "fallbacks", tuple(
            tuple(tuple(int(p) for p in alt) for alt in alts)
            for alts in self.fallbacks
        ))
        if self.pods < 2:
            raise ValueError(f"a fabric needs >= 2 pods, got {self.pods}")
        if self.links_per_pair < 1:
            raise ValueError(
                f"links_per_pair must be >= 1, got {self.links_per_pair}"
            )
        if self.comb_group not in _COMB_GROUPS:
            raise ValueError(
                f"unknown comb_group {self.comb_group!r}; valid: {_COMB_GROUPS}"
            )
        for route in self.routes:
            self._check_route(route)
        if self.fallbacks and len(self.fallbacks) != len(self.routes):
            raise ValueError(
                f"fallbacks must be empty or one tuple per route: got "
                f"{len(self.fallbacks)} for {len(self.routes)} routes"
            )
        for route, alts in zip(self.routes, self.fallbacks):
            for alt in alts:
                self._check_route(alt)
                if (alt[0], alt[-1]) != (route[0], route[-1]):
                    raise ValueError(
                        f"fallback {alt} does not share route {route}'s "
                        f"endpoints ({route[0]}, {route[-1]})"
                    )

    # ---------------------------------------------------------- topology
    @property
    def pairs(self) -> tuple:
        """Unordered pod pairs (a < b), bundle index order."""
        return tuple(
            (a, b)
            for a in range(self.pods)
            for b in range(a + 1, self.pods)
        )

    @property
    def n_pairs(self) -> int:
        return self.pods * (self.pods - 1) // 2

    @property
    def n_links(self) -> int:
        return self.n_pairs * self.links_per_pair

    def link_pair(self) -> np.ndarray:
        """(n_links,) int: bundle (pod-pair) index of each link."""
        return np.repeat(np.arange(self.n_pairs), self.links_per_pair)

    def link_pods(self) -> tuple:
        """((n_links,) src pod, (n_links,) dst pod) with src < dst."""
        pairs = np.asarray(self.pairs, np.int64).reshape(-1, 2)
        lp = self.link_pair()
        return pairs[lp, 0], pairs[lp, 1]

    def link_in_pair(self) -> np.ndarray:
        """(n_links,) int: index of each link within its bundle."""
        return np.tile(np.arange(self.links_per_pair), self.n_pairs)

    # -------------------------------------------------------- comb groups
    def link_group(self) -> np.ndarray:
        """(n_links,) int: comb group of each link (see ``n_groups``)."""
        if self.comb_group == "link":
            return np.arange(self.n_links)
        if self.comb_group == "bundle":
            return self.link_pair()
        if self.comb_group == "pod":
            return self.link_pods()[0]
        return np.zeros(self.n_links, np.int64)  # "fabric"

    @property
    def n_groups(self) -> int:
        return int(self.link_group().max()) + 1

    # ------------------------------------------------------------- routes
    @property
    def max_hops(self) -> int:
        return max((len(r) - 1 for r in self.routes), default=0)

    def route_hops(self) -> np.ndarray:
        """(n_routes, max_hops) int: bundle index per hop, -1 padding."""
        pair_index = {p: i for i, p in enumerate(self.pairs)}
        hops = np.full((len(self.routes), max(self.max_hops, 1)), -1, np.int64)
        for ri, route in enumerate(self.routes):
            for hi, (a, b) in enumerate(zip(route, route[1:])):
                hops[ri, hi] = pair_index[(min(a, b), max(a, b))]
        return hops

    def route_alternatives(self) -> tuple:
        """Per-route alternative sets for the degraded-mode metrics.

        Returns ``(hops, valid)``: ``hops`` is (n_routes, n_alts, max_hops)
        int with bundle index per hop (-1 padding), alternative 0 always the
        primary route; ``valid`` is (n_routes, n_alts) bool marking real
        alternatives (routes with fewer fallbacks are padded with invalid
        rows).  With no fallbacks declared every route has exactly its
        primary (``hops[:, :1] == route_hops()[:, None]``).
        """
        pair_index = {p: i for i, p in enumerate(self.pairs)}
        alts_per = [
            (route,) + (self.fallbacks[ri] if self.fallbacks else ())
            for ri, route in enumerate(self.routes)
        ]
        n_alts = max((len(a) for a in alts_per), default=1)
        max_h = max(
            (len(r) - 1 for alts in alts_per for r in alts), default=1
        )
        hops = np.full((len(self.routes), n_alts, max(max_h, 1)), -1, np.int64)
        valid = np.zeros((len(self.routes), n_alts), bool)
        for ri, alts in enumerate(alts_per):
            for ai, route in enumerate(alts):
                valid[ri, ai] = True
                for hi, (a, b) in enumerate(zip(route, route[1:])):
                    hops[ri, ai, hi] = pair_index[(min(a, b), max(a, b))]
        return hops, valid
