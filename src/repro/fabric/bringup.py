"""Fabric bring-up: per-link arbitration composed with network constraints.

Every link is two N-ring transceivers sharing one comb's light: bring-up
runs the chosen arbitration scheme on both endpoints (one T=2 evaluation
per link, vmapped over link chunks through the sweep engine's
``chunked_map``), then composes the per-link outcomes with the
network-level wavelength-assignment constraints of the RWA-style related
work (PAPERS.md):

  * **endpoint-matched spectral orderings** — a link is *up* only when both
    ends arbitrate successfully; among up links, ends whose lane -> line
    maps are LtC-clean either already agree on the barrel shift
    (``matched``) or need a one-time electrical remap at one end
    (``reconciled``);
  * **shared-comb coupling** — links in one comb group draw correlated
    laser variations (``comb_coupling`` axis; ``fabric.sampling``), so a
    bad comb draw degrades a whole bundle together;
  * **per-route wavelength continuity** — a route (pod sequence) is *up*
    when every hop's bundle has a fully-arbitrated link, and *continuous*
    when one wavelength channel is captured at both ends of a usable link
    on every hop (the Multi-Path-RWA continuity constraint, any-link-per-
    bundle form).

``fabric_stats_impl`` is the sweep engine's per-grid-point body
(``SweepRequest(fabric=...)``); ``bringup`` is the standalone entry that
additionally returns per-link records and live endpoint lock state for
warm re-arbitration (``optics/interconnect.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import _build_tables, _ideal_success, scheme_spec
from repro.core.grid import ArbitrationConfig
from repro.core.outcomes import classify
from repro.core.protocol import ProtocolState
from repro.core.relation import chain_spec
from repro.core.sampling import SystemBatch
from repro.core.ssm import Assignment
from repro.core.sweep import _CHUNK_BUDGET, chunked_map, scheme_point_bytes
from repro.core.variations import Variations, as_variations

from .sampling import FabricUnits, instantiate_link, make_fabric_units
from .spec import FabricSpec


class LinkEval(NamedTuple):
    """Per-link bring-up record (leading axis = links once stacked)."""

    alg: jax.Array      # ()    bool: both ends arbitrated successfully
    ideal: jax.Array    # ()    bool: ideal policy succeeds at both ends
    lanes: jax.Array    # ()    int32 usable lanes (N when ``alg``)
    zero: jax.Array     # (2,)  bool per-end zero-lock
    dup: jax.Array      # (2,)  bool per-end dup-lock
    order: jax.Array    # (2,)  bool per-end order error (scheme policy)
    ltc_ok: jax.Array   # (2,)  bool per-end LtC-clean (uniform barrel shift)
    shift: jax.Array    # (2,)  int32 per-end barrel shift (ring 0's line)
    ch_up: jax.Array    # (N,)  bool: channel captured at BOTH ends
    wl: jax.Array       # (2, N) int32 per-end locked line ids (-1 starved)
    entry: jax.Array    # (2, N) int32 per-end locked table entries
    system: Any = None  # SystemBatch (2, N) when requested (warm restarts)


class FabricStats(NamedTuple):
    """Fabric-level yield metrics (scalars; grids under the sweep engine).

    Route metrics are 1.0 when the spec declares no routes (vacuously
    satisfied constraints).  ``route_up``/``route_cont`` score the primary
    routes only; the ``*_served`` / ``route_bandwidth`` degraded-mode
    metrics score each route by its best alternative (primary or declared
    fallback), so a comb/link failure reports a bandwidth floor instead of
    a binary fabric death.
    """

    link_up: jax.Array     # fraction of links with both ends arbitrated
    afp: jax.Array         # fabric AFP: P(ideal fails on either end)
    cafp: jax.Array        # P(link fails & ideal fine on both ends) (Eq. 6)
    matched: jax.Array     # up links whose ends agree on the barrel shift
    reconciled: jax.Array  # up links needing a one-time shift reconciliation
    bandwidth: jax.Array   # mean usable-lane fraction over links
    route_up: jax.Array    # routes with >= 1 fully-up link on every hop
    route_cont: jax.Array  # routes with a continuity wavelength on every hop
    route_served: jax.Array      # routes with ANY alternative fully up
    route_cont_served: jax.Array # ... with a continuity wavelength on any alt
    route_bandwidth: jax.Array   # mean over routes of best-alt bottleneck
                                 # usable-lane fraction (max link per hop)


def link_record(
    cfg: ArbitrationConfig,
    policy: str,
    wl: jax.Array,
    entry: jax.Array,
    ideal_ok: jax.Array,
    system=None,
) -> LinkEval:
    """Classify one link's (2, N) locked-line map into a ``LinkEval``.

    Shared by one-shot bring-up (``_eval_link``) and the chaos timeline
    (``fabric.chaos``), which re-derives records from the live protocol
    state each step — same lane accounting, bit for bit.
    """
    n = cfg.grid.n_ch
    s = jnp.asarray(cfg.s)
    asg = Assignment(entry=entry, wl=wl, delta=jnp.zeros(wl.shape, jnp.float32))
    out = classify(asg, s, policy=policy)

    # LtC-cleanliness is reported for every scheme (LtA fabrics still need
    # it for the spectral-ordering metrics); for ltc-policy schemes it
    # coincides with ``out.success``.
    ltc = classify(asg, s, policy="ltc")
    shift = (wl[:, 0] - s[0]) % n

    onehot = jax.nn.one_hot(jnp.clip(wl, 0, n - 1), n, dtype=jnp.int32)
    counts = jnp.sum(onehot * (wl >= 0)[..., None], axis=1)    # (2, N)
    distinct = jnp.sum((counts > 0).astype(jnp.int32), axis=1)  # (2,)
    locked = jnp.sum((wl >= 0).astype(jnp.int32), axis=1)       # (2,)
    # A lane carries data when its ring locked a *unique* line: every dup
    # costs one extra lane beyond the distinct count (old interconnect
    # heuristic, now per endpoint); an order error is a crossbar remap,
    # no lane loss — and indeed 2*N - N = N below.
    end_lanes = jnp.clip(2 * distinct - locked, 0, n)

    link_alg = out.success[0] & out.success[1]
    lanes = jnp.where(link_alg, n, jnp.minimum(end_lanes[0], end_lanes[1]))
    return LinkEval(
        alg=link_alg,
        ideal=ideal_ok[0] & ideal_ok[1],
        lanes=lanes.astype(jnp.int32),
        zero=out.zero_lock,
        dup=out.dup_lock,
        order=out.order_err,
        ltc_ok=ltc.success,
        shift=shift.astype(jnp.int32),
        ch_up=(counts[0] > 0) & (counts[1] > 0),
        wl=wl.astype(jnp.int32),
        entry=entry.astype(jnp.int32),
        system=system,
    )


def _eval_link(
    cfg: ArbitrationConfig,
    spec: FabricSpec,
    scheme: str,
    backend: str | None,
    with_system: bool,
    variations: Variations,
    link_units: FabricUnits,
) -> LinkEval:
    """Arbitrate one link's two endpoints and classify the outcomes."""
    sspec = scheme_spec(scheme)
    sys = instantiate_link(cfg, spec, link_units, variations)
    tr = variations.resolve("tr_mean", cfg)
    tables = _build_tables(cfg, sys, tr, backend)
    assign = sspec.arbiter(cfg, tables, chain_spec(cfg.s), backend=backend)
    ideal_ok = _ideal_success(cfg, sys, sspec.policy, tr, backend)
    return link_record(
        cfg, sspec.policy, assign.wl, assign.entry, ideal_ok,
        system=sys if with_system else None,
    )


def aggregate_stats(cfg: ArbitrationConfig, spec: FabricSpec,
                    ev: LinkEval) -> FabricStats:
    """Reduce stacked per-link records to fabric-level ``FabricStats``."""
    n = cfg.grid.n_ch
    f32 = lambda x: x.astype(jnp.float32)
    alg, ideal = ev.alg, ev.ideal
    ltc_both = ev.ltc_ok[:, 0] & ev.ltc_ok[:, 1]
    shift_eq = ev.shift[:, 0] == ev.shift[:, 1]

    if spec.routes:
        link_pair = jnp.asarray(spec.link_pair())
        pair_up = (
            jnp.zeros((spec.n_pairs,), jnp.int32)
            .at[link_pair].add(alg.astype(jnp.int32))
        ) > 0
        usable = ev.lanes > 0
        avail = (
            jnp.zeros((spec.n_pairs, n), jnp.int32)
            .at[link_pair].add((ev.ch_up & usable[:, None]).astype(jnp.int32))
        ) > 0
        hops = spec.route_hops()                      # (R, H) host-side
        valid = jnp.asarray(hops >= 0)
        safe = jnp.asarray(np.clip(hops, 0, None))
        r_up = jnp.all(jnp.where(valid, pair_up[safe], True), axis=1)
        cont_c = jnp.all(
            jnp.where(valid[:, :, None], avail[safe], True), axis=1
        )                                             # (R, N)
        route_up = jnp.mean(f32(r_up))
        route_cont = jnp.mean(f32(jnp.any(cont_c, axis=1)))

        # Degraded-mode scoring: every route evaluated over its alternative
        # set (primary + declared fallbacks), scored by its best survivor.
        # Computed additively — the primary-only metrics above are
        # untouched, so fabrics without fallbacks report
        # route_served == route_up bit for bit.
        a_hops, a_valid = spec.route_alternatives()   # (R, A, H), (R, A)
        av = jnp.asarray(a_valid)
        vh = jnp.asarray(a_hops >= 0)
        sh = jnp.asarray(np.clip(a_hops, 0, None))
        pair_bw = (
            jnp.zeros((spec.n_pairs,), jnp.float32)
            .at[link_pair].max(f32(ev.lanes) / n)
        )                                             # best link per bundle
        a_up = jnp.all(jnp.where(vh, pair_up[sh], True), axis=2)   # (R, A)
        a_cont = jnp.any(
            jnp.all(jnp.where(vh[:, :, :, None], avail[sh], True), axis=2),
            axis=2,
        )                                             # (R, A)
        a_bw = jnp.min(
            jnp.where(vh, pair_bw[sh], jnp.float32(np.inf)), axis=2
        )                                             # (R, A) hop bottleneck
        route_served = jnp.mean(f32(jnp.any(a_up & av, axis=1)))
        route_cont_served = jnp.mean(f32(jnp.any(a_cont & av, axis=1)))
        route_bandwidth = jnp.mean(
            jnp.max(jnp.where(av, a_bw, 0.0), axis=1)
        )
    else:
        route_up = jnp.float32(1.0)
        route_cont = jnp.float32(1.0)
        route_served = jnp.float32(1.0)
        route_cont_served = jnp.float32(1.0)
        route_bandwidth = jnp.float32(1.0)

    return FabricStats(
        link_up=jnp.mean(f32(alg)),
        afp=1.0 - jnp.mean(f32(ideal)),
        cafp=jnp.mean(f32(~alg & ideal)),
        matched=jnp.mean(f32(alg & ltc_both & shift_eq)),
        reconciled=jnp.mean(f32(alg & ltc_both & ~shift_eq)),
        bandwidth=jnp.mean(f32(ev.lanes) / n),
        route_up=route_up,
        route_cont=route_cont,
        route_served=route_served,
        route_cont_served=route_cont_served,
        route_bandwidth=route_bandwidth,
    )


def auto_link_chunk(cfg: ArbitrationConfig, n_links: int,
                    budget: int = _CHUNK_BUDGET) -> int:
    """Largest link-chunk whose T=2*chunk table working set fits ``budget``.

    Uses the same ``scheme_point_bytes`` accounting the sweep engine budgets
    grid chunks with (a chunk of K links is one 2K-trial scheme evaluation),
    so fabric memory cannot drift from the engine's contract.
    """
    if n_links < 1:
        raise ValueError(f"n_links must be >= 1, got {n_links}")
    if scheme_point_bytes(cfg, 2 * n_links) <= budget:
        return n_links
    if scheme_point_bytes(cfg, 2) > budget:
        # Degenerate floor: even a single link overflows the budget (tiny
        # budgets, huge configs).  One link per chunk is the smallest unit
        # the engine can evaluate; the caller pays the overage knowingly
        # rather than the bisection asserting on an invariant that never
        # held ("lo fits").
        return 1
    lo, hi = 1, n_links  # n_links >= 2 here: the full fabric did not fit
    while hi - lo > 1:  # invariant: lo fits, hi does not
        mid = (lo + hi) // 2
        if scheme_point_bytes(cfg, 2 * mid) <= budget:
            lo = mid
        else:
            hi = mid
    return lo


def fabric_stats_impl(
    cfg: ArbitrationConfig,
    units: FabricUnits,
    spec: FabricSpec,
    variations: Variations,
    *,
    scheme: str,
    backend: str | None = None,
    link_chunk: int,
) -> FabricStats:
    """Un-jitted fabric evaluation body: the sweep engine's per-grid-point
    primitive for ``SweepRequest(fabric=...)`` (vmap-safe; link chunking is
    an inner ``chunked_map``, so one grid point's live set is one link
    chunk's tables)."""
    ev = chunked_map(
        partial(_eval_link, cfg, spec, scheme, backend, False),
        units, chunk=link_chunk, broadcast=(variations,), tag="fabric_links",
    )
    return aggregate_stats(cfg, spec, ev)


@partial(
    jax.jit,
    static_argnames=("cfg", "spec", "scheme", "backend", "link_chunk", "mesh"),
)
def _bringup_flat(cfg, spec, units, variations, *, scheme, backend,
                  link_chunk, mesh):
    ev = chunked_map(
        partial(_eval_link, cfg, spec, scheme, backend, True),
        units, chunk=link_chunk, mesh=mesh, broadcast=(variations,),
        tag="bringup_links",
    )
    return ev, aggregate_stats(cfg, spec, ev)


def state_from_assignment(wl, entry) -> ProtocolState:
    """One-shot ``Assignment`` fields -> a protocol-invariant-safe state.

    The protocol engine requires dup-lock freedom (``_line_holder`` assumes
    at most one holder per line), but one-shot schemes can emit duplicate
    locks on failed trials.  Sanitize: per duplicated line the lowest-
    indexed ring keeps the lock, later claimants are starved (their warm
    re-arbitration relocks them red-ward).  Probes start at zero.
    """
    wl = jnp.asarray(wl, jnp.int32)
    entry = jnp.asarray(entry, jnp.int32)
    t, n = wl.shape
    held = wl >= 0
    eq = (wl[:, :, None] == jnp.arange(n)[None, None, :]) & held[:, :, None]
    first_holder = jnp.argmax(eq, axis=1).astype(jnp.int32)      # (T, L)
    mine = jnp.take_along_axis(
        first_holder, jnp.clip(wl, 0, n - 1), axis=1
    )
    keep = held & (mine == jnp.arange(n, dtype=jnp.int32)[None, :])
    lock = jnp.where(keep, wl, -1)
    ent = jnp.where(keep, entry, -1)
    return ProtocolState(
        lock=lock,
        entry=ent,
        cursor=jnp.maximum(ent, 0),
        probes=jnp.zeros((t,), jnp.int32),
    )


@dataclasses.dataclass
class FabricResult:
    """Standalone bring-up output: per-link records + warm-restart state.

    ``ev`` fields are numpy-stacked over links; ``system`` is the flat
    (2*K, N) instantiated batch (row 2k = link k's tx end, 2k+1 rx) and
    ``state`` the matching live, dup-sanitized endpoint lock state —
    together exactly what ``optics.interconnect.rearbitrate`` needs to
    warm-restart the protocol engine instead of re-drawing thermals.
    """

    spec: FabricSpec
    scheme: str
    variations: Variations
    units: FabricUnits
    ev: LinkEval
    stats: FabricStats
    system: SystemBatch
    state: ProtocolState


def bringup(
    cfg: ArbitrationConfig,
    spec: FabricSpec,
    *,
    tr_mean: float | None = None,
    scheme: str = "vtrs_ssm",
    seed: int = 0,
    variations=None,
    backend: str | None = None,
    mesh=None,
    link_chunk: int | None = None,
) -> FabricResult:
    """Arbitrate a whole fabric in one jitted, chunked, mesh-shardable call.

    ``mesh`` (1-D, e.g. ``repro.launch.mesh.make_sweep_mesh()``) splits the
    link-chunk axis over devices with ``shard_map`` — bit-identical to the
    unsharded path.  ``link_chunk`` defaults to the auto budget fit.
    """
    var = as_variations(variations)
    if tr_mean is not None:
        var = var.replace(tr_mean=tr_mean)
    units = make_fabric_units(cfg, spec, seed)
    chunk = link_chunk or auto_link_chunk(cfg, spec.n_links)
    from repro.obs.phase import current_recorder, measured_call

    rec = current_recorder()
    if rec is None:
        ev, stats = _bringup_flat(
            cfg, spec, units, var,
            scheme=scheme, backend=backend, link_chunk=chunk, mesh=mesh,
        )
    else:
        from repro.core.sweep import _CHUNK_BUDGET, scheme_point_bytes

        rec.note(
            "bringup.plan", links=int(spec.n_links), link_chunk=int(chunk),
            n_chunks=-(-int(spec.n_links) // int(chunk)), scheme=scheme,
            per_chunk_bytes=int(scheme_point_bytes(cfg, 2 * chunk)),
            budget=_CHUNK_BUDGET,
        )
        statics = dict(scheme=scheme, backend=backend, link_chunk=chunk,
                       mesh=mesh)
        ev, stats = measured_call(
            "bringup", _bringup_flat, (cfg, spec, units, var), statics,
            dynamic_args=(units, var), budget=_CHUNK_BUDGET,
        )
    k, n = spec.n_links, cfg.grid.n_ch
    system = SystemBatch(
        laser=ev.system.laser.reshape(2 * k, n),
        ring=ev.system.ring.reshape(2 * k, n),
        fsr=ev.system.fsr.reshape(2 * k, n),
        tr_unit=ev.system.tr_unit.reshape(2 * k, n),
    )
    state = state_from_assignment(
        ev.wl.reshape(2 * k, n), ev.entry.reshape(2 * k, n)
    )
    return FabricResult(
        spec=spec, scheme=scheme, variations=var, units=units,
        ev=ev._replace(system=None), stats=stats,
        system=system, state=state,
    )
