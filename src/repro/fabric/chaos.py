"""Fabric-scale fault injection: chaos timelines + warm re-lock at 1000 links.

The temporal x fabric bridge the ROADMAP left open: a ``FabricTimeline``
carries *fabric-scoped* drift and fault events — per-pod thermal ramps,
comb-group-correlated laser wander, link kill/flap, comb-source failure
(every link drawing that comb's light loses its lines together), and ring
death on a chosen endpoint — and ``run_fabric_timeline`` steps every link's
``ProtocolState`` through it with the PR-7 temporal machinery:

1. per step, every link's drifted optics rebuild their search tables
   against the *live* bus (dead lanes/rings/links masked via the tables'
   ``visible`` hook),
2. carried locks revalidate with hysteresis (``protocol.revalidate_state``),
3. *disturbed* links warm-restart the protocol engine (transactional
   make-before-break commits, per-link cold-fallback escalation —
   ``core.temporal.protocol_relock``, the exact escalation the
   single-transceiver timeline runs); undisturbed links keep their carried
   state verbatim and spend nothing,
4. per-step ``FabricStats`` aggregate the re-derived link records,
   including the degraded-mode route metrics (``route_served`` /
   ``route_bandwidth``) that turn a comb failure into a bandwidth floor
   instead of a binary fabric death.

Step 0 is the bring-up: the scheme's own arbiter runs on the step-0 bus
exactly as ``fabric.bringup`` does — with zero drift and no events the
step-0 records are bit-identical to a single-shot ``bringup`` (the
no-fault parity gate; an all-True visibility mask is ``ok & True`` in the
table builder).  Steps >= 1 re-lock with the protocol engine in both modes
(warm resumes carried state; cold re-arbitrates from scratch — the
baseline), matching ``optics.interconnect``'s repair model: the scheme
governs bring-up, the protocol engine governs repair.

The link axis rides ``chunked_map`` inside a ``lax.scan`` over steps, so a
WDM16 1008-link fabric stays inside the 256 MB chunk budget and
mesh-shards; ``SweepRequest(fabric=..., timeline=...)`` maps whole chaos
timelines over variation grids.  Benchmarked in
``benchmarks/fig22_fabric_chaos.py``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import _build_tables, _ideal_success, scheme_spec
from repro.core.grid import ArbitrationConfig
from repro.core.matching import adjacency_bitmask, max_matching
from repro.core.protocol import ProtocolState, cold_state, revalidate_state
from repro.core.reach import reach_matrix
from repro.core.relation import chain_spec
from repro.core.sweep import chunked_map
from repro.core.temporal import _protocol_kwargs, _ramp, protocol_relock
from repro.core.variations import (
    Variations,
    apply_axis_transforms,
    as_variations,
)

from .bringup import (
    FabricStats,
    aggregate_stats,
    auto_link_chunk,
    link_record,
    state_from_assignment,
)
from .sampling import FabricUnits, instantiate_link
from .spec import FabricSpec


class FabricTimeline(NamedTuple):
    """A fabric-scoped drift/event trajectory over S steps and K links.

    Drift offsets are in nm and *absolute* relative to the undrifted
    system (not per-step increments); liveness is per step.  ``disturbed``
    is host-precomputed: a link is disturbed at step s when any of its
    drift or liveness fields changed vs step s-1 (step 0 compares against
    the pristine zero-drift, all-alive fabric) — the warm scan restarts
    only disturbed links.
    """

    ring_drift: jax.Array   # (S, K, 2, N) per-endpoint ring offsets
    laser_drift: jax.Array  # (S, K, N) per-link comb-line offsets
    lane_alive: jax.Array   # (S, K, N) bool: laser line on the link's bus
    ring_alive: jax.Array   # (S, K, 2, N) bool: ring controller powered
    link_alive: jax.Array   # (S, K) bool: link (fiber/port) administratively up
    disturbed: jax.Array    # (S, K) bool: anything above changed this step

    @property
    def n_steps(self) -> int:
        return self.ring_drift.shape[0]

    @property
    def n_links(self) -> int:
        return self.ring_drift.shape[1]

    @property
    def n_ch(self) -> int:
        return self.ring_drift.shape[3]


_EVENT_ARITY = {
    "link_kill": 1, "link_heal": 1, "link_flap": 2,
    "comb_kill": 1, "comb_heal": 1,
    "lane_kill": 2, "lane_heal": 2,
    "ring_kill": 3, "ring_heal": 3,
}


def _check_index(kind: str, what: str, v: int, hi: int) -> int:
    v = int(v)
    if not 0 <= v < hi:
        raise ValueError(
            f"event {kind!r} references {what} {v}, outside 0..{hi - 1} "
            f"for this fabric"
        )
    return v


def make_fabric_timeline(
    spec: FabricSpec,
    n_steps: int,
    n_ch: int,
    *,
    thermal=None,
    pod_thermal=None,
    comb=None,
    events: Sequence[tuple] = (),
) -> FabricTimeline:
    """Deterministic host-side fabric timeline builder.

    thermal:     fabric-wide ring red-shift profile [nm] — scalar (linear
                 ramp to that value), (K, 2) ``(step, value)`` breakpoints,
                 or (S,) — applied to every endpoint (``core.temporal._ramp``
                 forms).
    pod_thermal: mapping pod id -> profile (same forms); each endpoint
                 follows its *own* pod's ramp (link k's end 0 sits in the
                 lower-numbered pod), added on top of ``thermal``.  This is
                 the correlated-across-links knob: every link touching a hot
                 pod drifts together.
    comb:        laser-line wander [nm] — ``(amplitude, period)`` for a
                 sinusoid phase-staggered per comb *group* (links sharing a
                 comb wander identically; distinct groups are offset by
                 1/n_groups of a period), or the ``_ramp`` forms (uniform
                 across groups).
    events:      fault events ``(step, kind, *args)``; liveness changes
                 persist from ``step`` onward and later events override
                 earlier ones (kill then heal is an outage window):

                   ("link_kill", link) / ("link_heal", link)
                   ("link_flap", link, down_steps)  — kill + auto-heal
                   ("comb_kill", group) / ("comb_heal", group) — every link
                       in comb group ``group`` loses/regains ALL laser lines
                   ("lane_kill", link, ch) / ("lane_heal", link, ch)
                   ("ring_kill", link, end, ch) / ("ring_heal", ...)

                 Out-of-range links/groups/endpoints/channels raise
                 ``ValueError`` (events cannot reference lanes absent from
                 the fabric spec).
    """
    if n_steps < 1:
        raise ValueError(f"a timeline needs >= 1 step, got {n_steps}")
    k = spec.n_links
    group = spec.link_group()
    src, dst = spec.link_pods()

    # ------------------------------------------------------------- drift
    base = _ramp(n_steps, thermal)                        # (S,)
    pod_t = np.zeros((n_steps, spec.pods), np.float32)
    for pod, prof in dict(pod_thermal or {}).items():
        pod = int(pod)
        if not 0 <= pod < spec.pods:
            raise ValueError(
                f"pod_thermal names pod {pod}, outside 0..{spec.pods - 1}"
            )
        pod_t[:, pod] = _ramp(n_steps, prof)
    end_pods = np.stack([src, dst], axis=1)               # (K, 2)
    ring_drift = np.broadcast_to(
        (base[:, None, None] + pod_t[:, end_pods])[..., None],
        (n_steps, k, 2, n_ch),
    ).astype(np.float32).copy()

    if isinstance(comb, tuple) and len(comb) == 2 and np.ndim(comb[0]) == 0:
        amp, period = comb
        steps = np.arange(n_steps, dtype=np.float32)
        phase = (
            np.arange(spec.n_groups, dtype=np.float32) / max(1, spec.n_groups)
        )
        g_t = np.float32(amp) * np.sin(
            2.0 * np.pi * (steps[:, None] / np.float32(period) + phase[None, :])
        ).astype(np.float32)                              # (S, G)
    else:
        g_t = np.broadcast_to(
            _ramp(n_steps, comb)[:, None], (n_steps, spec.n_groups)
        )
    laser_drift = np.broadcast_to(
        g_t[:, group][..., None], (n_steps, k, n_ch)
    ).astype(np.float32).copy()

    # ------------------------------------------------------------ events
    lane = np.ones((n_steps, k, n_ch), bool)
    ring = np.ones((n_steps, k, 2, n_ch), bool)
    link = np.ones((n_steps, k), bool)
    for ev in events:
        step, kind, *args = ev
        if kind not in _EVENT_ARITY:
            raise ValueError(
                f"unknown event kind {kind!r}; valid: "
                f"{tuple(_EVENT_ARITY)}"
            )
        if len(args) != _EVENT_ARITY[kind]:
            raise ValueError(
                f"event {kind!r} takes {_EVENT_ARITY[kind]} argument(s), "
                f"got {args}"
            )
        step = int(step)
        if not 0 <= step < n_steps:
            raise ValueError(
                f"event {ev} at step {step}, outside 0..{n_steps - 1}"
            )
        if kind in ("link_kill", "link_heal"):
            l = _check_index(kind, "link", args[0], k)
            link[step:, l] = kind.endswith("heal")
        elif kind == "link_flap":
            l = _check_index(kind, "link", args[0], k)
            down = int(args[1])
            if down < 1:
                raise ValueError(f"link_flap needs down_steps >= 1, got {down}")
            link[step:step + down, l] = False
        elif kind in ("comb_kill", "comb_heal"):
            g = _check_index(kind, "comb group", args[0], spec.n_groups)
            lane[step:, group == g, :] = kind.endswith("heal")
        elif kind in ("lane_kill", "lane_heal"):
            l = _check_index(kind, "link", args[0], k)
            ch = _check_index(kind, "channel", args[1], n_ch)
            lane[step:, l, ch] = kind.endswith("heal")
        else:  # ring_kill / ring_heal
            l = _check_index(kind, "link", args[0], k)
            end = _check_index(kind, "endpoint", args[1], 2)
            ch = _check_index(kind, "channel", args[2], n_ch)
            ring[step:, l, end, ch] = kind.endswith("heal")

    # --------------------------------------------------------- disturbed
    def changed(arr, pristine) -> np.ndarray:
        flat = arr.reshape(n_steps, k, -1)
        prev = np.concatenate(
            [np.full_like(flat[:1], pristine), flat[:-1]], axis=0
        )
        return (flat != prev).any(axis=2)

    disturbed = (
        changed(ring_drift, 0.0) | changed(laser_drift, 0.0)
        | changed(lane, True) | changed(ring, True) | changed(link, True)
    )
    return FabricTimeline(
        ring_drift=jnp.asarray(ring_drift),
        laser_drift=jnp.asarray(laser_drift),
        lane_alive=jnp.asarray(lane),
        ring_alive=jnp.asarray(ring),
        link_alive=jnp.asarray(link),
        disturbed=jnp.asarray(disturbed),
    )


class FabricChaosStats(NamedTuple):
    """Per-step output of one ``run_fabric_timeline`` call.

    ``fabric`` leaves are (S,) scalars-per-step (incl. the degraded-mode
    route metrics); per-link fields are (S, K).  ``probes``/``rounds``
    count only each step's incremental spend (step 0 is bring-up: zero —
    one-shot arbiters do not report probes, and both warm and cold modes
    share it).  ``feasible`` marks links whose live bus still admits a
    complete matching at both ends (dead rings exempt, dead lanes/links
    gone).
    """

    fabric: FabricStats     # (S,) leaves
    wl: jax.Array           # (S, K, 2, N) int32 committed locks per step
    probes: jax.Array       # (S, K) int32, summed over both endpoints
    rounds: jax.Array       # (S, K) int32, max over both endpoints
    locked: jax.Array       # (S, K) int32 locked rings (0..2N)
    broken: jax.Array       # (S, K) int32 locks broken at revalidation
    churn: jax.Array        # (S, K) int32 surviving locks that moved anyway
    feasible: jax.Array     # (S, K) bool
    #: (S, K) int8 ``repro.obs.health`` codes — only with ``health=True``
    #: (``run_fabric_timeline``); None otherwise, so the default pytree
    #: (and every existing consumer) is unchanged.
    health: Any = None


class _LinkStep(NamedTuple):
    """Per-link scalar accounting for one step (stacked to (K,) / (S, K))."""

    probes: jax.Array
    rounds: jax.Array
    locked: jax.Array
    broken: jax.Array
    churn: jax.Array
    feasible: jax.Array


def _drifted_system(cfg, spec, variations, link_units, tlk):
    """Instantiate one link's optics and apply the step's drift offsets
    through the registered variation transforms (the same hooks static
    sweeps use — drift composes additively with any swept drift axis)."""
    sys = instantiate_link(cfg, spec, link_units, variations)
    return apply_axis_transforms(
        sys,
        Variations(thermal_drift=tlk.ring_drift, comb_wander=tlk.laser_drift),
        cfg,
    )


def _visibility(tlk, n: int):
    """(2, N_ring, N_wl) bool: line visible to ring = lane alive & ring
    alive & link alive (a dead link sees an empty bus: all locks break and
    empty tables never spend probes — killed links are not re-locked)."""
    return jnp.broadcast_to(
        tlk.lane_alive[None, None, :]
        & tlk.ring_alive[:, :, None]
        & tlk.link_alive,
        (2, n, n),
    )


def _link_feasible(sys, tr, tlk):
    """Live-bus feasibility of one link: every live ring on BOTH endpoints
    matchable to a distinct live line within TR, and the link itself up."""
    reach = (
        reach_matrix(sys, tr)
        & tlk.lane_alive[None, None, :]
        & tlk.ring_alive[:, :, None]
    )
    match_wl, _ = max_matching(adjacency_bitmask(reach))
    n_live = jnp.sum(tlk.ring_alive.astype(jnp.int32), axis=1)   # (2,)
    end_ok = jnp.sum((match_wl >= 0).astype(jnp.int32), axis=1) >= n_live
    return end_ok[0] & end_ok[1] & tlk.link_alive


def _chaos_bringup_link(cfg, spec, scheme, backend, variations, item):
    """Step-0 bring-up of one link: the scheme's own arbiter on the step-0
    bus.  With zero drift and no events this is ``bringup._eval_link`` bit
    for bit (zero drift offsets add +0.0; the all-True visibility mask is
    ``ok & True`` in the table builder)."""
    link_units, tlk = item
    n = cfg.grid.n_ch
    sspec = scheme_spec(scheme)
    tr = variations.resolve("tr_mean", cfg)
    sys = _drifted_system(cfg, spec, variations, link_units, tlk)
    tables = _build_tables(cfg, sys, tr, backend, visible=_visibility(tlk, n))
    assign = sspec.arbiter(cfg, tables, chain_spec(cfg.s), backend=backend)
    ideal_ok = _ideal_success(cfg, sys, sspec.policy, tr, backend)
    rec = link_record(cfg, sspec.policy, assign.wl, assign.entry, ideal_ok)
    state = state_from_assignment(assign.wl, assign.entry)
    return state, rec, _link_feasible(sys, tr, tlk)


def _chaos_relock_link(cfg, spec, scheme, backend, warm, transactional,
                       patience, hysteresis, variations, item):
    """One step of one link: rebuild tables on the live drifted bus,
    revalidate carried locks, re-lock with the protocol engine.

    Warm mode resumes the carried state and gates on disturbance: an
    undisturbed link's tables are identical to the previous step's, so its
    carried state is already a fixed point — it is kept verbatim with zero
    spend (this is what "warm-restarts only disturbed links" means; it
    also stops the engine from re-seeking a link's permanently starved
    rings every quiet step).  Cold mode re-arbitrates every link from
    scratch each step — the baseline.  Both modes run the protocol engine
    (for one-shot bring-up schemes too: the scheme governs bring-up, the
    engine governs repair, exactly as ``optics.interconnect``).
    """
    link_units, tlk, st = item
    n = cfg.grid.n_ch
    sspec = scheme_spec(scheme)
    kw = _protocol_kwargs(scheme) or {}
    tr = variations.resolve("tr_mean", cfg)
    sys = _drifted_system(cfg, spec, variations, link_units, tlk)
    tables = _build_tables(cfg, sys, tr, backend, visible=_visibility(tlk, n))
    prev_lock = st.lock
    reval, kept = revalidate_state(
        tables, st, tr=tr * sys.tr_unit, hysteresis=hysteresis
    )
    broken_e = (prev_lock >= 0) & (reval.lock < 0)
    cold0 = cold_state(2, n)
    if warm:
        # A link with no surviving locks has nothing warm to resume: its
        # stale red-ward cursors would re-lock a shifted arrangement after
        # a full outage (e.g. comb heal).  Resume survivors, else restart.
        none_kept = ~jnp.any(reval.lock >= 0)
        start = jax.tree_util.tree_map(
            lambda r, c: jnp.where(none_kept, c, r), reval, cold0
        )
    else:
        start = cold0
    start = start._replace(probes=jnp.zeros((2,), jnp.int32))
    new, probes, rounds = protocol_relock(
        tables, chain_spec(cfg.s), start, warm=warm, backend=backend,
        transactional=transactional, patience=patience, kw=kw,
    )
    if warm:
        act = tlk.disturbed | jnp.any(broken_e)
        sel = jax.tree_util.tree_map(
            lambda nw, old: jnp.where(act, nw, old), new, st
        )
        probes = jnp.where(act, probes, 0)
        rounds = jnp.where(act, rounds, 0)
    else:
        sel = new
    ideal_ok = _ideal_success(cfg, sys, sspec.policy, tr, backend)
    rec = link_record(cfg, sspec.policy, sel.lock, sel.entry, ideal_ok)
    per = _LinkStep(
        probes=jnp.sum(probes).astype(jnp.int32),
        rounds=jnp.max(rounds).astype(jnp.int32),
        locked=jnp.sum((sel.lock >= 0).astype(jnp.int32)),
        broken=jnp.sum(broken_e.astype(jnp.int32)),
        churn=jnp.sum((kept & (sel.lock != prev_lock)).astype(jnp.int32)),
        feasible=_link_feasible(sys, tr, tlk),
    )
    return sel, rec, per


def run_fabric_timeline_impl(
    cfg: ArbitrationConfig,
    units: FabricUnits,
    spec: FabricSpec,
    timeline: FabricTimeline,
    variations=None,
    *,
    scheme: str = "vtrs_ssm",
    warm: bool = True,
    transactional: bool = True,
    patience: int | None = 4,
    hysteresis=0.0,
    backend: str | None = None,
    link_chunk: int = 0,
    mesh=None,
    health: bool = False,
) -> tuple[ProtocolState, FabricChaosStats]:
    """Drive every link of a fabric along a chaos timeline.

    Step 0 brings the fabric up with ``scheme``'s arbiter on the step-0
    bus; steps >= 1 are a ``lax.scan`` whose carry is the per-link
    ``ProtocolState`` pytree, each step one ``chunked_map`` over link
    chunks (``link_chunk=0`` auto-fits the 256 MB budget; ``mesh`` shards
    the chunk axis).  Returns ``(final_state, FabricChaosStats)`` with the
    state flattened to the (2K, N) interconnect layout (row 2k = link k's
    tx end).

    health=True additionally fills ``FabricChaosStats.health`` — the
    (S, K) int8 post-mortem matrix of ``repro.obs.health`` codes (down /
    hopeless / degraded / relocking / healthy), folded from the per-step
    aggregates already computed above, so enabling it never changes the
    arbitration outcome (asserted in ``tests/test_obs.py``).
    """
    var = as_variations(variations)
    k, n = spec.n_links, cfg.grid.n_ch
    if timeline.n_links != k or timeline.n_ch != n:
        raise ValueError(
            f"timeline is ({timeline.n_links} links, {timeline.n_ch} ch) "
            f"but the fabric needs ({k}, {n})"
        )
    chunk = link_chunk or auto_link_chunk(cfg, k)
    tree = jax.tree_util

    tl0 = tree.tree_map(lambda a: a[0], timeline)
    st0, ev0, feas0 = chunked_map(
        partial(_chaos_bringup_link, cfg, spec, scheme, backend),
        (units, tl0), chunk=chunk, mesh=mesh, broadcast=(var,),
    )
    stats0 = aggregate_stats(cfg, spec, ev0)
    zeros_k = jnp.zeros((k,), jnp.int32)
    per0 = _LinkStep(
        probes=zeros_k, rounds=zeros_k,
        locked=jnp.sum((st0.lock >= 0).astype(jnp.int32), axis=(1, 2)),
        broken=zeros_k, churn=zeros_k, feasible=feas0,
    )

    def body(st, tl_s):
        st_new, rec, per = chunked_map(
            partial(_chaos_relock_link, cfg, spec, scheme, backend, warm,
                    transactional, patience, hysteresis),
            (units, tl_s, st), chunk=chunk, mesh=mesh, broadcast=(var,),
        )
        return st_new, (aggregate_stats(cfg, spec, rec), rec.wl, per)

    rest = tree.tree_map(lambda a: a[1:], timeline)
    st_f, (stats_r, wl_r, per_r) = jax.lax.scan(body, st0, rest)

    cat = lambda a0, ar: jnp.concatenate([a0[None], ar], axis=0)
    chaos = FabricChaosStats(
        fabric=tree.tree_map(cat, stats0, stats_r),
        wl=cat(ev0.wl, wl_r),
        **tree.tree_map(cat, per0, per_r)._asdict(),
    )
    if health:
        from repro.obs.health import health_codes

        chaos = chaos._replace(health=health_codes(
            chaos.locked, chaos.probes, chaos.feasible,
            timeline.link_alive, n,
        ))
    state = ProtocolState(
        lock=st_f.lock.reshape(2 * k, n),
        entry=st_f.entry.reshape(2 * k, n),
        cursor=st_f.cursor.reshape(2 * k, n),
        probes=st_f.probes.reshape(2 * k),
    )
    return state, chaos


run_fabric_timeline = jax.jit(
    run_fabric_timeline_impl,
    static_argnames=("cfg", "spec", "scheme", "warm", "transactional",
                     "patience", "backend", "link_chunk", "mesh", "health"),
)


def summarize_chaos(cs: FabricChaosStats) -> FabricChaosStats:
    """Reduce per-link fields to link means — the (S,)-leaved form a chaos
    grid point returns under ``SweepRequest(fabric=..., timeline=...)``
    (``wl`` is dropped: per-step lock maps do not aggregate)."""
    mean = lambda a: jnp.mean(a.astype(jnp.float32), axis=1)
    return cs._replace(
        wl=None, health=None,
        probes=mean(cs.probes), rounds=mean(cs.rounds),
        locked=mean(cs.locked), broken=mean(cs.broken),
        churn=mean(cs.churn), feasible=mean(cs.feasible),
    )
