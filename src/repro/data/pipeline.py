"""Deterministic synthetic token pipeline.

Production-shaped: per-host sharding (each host materializes only its slice
of the global batch), seed-split streams, background prefetch, and packing
of variable-length documents into fixed-length training sequences.  Tokens
are synthesized from a stationary n-gram-ish generator so losses decrease
measurably during the example runs (the model has structure to learn).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    mean_doc_len: int = 512
    prefetch: int = 2
    frontend_len: int = 0
    d_model: int = 0          # for frontend embedding synthesis


class _DocSource:
    """Markov-chain document generator: learnable bigram structure."""

    def __init__(self, cfg: DataConfig, stream: int):
        self.cfg = cfg
        self.rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, cfg.host_id, stream])
        )
        v = cfg.vocab
        # sparse row-stochastic transition structure: token t prefers a
        # small deterministic successor set
        self.n_succ = min(8, v)
        base = np.arange(v, dtype=np.int64)
        self.succ = (
            (base[:, None] * 2654435761 + np.arange(self.n_succ)[None, :] * 40503)
            % v
        ).astype(np.int32)

    def next_doc(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.cfg.mean_doc_len)))
        out = np.empty(n, np.int32)
        t = int(self.rng.integers(self.cfg.vocab))
        for i in range(n):
            out[i] = t
            if self.rng.random() < 0.1:  # 10% resets keep entropy > 0
                t = int(self.rng.integers(self.cfg.vocab))
            else:
                t = int(self.succ[t, self.rng.integers(self.n_succ)])
        return out


class TokenPipeline:
    """Packs documents into (host_batch, seq_len+1) windows; yields dicts of
    numpy arrays (tokens, labels [, extra_embeds]) ready for device put."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        self._sources = [
            _DocSource(cfg, stream=i) for i in range(self.host_batch)
        ]
        self._buffers = [np.empty(0, np.int32) for _ in range(self.host_batch)]
        self._q: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(cfg.prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _fill_row(self, i: int, need: int) -> np.ndarray:
        buf = self._buffers[i]
        while buf.size < need:
            buf = np.concatenate([buf, self._sources[i].next_doc()])
        self._buffers[i] = buf[need:]
        return buf[:need]

    def _make_batch(self) -> Dict[str, np.ndarray]:
        L = self.cfg.seq_len
        rows = np.stack([self._fill_row(i, L + 1) for i in range(self.host_batch)])
        out = {"tokens": rows[:, :L].copy(), "labels": rows[:, 1:].copy()}
        if self.cfg.frontend_len:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.cfg.seed, 7, self.cfg.host_id])
            )
            out["extra_embeds"] = rng.normal(
                0, 0.02, (self.host_batch, self.cfg.frontend_len, self.cfg.d_model)
            ).astype(np.float32)
        return out

    def _worker(self):
        while not self._stop.is_set():
            batch = self._make_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.2)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
