"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the 512-placeholder-device
dry-run to control initialization order.
"""
from __future__ import annotations

import jax


def _make_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax exposes them (``jax.sharding.AxisType`` landed after 0.4); older
    versions default every axis to Auto already, so omitting the kwarg is
    behaviorally identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    When more placeholder devices exist than the mesh needs (the 512-device
    dry-run lowering a single-pod mesh), the leading subset is used.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) == n:
        return _make_mesh(shape, axes)
    if len(devs) > n:
        import numpy as np

        return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"need {n} devices for mesh {shape}, have {len(devs)} — run under "
        "dryrun.py (sets --xla_force_host_platform_device_count=512)"
    )


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_sweep_mesh(n_devices: int | None = None):
    """1-D ``("sweep",)`` mesh for device-parallel ``sweep_grid(mesh=...)``.

    Uses the leading ``n_devices`` of whatever exists (all of them by
    default) — real TPUs, or placeholder CPU devices under dryrun.py's
    ``--xla_force_host_platform_device_count=512``.  The sweep engine splits
    its flat chunk axis over this mesh with ``shard_map``; results are
    bit-identical for every mesh size, so the device count is purely a
    throughput knob.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if n > len(devs):
        raise RuntimeError(
            f"need {n} devices for a sweep mesh, have {len(devs)} — run under "
            "dryrun.py (sets --xla_force_host_platform_device_count=512)"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("sweep",))


def data_axes(mesh) -> tuple:
    """Mesh axes that shard the batch (pod + data when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
