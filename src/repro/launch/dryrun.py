import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
with 512 placeholder host devices standing in for 2 pods x 256 TPU v5e chips,
prove the distribution config is coherent (sharding, memory, collectives),
and extract the roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    SHAPES_BY_NAME,
    applicable,
    get_config,
    microbatches_for,
)
from repro.distributed import analysis, hlo_walk, sharding, steps
from repro.distributed.ctx import activation_axes
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw


def input_specs(cfg: ModelConfig, cell, mesh, dp=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, zero device allocation."""
    B, L = cell.global_batch, cell.seq_len
    sh = sharding.batch_shardings(
        cfg, mesh, with_frontend=bool(cfg.frontend_len), batch=B, dp=dp
    )
    i32 = jnp.int32
    if cell.kind == "train":
        text_len = L - (cfg.frontend_len if cfg.frontend_len else 0)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, text_len), i32, sharding=sh["tokens"]),
            "labels": jax.ShapeDtypeStruct((B, text_len), i32, sharding=sh["labels"]),
        }
        if cfg.frontend_len:
            batch["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), jnp.bfloat16,
                sharding=sh["extra_embeds"],
            )
        return batch
    if cell.kind == "prefill":
        text_len = L - (cfg.frontend_len if cfg.frontend_len else 0)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, text_len), i32, sharding=sh["tokens"])
        }
        if cfg.frontend_len:
            batch["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), jnp.bfloat16,
                sharding=sh["extra_embeds"],
            )
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32, sharding=sh["tokens"])}


def _abstract(tree_shapes, shardings):
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        tree_shapes,
        shardings,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_overrides: dict | None = None,
               n_micro_override: int | None = None,
               flat_fsdp: bool = False,
               variant: str = "baseline"):
    """Build + lower + compile one cell.  Returns (record, compiled).

    cfg_overrides / n_micro_override / flat_fsdp parameterize §Perf
    hillclimb variants; the default arguments are the recorded baseline.
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    cell = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    runs, reason = applicable(cfg, cell)
    if not runs:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": reason}, None

    params_sh = sharding.param_shardings(cfg, mesh, flat_fsdp=flat_fsdp)
    params_abs = _abstract(M.param_shapes(cfg), params_sh)
    dp = ("pod", "data") if multi_pod else ("data",)
    # flat_fsdp: params shard over (data, model) with no TP; activations
    # stay batch-sharded over (pod, data) and the residual carry can take
    # the model axis along the sequence (seq_shard_carry in the variant).

    with mesh, activation_axes(mesh, dp=dp):
        if cell.kind == "train":
            n_data = int(np.prod([mesh.shape[a] for a in
                                  (("pod", "data") if multi_pod else ("data",))]))
            n_micro = n_micro_override or microbatches_for(cfg, cell, n_data)
            opt_cfg = adamw.AdamWConfig(moment_dtype=cfg.moment_dtype)
            opt_abs_shapes = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), params_abs)
            opt_sh = sharding.opt_shardings(params_sh, sharding.replicated(mesh))
            opt_abs = _abstract(opt_abs_shapes, opt_sh)
            fn = steps.make_train_step(cfg, opt_cfg, n_micro)
            jfn = jax.jit(fn, donate_argnums=(0, 1))
            lowered = jfn.lower(params_abs, opt_abs, input_specs(cfg, cell, mesh, dp=dp))
            extra = {"n_microbatch": n_micro}
        elif cell.kind == "prefill":
            fn = steps.make_prefill_step(cfg, max_len=cell.seq_len)
            jfn = jax.jit(fn)
            lowered = jfn.lower(params_abs, input_specs(cfg, cell, mesh, dp=dp))
            extra = {}
        else:
            state_shapes = jax.eval_shape(
                lambda: M.init_decode_state(cfg, cell.global_batch, cell.seq_len)
            )
            state_sh = sharding.decode_state_shardings(cfg, mesh, cell.global_batch)
            state_abs = _abstract(state_shapes, state_sh)
            fn = steps.make_decode_step(cfg)
            jfn = jax.jit(fn, donate_argnums=(1,))
            lowered = jfn.lower(
                params_abs, state_abs, input_specs(cfg, cell, mesh, dp=dp)["tokens"]
            )
            extra = {}

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    walked = hlo_walk.analyze(compiled.as_text(), n_dev)
    model_flops = analysis.model_flops_estimate(cfg, cell)
    roof = analysis.roofline(
        walked.flops, walked.bytes, walked.collective_wire_bytes, n_dev, model_flops
    )

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant,
        "status": "ok",
        "n_devices": int(n_dev),
        "compile_s": round(compile_s, 1),
        "params_total": M.count_params(cfg),
        "params_active": M.count_params(cfg, active_only=True),
        "memory": {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "cost_analysis_raw": {
            k: float(v)
            for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals")
        },
        "hlo_walk": {
            "flops": walked.flops,
            "bytes": walked.bytes,
            "while_trips": walked.while_trips,
        },
        "collectives": {
            "ops": walked.per_collective_ops,
            "wire_bytes": {
                k: float(v) for k, v in walked.per_collective_bytes.items()
            },
        },
        "roofline": roof.as_dict(),
        **extra,
    }
    return record, compiled


def bytes_per_device(record) -> float:
    m = record.get("memory", {})
    return m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    cells = (
        [(a, s.name) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape_name in cells:
        for multi in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
            fp = outdir / f"{tag}.json"
            if fp.exists():
                print(f"[dryrun] {tag}: cached")
                continue
            print(f"[dryrun] {tag}: lowering...", flush=True)
            try:
                record, compiled = lower_cell(arch, shape_name, multi)
            except Exception as e:  # a dry-run failure is a bug in our system
                failures += 1
                record = {
                    "arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi else "single",
                    "status": "fail", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                }
                compiled = None
            fp.write_text(json.dumps(record, indent=1))
            if record["status"] == "ok":
                r = record["roofline"]
                print(
                    f"[dryrun] {tag}: OK compile={record['compile_s']}s "
                    f"mem/dev={bytes_per_device(record)/2**30:.2f}GiB "
                    f"terms(s): C={r['compute_s']:.4f} M={r['memory_s']:.4f} "
                    f"X={r['collective_s']:.4f} dom={r['dominant']}",
                    flush=True,
                )
                # memory_analysis is the fits-proof; cost_analysis feeds §Roofline
            elif record["status"] == "skip":
                print(f"[dryrun] {tag}: SKIP ({record['reason'][:60]}...)")
            else:
                print(f"[dryrun] {tag}: FAIL {record['error']}")
            del compiled
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
