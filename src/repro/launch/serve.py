"""Pointer stub: the LM-era serving launcher moved to the legacy quarantine.

The generic-LM request loop that used to live here (``--arch`` configs,
``repro.models`` prefill/decode) is seed scaffolding unrelated to the
wavelength-arbitration reproduction; it now lives at
``examples/legacy_lm/serve_arch_launcher.py`` with the rest of the
quarantined LM stack (see ``examples/legacy_lm/README.md``).

This module is reserved for the ROADMAP "arbitration as a service" item:
a request loop whose units are arbitration evaluations (sweep requests,
fabric bring-ups) rather than LM tokens.
"""
from __future__ import annotations


def main():
    raise SystemExit(
        "repro.launch.serve: the LM serving launcher moved to "
        "examples/legacy_lm/serve_arch_launcher.py (run it directly); "
        "this entry point is reserved for arbitration-as-a-service."
    )


if __name__ == "__main__":
    main()
