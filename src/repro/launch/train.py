"""Training launcher: ``python -m repro.launch.train --arch <id>``.

On real hardware this runs the production mesh; on a host container it
falls back to the reduced same-family smoke config over host devices so the
full stack (pipeline -> sharded step -> checkpointing -> optics fabric) is
exercised end-to-end.
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding, steps
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--production", action="store_true",
                    help="full config on the 16x16 production mesh")
    args = ap.parse_args()

    if args.production:
        cfg = get_config(args.arch)
        mesh = make_production_mesh()
    else:
        cfg = get_smoke(args.arch)
        mesh = make_host_mesh()
    print(f"arch={cfg.name} params={M.count_params(cfg)/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    opt_cfg = adamw.AdamWConfig(
        warmup_steps=max(args.steps // 10, 1),
        decay_steps=args.steps,
        moment_dtype=cfg.moment_dtype,
    )
    params_sh = sharding.param_shardings(cfg, mesh)
    opt_sh = sharding.opt_shardings(params_sh, sharding.replicated(mesh))
    step_fn = jax.jit(
        steps.make_train_step(cfg, opt_cfg, args.microbatch),
        donate_argnums=(0, 1),
    )
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 2, 10),
        ckpt_dir=args.ckpt or tempfile.mkdtemp(prefix=f"repro_{args.arch}_"),
        log_every=max(args.steps // 10, 1),
    )
    trainer = Trainer(cfg, tcfg, opt_cfg, mesh, step_fn, params_sh, opt_sh)
    fabric = trainer.bringup_fabric()
    print(f"optical fabric: {len(fabric.links)} links, "
          f"bw fraction {fabric.bandwidth_fraction:.3f}")

    data = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        frontend_len=cfg.frontend_len, d_model=cfg.d_model,
    ))
    state = trainer.init_state()
    state = trainer.fit(state, iter(data))
    data.close()
    for m in trainer.metrics_log:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['sec_per_step']:.2f}s/step")
    print(f"done at step {state.step}; ckpt={tcfg.ckpt_dir}")


if __name__ == "__main__":
    main()
