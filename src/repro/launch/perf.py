import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: runs named variant ladders on the three chosen
(arch x shape) pairs, recording hypothesis -> change -> before -> after for
EXPERIMENTS.md.  Each variant re-lowers, re-compiles and re-derives the
roofline terms; artifacts land in experiments/perf/.

  PYTHONPATH=src python -m repro.launch.perf --pair moe
  PYTHONPATH=src python -m repro.launch.perf --pair all
"""
import argparse
import json
from pathlib import Path

from repro.launch.dryrun import bytes_per_device, lower_cell

OUT = Path("experiments/perf")

# Each entry: (variant_name, hypothesis, kwargs for lower_cell)
LADDERS = {
    # Worst roofline fraction + most collective-bound: expert-buffer
    # gather/scatter all-gathers the full (E,cap,d) buffers per layer/ub.
    "moe": {
        "arch": "qwen3-moe-235b-a22b",
        "shape": "train_4k",
        "multi_pod": False,
        "variants": [
            (
                "i1_micro4",
                "collective wire scales with microbatch count (per-ub FSDP "
                "gathers + MoE buffer all-gathers); 16->4 ubs should cut the "
                "collective term ~3-4x at ~2-3x activation memory",
                dict(n_micro_override=4),
            ),
            (
                "i2_micro4_a2a",
                "MoE dispatch/return via shard_map all-to-all moves only "
                "routed tokens (T*k*d bytes) instead of all-gathering "
                "(E,cap,d) buffers: predict ~10x lower MoE collective bytes",
                dict(n_micro_override=4, cfg_overrides=dict(moe_impl="a2a")),
            ),
            (
                "i3_micro2_a2a",
                "with a2a the per-ub collective floor is FSDP param gathers; "
                "fewer ubs amortize them further; memory should still fit",
                dict(n_micro_override=2, cfg_overrides=dict(moe_impl="a2a")),
            ),
            (
                "i4_micro8_a2a_cskip",
                "memory term is now co-dominant and attention-score traffic "
                "is half wasted on fully-masked causal tiles; the static "
                "lower-triangle pair scan halves attention flops+bytes, and "
                "8 ubs rebalance the carry memory that micro4 inflated",
                dict(n_micro_override=8,
                     cfg_overrides=dict(moe_impl="a2a", causal_skip=True)),
            ),
        ],
    },
    # Biggest dense model; collective-bound via FSDP gathers x 16 ubs + SP.
    "dense340b": {
        "arch": "nemotron-4-340b",
        "shape": "train_4k",
        "multi_pod": False,
        "variants": [
            (
                "i1_micro4",
                "FSDP all-gathers repeat per microbatch: 16->4 ubs cuts "
                "param-gather wire ~4x; carry memory rises ~4x (seq-sharded "
                "carries keep it within HBM)",
                dict(n_micro_override=4),
            ),
            (
                "i2_micro4_nosp",
                "ablate sequence-parallel carries: SP halves carry memory "
                "but adds h-sized all-gathers around every block; without "
                "SP collective should drop at higher memory",
                dict(n_micro_override=4, cfg_overrides=dict(seq_shard_carry=False)),
            ),
            (
                "i3_micro8_nosp",
                "pick the fit point: no-SP at 8 ubs balances carry memory "
                "vs per-ub gather traffic",
                dict(n_micro_override=8, cfg_overrides=dict(seq_shard_carry=False)),
            ),
            (
                "i4_micro8_nosp_cskip",
                "squared-ReLU 96-layer stack at 4k: attention tiles are "
                "~20% of memory traffic; causal tile skipping halves them",
                dict(n_micro_override=8,
                     cfg_overrides=dict(seq_shard_carry=False, causal_skip=True)),
            ),
            (
                "i5_sp_cskip",
                "no-SP variants beat the bound but blow HBM (carry stash); "
                "keep SP for fitment and take the free causal-skip win — "
                "the shipped configuration (i2-i4 recorded as perf upper "
                "bounds pending sqrt-remat of the layer scan)",
                dict(cfg_overrides=dict(causal_skip=True)),
            ),
            (
                "i6_micro8_nosp_cskip_sqrt",
                "the 96-layer carry stash is what forced SP: a two-level "
                "(12x8) sqrt-remat scan keeps only ~20 boundary carries, "
                "so the fast no-SP sharding should now FIT — predict i4's "
                "bound (~205s, 2.5x fraction) at roughly half the memory",
                dict(n_micro_override=8,
                     cfg_overrides=dict(seq_shard_carry=False,
                                        causal_skip=True, scan_levels=2)),
            ),
        ],
    },
    # Paper-representative: cross-pod DP traffic on arbitrated DWDM links;
    # small model where 16-way TP is pure overhead.
    "crosspod": {
        "arch": "internlm2-1.8b",
        "shape": "train_4k",
        "multi_pod": True,
        "variants": [
            (
                "i1_flat_fsdp",
                "[REFUTED v1: sharding batch over all 512 incl. model axis "
                "replicated activations (256 % 512 != 0) and exploded both "
                "terms] v2: 1.8B params need no TP -> flat FSDP params over "
                "(data x model), batch over (pod x data), carry seq-sharded "
                "over model: removes the 2-all-reduce-per-layer TP tax",
                dict(flat_fsdp=True,
                     cfg_overrides=dict(seq_shard_carry=True)),
            ),
            (
                "i2_flat_fsdp_micro1",
                "per-device batch is 8 sequences at micro=4; grad "
                "accumulation is pure overhead at this scale -> 1 ub "
                "amortizes the FSDP param gathers 4x",
                dict(flat_fsdp=True, n_micro_override=1,
                     cfg_overrides=dict(seq_shard_carry=True)),
            ),
            (
                "i3_flat_fsdp_micro1_dots",
                "small model: full remat recompute is ~25% of compute; "
                "'dots' policy saves matmul outputs (memory is ample) "
                "cutting recompute flops",
                dict(flat_fsdp=True, n_micro_override=1,
                     cfg_overrides=dict(seq_shard_carry=True, remat="dots")),
            ),
            (
                "i4_flat_fsdp_micro1_cskip",
                "with collectives fixed the cell turns memory-bound; "
                "causal tile skipping halves the dominant attention-score "
                "traffic",
                dict(flat_fsdp=True, n_micro_override=1,
                     cfg_overrides=dict(seq_shard_carry=True, remat="dots",
                                        causal_skip=True)),
            ),
        ],
    },
}


def run_ladder(name: str):
    spec = LADDERS[name]
    OUT.mkdir(parents=True, exist_ok=True)
    arch, shape, multi = spec["arch"], spec["shape"], spec["multi_pod"]
    mesh_tag = "multi" if multi else "single"

    # baseline from the dry-run artifacts
    base_fp = Path("experiments/dryrun") / f"{arch}__{shape}__{mesh_tag}.json"
    baseline = json.loads(base_fp.read_text())
    rows = [("baseline", "recorded dry-run baseline", baseline)]

    for vname, hypothesis, kw in spec["variants"]:
        fp = OUT / f"{name}__{vname}.json"
        if fp.exists():
            rec = json.loads(fp.read_text())
        else:
            print(f"[perf:{name}] {vname}: lowering...", flush=True)
            try:
                rec, compiled = lower_cell(
                    arch, shape, multi, variant=vname, **kw
                )
                del compiled
            except Exception as e:
                rec = {"status": "fail", "error": f"{type(e).__name__}: {e}"}
            rec["hypothesis"] = hypothesis
            fp.write_text(json.dumps(rec, indent=1))
        rows.append((vname, hypothesis, rec))

    print(f"\n=== ladder {name}: {arch} x {shape} ({mesh_tag}) ===")
    print(f"{'variant':26s} {'C[s]':>9s} {'M[s]':>9s} {'X[s]':>9s} "
          f"{'bound[s]':>9s} {'frac':>8s} {'mem GiB':>8s}")
    prev_bound = None
    for vname, hyp, rec in rows:
        if rec.get("status") != "ok":
            print(f"{vname:26s} FAILED: {rec.get('error', rec.get('status'))[:60]}")
            continue
        r = rec["roofline"]
        mem = bytes_per_device(rec) / 2**30
        bound = r["step_time_lower_bound_s"]
        delta = "" if prev_bound is None else f"  ({bound/prev_bound:.2f}x)"
        print(
            f"{vname:26s} {r['compute_s']:9.3f} {r['memory_s']:9.3f} "
            f"{r['collective_s']:9.3f} {bound:9.3f} "
            f"{r['roofline_fraction']:8.4f} {mem:8.1f}{delta}"
        )
        prev_bound = bound
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(LADDERS) + ["all"], default="all")
    args = ap.parse_args()
    names = list(LADDERS) if args.pair == "all" else [args.pair]
    for n in names:
        run_ladder(n)


if __name__ == "__main__":
    main()
