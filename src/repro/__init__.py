"""repro: wavelength-arbitrated multi-pod JAX training/serving framework.

Reproduction + extension of Choi & Stojanovic, "Scalable Wavelength
Arbitration for Microring-based DWDM Transceivers".  See DESIGN.md for the
system map and EXPERIMENTS.md for validation/roofline/perf results.
"""
__version__ = "1.0.0"
