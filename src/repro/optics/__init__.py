from .interconnect import (  # noqa: F401
    FabricState,
    LinkHealth,
    bringup,
    expected_failure_rates,
    rearbitrate,
)
