"""Optical interconnect runtime: wavelength arbitration as the first-class
link-initialization feature of the multi-pod fabric (DESIGN.md §2).

Every inter-pod edge of the production mesh is a bundle of microring DWDM
transceivers (paper §II).  Bring-up runs the wavelength-oblivious arbiter
(VT-RS/SSM by default) on every transceiver; outcomes become `LinkHealth`:

  * usable lanes  (zero/dup-locked channels are dead lanes)
  * spectral ordering + the barrel-shift remap cost (LtC) feeding the
    port-remapper config (paper §II-A)
  * effective per-link bandwidth, consumed by the collective scheduler and
    the roofline collective term

Failures do not kill the job: LtC re-arbitration (barrel shift) runs
in-place; persistent lane loss degrades bandwidth and triggers straggler
mitigation instead (runtime/trainer.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ArbitrationConfig,
    classify,
    evaluate_scheme,
    make_units,
    oblivious_arbitrate,
)
from repro.core import ideal
from repro.core.sampling import instantiate

LINK_GBPS_PER_LANE = 6.25  # 50 Gb/s/lane optical -> 6.25 GB/s


@dataclasses.dataclass
class LinkHealth:
    src_pod: int
    dst_pod: int
    transceiver: int
    lanes_total: int
    lanes_up: int
    spectral_shift: int          # LtC barrel shift c (remap cost metric)
    failure: Optional[str]       # None | zero_lock | dup_lock | order_err

    @property
    def bandwidth_gbps(self) -> float:
        return self.lanes_up * LINK_GBPS_PER_LANE

    @property
    def degraded(self) -> bool:
        return self.lanes_up < self.lanes_total


@dataclasses.dataclass
class FabricState:
    links: List[LinkHealth]
    scheme: str
    tr_mean: float

    @property
    def min_link_bandwidth(self) -> float:
        return min(l.bandwidth_gbps for l in self.links) if self.links else 0.0

    @property
    def bandwidth_fraction(self) -> float:
        """Worst-link usable-lane fraction — scales the roofline collective
        term for cross-pod traffic."""
        if not self.links:
            return 1.0
        return min(l.lanes_up / l.lanes_total for l in self.links)

    def degraded_links(self) -> List[LinkHealth]:
        return [l for l in self.links if l.degraded]


def _arbitrate_batch(cfg: ArbitrationConfig, seed: int, n_links: int,
                     tr_mean: float, scheme: str):
    """Run the oblivious arbiter on n_links sampled transceivers at once
    (each link draws an independent laser x ring-row pair)."""
    units = make_units(cfg, seed=seed, n_laser=n_links, n_ring=1)
    # cross product gives n_links trials (one ring row per laser here);
    # re-draw rings per link for full independence
    units2 = make_units(cfg, seed=seed + 1, n_laser=1, n_ring=n_links)
    units = units._replace(u_rlv=units2.u_rlv, u_fsr=units2.u_fsr, u_tr=units2.u_tr)
    sys = instantiate(cfg, units)
    assign = oblivious_arbitrate(cfg, sys, tr_mean, scheme)
    out = classify(assign, jnp.asarray(cfg.s), policy="ltc")
    shift = (assign.wl[:, 0] - jnp.asarray(cfg.s)[0]) % cfg.grid.n_ch
    return out, np.asarray(shift), np.asarray(assign.wl)


def bringup(
    pods: int,
    links_per_pod_pair: int,
    cfg: ArbitrationConfig,
    *,
    tr_mean: float = 8.96,
    scheme: str = "vtrs_ssm",
    seed: int = 0,
) -> FabricState:
    """Arbitrate every inter-pod transceiver; returns fabric health."""
    links: List[LinkHealth] = []
    pairs = [(a, b) for a in range(pods) for b in range(pods) if a < b]
    for pi, (a, b) in enumerate(pairs):
        out, shift, wl = _arbitrate_batch(
            cfg, seed + 101 * pi, links_per_pod_pair, tr_mean, scheme
        )
        succ = np.asarray(out.success)
        zl = np.asarray(out.zero_lock)
        dl = np.asarray(out.dup_lock)
        oe = np.asarray(out.order_err)
        for t in range(links_per_pod_pair):
            if succ[t]:
                lanes_up, fail = cfg.grid.n_ch, None
            else:
                # lanes that did lock a unique line still carry data;
                # order errors cost remap but keep lanes alive.
                lanes = wl[t]
                good = len({int(k) for k in lanes if k >= 0})
                dup_loss = len([k for k in lanes if k >= 0]) - good
                lanes_up = max(0, good - dup_loss)
                fail = (
                    "zero_lock" if zl[t] else
                    "dup_lock" if dl[t] else
                    "order_err" if oe[t] else None
                )
                if fail == "order_err":
                    lanes_up = cfg.grid.n_ch  # crossbar remap, no lane loss
            links.append(
                LinkHealth(
                    src_pod=a, dst_pod=b, transceiver=t,
                    lanes_total=cfg.grid.n_ch, lanes_up=int(lanes_up),
                    spectral_shift=int(shift[t]), failure=fail,
                )
            )
    return FabricState(links=links, scheme=scheme, tr_mean=tr_mean)


def rearbitrate(state: FabricState, cfg: ArbitrationConfig, *, seed: int,
                max_rounds: int = 3) -> Tuple[FabricState, int]:
    """Re-run arbitration on degraded links (fresh thermal state => fresh
    draw).  Returns (new_state, rounds_used)."""
    rounds = 0
    links = list(state.links)
    for r in range(max_rounds):
        degraded = [i for i, l in enumerate(links) if l.degraded]
        if not degraded:
            break
        rounds += 1
        out, shift, wl = _arbitrate_batch(
            cfg, seed + 31 * r, len(degraded), state.tr_mean, state.scheme
        )
        succ = np.asarray(out.success)
        for j, i in enumerate(degraded):
            if succ[j]:
                l = links[i]
                links[i] = dataclasses.replace(
                    l, lanes_up=l.lanes_total, spectral_shift=int(shift[j]),
                    failure=None,
                )
    return FabricState(links=links, scheme=state.scheme, tr_mean=state.tr_mean), rounds


def expected_failure_rates(cfg: ArbitrationConfig, tr_mean: float,
                           scheme: str = "vtrs_ssm", seed: int = 0,
                           n: int = 64) -> Dict[str, float]:
    """Fleet-planning numbers: AFP (policy yield) and CAFP (algorithmic) at
    the deployed operating point — the paper's metrics, evaluated on the
    deployment config."""
    units = make_units(cfg, seed=seed, n_laser=n, n_ring=n)
    r = evaluate_scheme(cfg, units, scheme, tr_mean)
    return {
        "afp": float(r.afp),
        "cafp": float(r.cafp),
        "total_failure": float(r.afp + r.cafp),
    }
