"""Optical interconnect runtime: wavelength arbitration as the first-class
link-initialization feature of the multi-pod fabric (DESIGN.md §2).

Every inter-pod edge of the production mesh is a bundle of microring DWDM
transceivers (paper §II).  This module is now a thin runtime wrapper over
the fabric subsystem (``repro.fabric``): ``bringup`` arbitrates every link
in ONE jitted, link-chunked call (per-link draws genuinely independent —
the old ``seed``/``seed+1`` re-draw splice crossed an n_links-laser batch
with an n_links-ring batch and kept the first n_links of n_links^2 trials,
so every link shared laser sample 0), and outcomes become ``LinkHealth``:

  * usable lanes  (zero/dup-locked channels are dead lanes)
  * spectral ordering + the barrel-shift remap cost (LtC) feeding the
    port-remapper config (paper §II-A)
  * effective per-link bandwidth, consumed by the collective scheduler and
    the roofline collective term

Failures do not kill the job: ``rearbitrate`` *warm-restarts* the protocol
engine from the live lock state carried in the bring-up handle
(``run_protocol(init_state=revalidate_state(...), transactional=True)``,
the PR-7 temporal machinery) — surviving locks are kept, starved rings
re-seek, and a transactional round can only improve a link.  Persistent
lane loss degrades bandwidth and triggers straggler mitigation instead
(runtime/trainer.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArbitrationConfig, classify, evaluate_scheme, make_units
from repro.core.api import _build_tables, scheme_spec
from repro.core.protocol import ProtocolState, revalidate_state, run_protocol
from repro.core.relation import chain_spec
from repro.core.sampling import SystemBatch
from repro.core.ssm import Assignment
from repro.fabric import FabricSpec
from repro.fabric import bringup as fabric_bringup

LINK_GBPS_PER_LANE = 6.25  # 50 Gb/s/lane optical -> 6.25 GB/s


@dataclasses.dataclass
class LinkHealth:
    src_pod: int
    dst_pod: int
    transceiver: int
    lanes_total: int
    lanes_up: int
    spectral_shift: int          # LtC barrel shift c (remap cost metric)
    failure: Optional[str]       # None | zero_lock | dup_lock | order_err

    @property
    def bandwidth_gbps(self) -> float:
        return self.lanes_up * LINK_GBPS_PER_LANE

    @property
    def degraded(self) -> bool:
        return self.lanes_up < self.lanes_total


@dataclasses.dataclass
class FabricHandle:
    """Live physical state carried from bring-up for warm re-arbitration.

    ``system`` holds the instantiated optics (row 2k = link k's tx end,
    2k+1 rx) and ``state`` the dup-sanitized endpoint lock state — enough
    to rebuild search tables and resume the protocol engine without
    re-drawing thermals (re-arbitration happens on the SAME hardware).
    ``link_alive`` (None = all up) marks links whose fiber/port is dead
    (``inject_link_failure``): warm repair masks them out of the rebuilt
    tables, so their locks break and are never re-locked until the mask
    clears.
    """

    spec: FabricSpec
    system: SystemBatch
    state: ProtocolState
    tr_mean: float
    link_alive: Optional[np.ndarray] = None


@dataclasses.dataclass
class FabricState:
    links: List[LinkHealth]
    scheme: str
    tr_mean: float
    handle: Optional[FabricHandle] = None

    @property
    def min_link_bandwidth(self) -> float:
        return min(l.bandwidth_gbps for l in self.links) if self.links else 0.0

    @property
    def bandwidth_fraction(self) -> float:
        """Worst-link usable-lane fraction — scales the roofline collective
        term for cross-pod traffic."""
        if not self.links:
            return 1.0
        return min(l.lanes_up / l.lanes_total for l in self.links)

    def degraded_links(self) -> List[LinkHealth]:
        return [l for l in self.links if l.degraded]


def _link_summaries(cfg: ArbitrationConfig, wl: np.ndarray,
                    policy: str) -> tuple:
    """(K, 2, N) locked lines -> per-link (ok, lanes, shift, failure).

    The same lane accounting as the fabric layer: a lane carries data when
    its ring locked a unique line (every dup costs one extra lane), an
    order error is a crossbar remap with no lane loss, and a link is up
    only when BOTH ends succeed under the scheme's policy.
    """
    n = cfg.grid.n_ch
    s = jnp.asarray(cfg.s)
    k = wl.shape[0]
    flat = jnp.asarray(wl.reshape(2 * k, n))
    asg = Assignment(entry=jnp.zeros_like(flat), wl=flat,
                     delta=jnp.zeros(flat.shape, jnp.float32))
    out = classify(asg, s, policy=policy)
    shift = np.asarray((flat[:, 0] - s[0]) % n).reshape(k, 2)
    succ = np.asarray(out.success).reshape(k, 2)
    zero = np.asarray(out.zero_lock).reshape(k, 2)
    dup = np.asarray(out.dup_lock).reshape(k, 2)
    order = np.asarray(out.order_err).reshape(k, 2)

    locked = wl >= 0
    distinct = np.array([
        [len({int(v) for v in wl[i, e] if v >= 0}) for e in range(2)]
        for i in range(k)
    ])
    end_lanes = np.clip(2 * distinct - locked.sum(axis=2), 0, n)
    link_ok = succ.all(axis=1)
    lanes = np.where(link_ok, n, end_lanes.min(axis=1))
    failure = [
        None if link_ok[i] else
        "zero_lock" if zero[i].any() else
        "dup_lock" if dup[i].any() else
        "order_err" if order[i].any() else None
        for i in range(k)
    ]
    return link_ok, lanes, shift[:, 1], failure


def bringup(
    pods: int,
    links_per_pod_pair: int,
    cfg: ArbitrationConfig,
    *,
    tr_mean: float = 8.96,
    scheme: str = "vtrs_ssm",
    seed: int = 0,
) -> FabricState:
    """Arbitrate every inter-pod transceiver; returns fabric health.

    One fabric-layer call (jitted, link-chunked); per-link comb and ring
    draws are independent (``comb_group="link"`` — the runtime models
    per-link comb sources; couple them via ``repro.fabric`` directly).
    The returned state carries a ``FabricHandle`` so ``rearbitrate`` can
    warm-restart the protocol engine on the same physical draws.
    """
    spec = FabricSpec(pods=pods, links_per_pair=links_per_pod_pair,
                      comb_group="link")
    res = fabric_bringup(cfg, spec, tr_mean=tr_mean, scheme=scheme, seed=seed)
    n = cfg.grid.n_ch
    wl = np.asarray(res.ev.wl)
    _, lanes, shift, failure = _link_summaries(
        cfg, wl, scheme_spec(scheme).policy
    )
    src, dst = spec.link_pods()
    tix = spec.link_in_pair()
    links = [
        LinkHealth(
            src_pod=int(src[k]), dst_pod=int(dst[k]), transceiver=int(tix[k]),
            lanes_total=n, lanes_up=int(lanes[k]),
            spectral_shift=int(shift[k]), failure=failure[k],
        )
        for k in range(spec.n_links)
    ]
    handle = FabricHandle(spec=spec, system=res.system, state=res.state,
                          tr_mean=tr_mean)
    return FabricState(links=links, scheme=scheme, tr_mean=tr_mean,
                       handle=handle)


@partial(jax.jit, static_argnames=("cfg",))
def _warm_repair(cfg: ArbitrationConfig, system: SystemBatch, tr_mean,
                 state: ProtocolState, visible=None):
    """One warm protocol pass on the live fabric state.

    Tables are rebuilt from the stored optics (drift-free here; the
    temporal layer owns drifting tables), carried locks are revalidated
    and re-anchored, and a transactional protocol run repairs starved
    rings — committing per trial only if it strictly improves the lock
    count, so link health is monotone under repair.  ``visible`` ((2K, N)
    bool, None = all) masks dead links' lines out of the rebuilt tables:
    their locks break at revalidation and an empty table never re-locks
    (dead fiber cannot carry light, let alone an arbitration).
    """
    tables = _build_tables(cfg, system, tr_mean, None, visible=visible)
    st, _ = revalidate_state(tables, state)
    return run_protocol(
        tables, chain_spec(cfg.s),
        init_state=st, with_state=True, transactional=True, patience=4,
    )


def inject_link_failure(state: FabricState, links) -> FabricState:
    """Mark links as hard-down (fiber cut / port death) in a handle-carrying
    fabric state.

    The returned state records zero lanes and ``failure="link_down"`` for
    each killed link, and the handle's ``link_alive`` mask makes every
    subsequent ``rearbitrate`` treat their buses as empty — killed links
    are never re-locked, and surviving links repair exactly as before.
    Idempotent; a fresh ``bringup`` (or a healed mask) clears it.
    """
    if state.handle is None:
        raise ValueError("inject_link_failure needs a handle-carrying state "
                         "(bringup output), not a legacy record-only state")
    ids = [int(i) for i in np.atleast_1d(np.asarray(links, np.int64))]
    n_links = len(state.links)
    for i in ids:
        if not 0 <= i < n_links:
            raise ValueError(f"link {i} outside 0..{n_links - 1}")
    alive = (np.ones(n_links, bool) if state.handle.link_alive is None
             else state.handle.link_alive.copy())
    alive[ids] = False
    new_links = list(state.links)
    for i in ids:
        new_links[i] = dataclasses.replace(
            new_links[i], lanes_up=0, failure="link_down")
    handle = dataclasses.replace(state.handle, link_alive=alive)
    return FabricState(links=new_links, scheme=state.scheme,
                       tr_mean=state.tr_mean, handle=handle)


def rearbitrate(state: FabricState, cfg: ArbitrationConfig, *, seed: int = 0,
                max_rounds: int = 3) -> Tuple[FabricState, int]:
    """Warm re-arbitration of degraded links from live lock state.

    Runs the protocol engine with ``init_state=`` the handle's carried
    locks (revalidated against rebuilt tables) instead of a cold re-draw —
    healthy lanes keep their locks (no spectral churn), starved rings
    re-seek with multi-hop augmenting, and transactional commits make
    every round monotone.  Degraded ``LinkHealth`` records are re-derived
    from the post-repair state; rounds stop early once a pass changes
    nothing (the warm repair is deterministic).  Returns
    ``(new_state, rounds_used)``.

    ``seed`` is accepted for API compatibility; the warm path is
    deterministic and only a legacy handle-less state uses it (cold
    re-draw of degraded links, the pre-fabric behaviour).
    """
    if state.handle is None:
        return _cold_rearbitrate(state, cfg, seed=seed, max_rounds=max_rounds)

    handle = state.handle
    links = list(state.links)
    n = cfg.grid.n_ch
    policy = scheme_spec(state.scheme).policy
    proto = handle.state
    rounds = 0
    alive = handle.link_alive
    visible = None
    if alive is not None and not alive.all():
        visible = jnp.asarray(
            np.repeat(alive, 2)[:, None] & np.ones((1, n), bool)
        )
    dead = set() if alive is None else {int(i) for i in np.flatnonzero(~alive)}
    for _ in range(max_rounds):
        degraded = [i for i, l in enumerate(links)
                    if l.degraded and i not in dead]
        if not degraded:
            break
        rounds += 1
        _, proto = _warm_repair(
            cfg, handle.system, handle.tr_mean, proto, visible
        )
        wl = np.asarray(proto.lock).reshape(-1, 2, n)
        _, lanes, shift, failure = _link_summaries(cfg, wl, policy)
        changed = False
        for i in degraded:
            l = links[i]
            new_lanes = max(int(lanes[i]), l.lanes_up)  # monotone guard
            new_fail = failure[i] if new_lanes < l.lanes_total else None
            if (new_lanes, new_fail, int(shift[i])) != (
                    l.lanes_up, l.failure, l.spectral_shift):
                links[i] = dataclasses.replace(
                    l, lanes_up=new_lanes, spectral_shift=int(shift[i]),
                    failure=new_fail,
                )
                changed = True
        if not changed:
            break
    new_handle = dataclasses.replace(handle, state=proto)
    return (
        FabricState(links=links, scheme=state.scheme, tr_mean=state.tr_mean,
                    handle=new_handle),
        rounds,
    )


def _cold_rearbitrate(state: FabricState, cfg: ArbitrationConfig, *,
                      seed: int, max_rounds: int) -> Tuple[FabricState, int]:
    """Legacy path for handle-less states: fresh independent draws for the
    degraded links (delegated to the fabric sampler — a 2-pod bundle of
    exactly the degraded count), committing successes only."""
    rounds = 0
    links = list(state.links)
    policy = scheme_spec(state.scheme).policy
    for r in range(max_rounds):
        degraded = [i for i, l in enumerate(links) if l.degraded]
        if not degraded:
            break
        rounds += 1
        spec = FabricSpec(pods=2, links_per_pair=len(degraded),
                          comb_group="link")
        res = fabric_bringup(cfg, spec, tr_mean=state.tr_mean,
                             scheme=state.scheme, seed=seed + 31 * r)
        ok, _, shift, _ = _link_summaries(
            cfg, np.asarray(res.ev.wl), policy
        )
        for j, i in enumerate(degraded):
            if ok[j]:
                l = links[i]
                links[i] = dataclasses.replace(
                    l, lanes_up=l.lanes_total, spectral_shift=int(shift[j]),
                    failure=None,
                )
    return (
        FabricState(links=links, scheme=state.scheme, tr_mean=state.tr_mean),
        rounds,
    )


def expected_failure_rates(cfg: ArbitrationConfig, tr_mean: float,
                           scheme: str = "vtrs_ssm", seed: int = 0,
                           n: int = 64) -> Dict[str, float]:
    """Fleet-planning numbers: AFP (policy yield) and CAFP (algorithmic) at
    the deployed operating point — the paper's metrics, evaluated on the
    deployment config."""
    units = make_units(cfg, seed=seed, n_laser=n, n_ring=n)
    r = evaluate_scheme(cfg, units, scheme, tr_mean)
    return {
        "afp": float(r.afp),
        "cafp": float(r.cafp),
        "total_failure": float(r.afp + r.cafp),
    }
