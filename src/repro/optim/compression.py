"""Top-k gradient compression with error feedback (DESIGN.md §8).

For cross-pod data parallelism over *degraded* optical links (lanes lost to
arbitration failures, paper Fig. 9(d)(e)), the runtime can trade gradient
fidelity for wire bytes: each step transmits only the top-k fraction of
gradient magnitudes per tensor; the residual accumulates locally (error
feedback, Stich et al. / Lin et al. Deep Gradient Compression) so the
optimizer sees an unbiased long-run signal.

Deterministic shapes (k fixed per tensor) keep the collective schedule
static — the compressed payload is what rides the pod axis; within-pod
reduction stays exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class FeedbackState(NamedTuple):
    residual: Any   # same tree as grads


def init_feedback(grads_shape) -> FeedbackState:
    return FeedbackState(
        residual=jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape
        )
    )


def _topk_mask(x, k_frac: float):
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(k_frac * flat.size))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress(grads, state: FeedbackState, k_frac: float = 0.1
             ) -> Tuple[Any, FeedbackState, dict]:
    """Returns (sparse grads to transmit, new feedback state, stats).

    Transmitted tree has the dense shape with zeros off-support (the
    collective layer packs indices+values; byte accounting uses 2*k of the
    dense payload: values + indices).
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        mask = _topk_mask(g32, k_frac)
        send = g32 * mask
        return send.astype(g.dtype), g32 - send

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    send = tdef.unflatten([o[0] for o in outs])
    resid = tdef.unflatten([o[1] for o in outs])
    density = k_frac
    return send, FeedbackState(residual=resid), {
        "wire_fraction": 2.0 * density,  # values + indices vs dense
    }


def compression_for_bandwidth(bandwidth_fraction: float) -> float:
    """Scheduler policy: pick the top-k fraction so cross-pod gradient
    traffic fits the degraded link budget (identity at full bandwidth)."""
    if bandwidth_fraction >= 0.999:
        return 1.0
    # wire_fraction = 2k must be <= bandwidth_fraction
    return max(0.01, bandwidth_fraction / 2.0)
