"""AdamW with dtype-configurable moments and global-norm clipping.

Hand-rolled (no optax dependency) so moment dtypes, update fusion and
sharding stay fully under framework control: for >=30B-param archs the
moments are bf16 (halving optimizer HBM) with fp32 update math — one of the
distributed-memory tricks recorded in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """One fused AdamW update; returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v), {"grad_norm": gnorm, "lr": lr}
