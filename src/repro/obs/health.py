"""Chaos health matrix: per-step x per-link health codes for fig22 post-mortems.

``run_fabric_timeline(..., health=True)`` folds its per-step per-link
aggregates into one small int8 tensor answering the post-mortem question
"what was every link's condition at every step?" — rendered by
``repro.obs.report`` as an ASCII timeline (steps down, links across).

The code ladder is ordered worst-first so a glance finds the incident:

  0 down       link administratively dead (killed fiber/port)
  1 hopeless   alive but the live bus admits no complete matching
  2 degraded   feasible yet short of a full 2N lock set
  3 relocking  fully locked, but this step spent probes getting there
               (warm restart after a disturbance)
  4 healthy    fully locked, zero spend — carried state verbatim

Pure ``jnp`` on already-computed stats: enabling it never changes the
arbitration outcome (asserted in ``tests/test_obs.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["HEALTH_CODES", "HEALTH_GLYPHS", "health_codes",
           "health_matrix_summary"]

#: code -> name; the order is the on-tensor integer encoding (worst first).
HEALTH_CODES = ("down", "hopeless", "degraded", "relocking", "healthy")

#: code -> single char for the report's ASCII timeline.
HEALTH_GLYPHS = "x!~+#"


def health_codes(locked, probes, feasible, link_alive, n_ch: int):
    """Fold per-link step aggregates into int8 health codes.

    locked:     (..., K) locked rings per link (0..2N)
    probes:     (..., K) this step's incremental probe spend
    feasible:   (..., K) bool, live bus admits a complete matching
    link_alive: (..., K) bool, link administratively up
    """
    full = locked >= 2 * int(n_ch)
    code = jnp.where(probes > 0, jnp.int8(3), jnp.int8(4))   # relocking/healthy
    code = jnp.where(~full, jnp.int8(2), code)               # degraded
    code = jnp.where(~feasible, jnp.int8(1), code)           # hopeless
    code = jnp.where(~link_alive, jnp.int8(0), code)         # down
    return code


def health_matrix_summary(health) -> dict:
    """Host-side aggregate of an (S, K) health tensor (manifest payload)."""
    h = np.asarray(health)
    s, k = h.shape
    per_code = {
        name: int((h == code).sum()) for code, name in enumerate(HEALTH_CODES)
    }
    worst_step = int(np.argmin(h.min(axis=1))) if s else 0
    return {
        "steps": s,
        "links": k,
        "by_code": per_code,
        "worst_step": worst_step,
        "healthy_frac": float((h == 4).mean()) if h.size else 1.0,
    }
