"""Post-hoc failure taxonomy over flight-recorder traces.

``classify_trials`` turns one ``run_protocol`` outcome — final lock state,
table occupancy, per-kind event counts, honest round counts — into a
per-trial failure code.  The vocabulary mirrors how arbitration actually
dies (fig19's mid-TR residuals, fig22's unhealed links):

  starvation   a ring ran out of visible lines and nothing it could do
               (no displacement activity) would have freed one
  storm        heavy displacement/surrender churn: lines exist but the
               oblivious controllers keep stealing them from each other
  livelock     the engine sticky-halted early (fixed point or plateau)
               *while* displacement was active — the hole walks a cycle
  hopeless     the trial was never winnable: the live bus admits no
               complete matching (or every starved ring's table is empty)
  locked       not a failure — the trial completed

Precedence (hopeless > livelock > storm > starvation) makes the classes
exhaustive and mutually exclusive: every trial gets exactly one code and
``unknown`` cannot occur by construction — the acceptance gate for fig19's
WDM16 seq_retry residuals asserts exactly that.

``explain_residuals`` is the fig19 driver: per TR point it finds the
trials a one-shot scheme (default ``seq_retry``) loses but the ideal LtA
arbiter wins, re-runs them through the traced protocol engine at the
scheme's displacement depth, and classifies every residual from the trace
alone.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.obs.trace import EV_DISPLACE, EV_SURRENDER

ST_STARVATION = 0
ST_STORM = 1
ST_LIVELOCK = 2
ST_HOPELESS = 3
ST_UNKNOWN = 4  # reserved: classify_trials never emits it
ST_LOCKED = 5

#: code -> label; order is the integer encoding.
TAXONOMY = ("starvation", "storm", "livelock", "hopeless", "unknown",
            "locked")

__all__ = [
    "ST_STARVATION", "ST_STORM", "ST_LIVELOCK", "ST_HOPELESS",
    "ST_UNKNOWN", "ST_LOCKED", "TAXONOMY",
    "classify_trials", "taxonomy_histogram", "explain_residuals",
]


def classify_trials(lock, n_valid, counts, worked, *, rounds: int,
                    feasible=None, storm_factor: int = 2):
    """Per-trial failure codes (host or traced; pure ``jnp``).

    lock:     (T, N) final lock state (< 0 = starved)
    n_valid:  (T, N) search-table occupancy
    counts:   (T, len(EVENT_KINDS)) per-kind totals from a ``TraceBuffer``
              (wraparound-immune, so long trials classify exactly)
    worked:   (T,) honest executed-round count (``ProtocolStats.worked``)
    rounds:   the static round bound the run used
    feasible: optional (T,) bool — ideal feasibility; when given it defines
              ``hopeless`` exactly, otherwise the all-tables-empty proxy is
              used (sound: an empty-table starved ring can never lock)
    storm_factor: displacement activity >= factor * N reads as a storm
    """
    t, n = lock.shape
    lock = jnp.asarray(lock)
    complete = jnp.all(lock >= 0, axis=1)
    starved_dead = (lock < 0) & (jnp.asarray(n_valid) <= 0)
    dead_end = jnp.all(jnp.where(lock < 0, starved_dead, True), axis=1)
    if feasible is not None:
        hopeless = ~jnp.asarray(feasible)
    else:
        hopeless = dead_end
    counts = jnp.asarray(counts)
    activity = counts[:, EV_DISPLACE] + counts[:, EV_SURRENDER]
    early = jnp.asarray(worked) < rounds
    code = jnp.where(
        (activity >= storm_factor * n), jnp.int8(ST_STORM),
        jnp.int8(ST_STARVATION),
    )
    code = jnp.where(early & (activity > 0), jnp.int8(ST_LIVELOCK), code)
    code = jnp.where(hopeless, jnp.int8(ST_HOPELESS), code)
    return jnp.where(complete, jnp.int8(ST_LOCKED), code)


def taxonomy_histogram(codes) -> dict:
    """Host-side {label: count} over a code array (manifest payload)."""
    c = np.asarray(codes)
    return {label: int((c == i).sum()) for i, label in enumerate(TAXONOMY)}


def explain_residuals(
    cfg,
    units,
    tr_values,
    *,
    scheme: str = "seq_retry",
    policy: str = "lta",
    depth: int = 1,
    n_rounds: int | None = None,
    trace_cap: int = 128,
    storm_factor: int = 2,
    backend: str | None = None,
) -> dict:
    """Classify every residual trial of a one-shot scheme from traces alone.

    Per TR point: run ``scheme`` and the ideal ``policy`` arbiter; a
    *residual* trial is one the scheme loses while the ideal wins (the
    fig19 CAFP numerator).  The traced protocol engine then re-arbitrates
    the same tables at displacement depth ``depth`` and every residual is
    classified.  A residual the deeper engine *recovers* (code ``locked``)
    is remapped from its trace: displacement activity on the recovery path
    means the one-shot scheme lost a line it needed someone to surrender
    (``storm``); a quiet recovery means it simply stopped re-searching too
    early (``starvation``).  Either way the code set stays closed — the
    returned ``unknown`` count is structurally zero.
    """
    from repro.core.api import _build_tables, _ideal_success, scheme_spec
    from repro.core.outcomes import classify
    from repro.core.protocol import default_rounds, run_protocol
    from repro.core.relation import chain_spec
    from repro.core.sampling import instantiate
    from repro.core.variations import Variations

    sspec = scheme_spec(scheme)
    spec = chain_spec(cfg.s)
    n = cfg.grid.n_ch
    rounds = default_rounds(n) if n_rounds is None else int(n_rounds)
    s_arr = jnp.asarray(cfg.s)

    points: list[dict] = []
    total = np.zeros(len(TAXONOMY), np.int64)
    for tr in np.asarray(tr_values, np.float32):
        tr = float(tr)
        sys = instantiate(cfg, units, Variations())
        tables = _build_tables(cfg, sys, tr, backend)
        asg = sspec.arbiter(cfg, tables, spec, backend=backend)
        scheme_ok = classify(asg, s_arr, policy=policy).success
        ideal_ok = _ideal_success(cfg, sys, policy, tr, backend)
        residual = np.asarray(~scheme_ok & ideal_ok)

        _, stats, state, buf = run_protocol(
            tables, spec, depth=depth, n_rounds=rounds, backend=backend,
            with_stats=True, with_state=True, trace=trace_cap,
        )
        codes = np.asarray(classify_trials(
            state.lock, tables.n_valid, buf.counts, stats.worked,
            rounds=rounds, feasible=ideal_ok, storm_factor=storm_factor,
        ))
        activity = np.asarray(
            buf.counts[:, EV_DISPLACE] + buf.counts[:, EV_SURRENDER]
        )
        recovered = residual & (codes == ST_LOCKED)
        codes = np.where(
            recovered & (activity > 0), ST_STORM,
            np.where(recovered, ST_STARVATION, codes),
        ).astype(np.int8)

        res_codes = codes[residual]
        hist = taxonomy_histogram(res_codes)
        for i in range(len(TAXONOMY)):
            total[i] += int((res_codes == i).sum())
        points.append({
            "tr_mean": round(tr, 4),
            "residual_trials": int(residual.sum()),
            "codes": res_codes.tolist(),
            "trial_index": np.nonzero(residual)[0].tolist(),
            "histogram": {k: v for k, v in hist.items() if v},
        })

    histogram = {label: int(total[i]) for i, label in enumerate(TAXONOMY)}
    return {
        "scheme": scheme,
        "policy": policy,
        "depth": depth,
        "rounds": rounds,
        "trace_cap": trace_cap,
        "points": points,
        "residual_total": int(sum(p["residual_trials"] for p in points)),
        "histogram": {k: v for k, v in histogram.items() if v},
        "unknown": histogram["unknown"],
    }
