"""End-to-end obs smoke: ``python -m repro.obs.smoke`` (the ``make ci`` gate).

One tiny WDM8 pass through every instrument: a trace-enabled protocol run
with taxonomy, a recorded sweep (phase spans + compiled-memory watermark),
a chaos timeline with the health matrix — all written to a run manifest and
rendered back through ``repro.obs.report``.  Fails loudly (nonzero exit) if
any instrument changes an arbitration outcome or the render chokes.
"""
from __future__ import annotations

import sys
import tempfile

import numpy as np


def main() -> int:
    import jax.numpy as jnp

    from repro.configs.fabric import FABRIC_TINY
    from repro.configs.wdm import WDM8_G200
    from repro.core import SweepRequest, make_units, sweep
    from repro.core.protocol import default_rounds, run_protocol
    from repro.core.relation import chain_spec
    from repro.core.sampling import instantiate
    from repro.core.search_table import build_search_tables
    from repro.fabric import make_fabric_timeline, run_fabric_timeline
    from repro.fabric.sampling import make_fabric_units
    from repro.obs.manifest import RunManifest
    from repro.obs.phase import PhaseRecorder, use_recorder
    from repro.obs.report import render_report
    from repro.obs.taxonomy import classify_trials, taxonomy_histogram
    from repro.obs.trace import trace_summary

    cfg = WDM8_G200
    n = cfg.grid.n_ch
    with tempfile.TemporaryDirectory() as tmp:
        manifest = RunManifest.create(tmp, label="obs-smoke")
        with manifest:
            # 1) trace-enabled protocol run + invariance + taxonomy
            units = make_units(cfg, seed=7, n_laser=4, n_ring=6)
            sys_b = instantiate(cfg, units)
            tables = build_search_tables(
                sys_b, 3.2, max_alias=cfg.max_fsr_alias
            )
            spec = chain_spec(cfg.s)
            _, stats0 = run_protocol(tables, spec, with_stats=True)
            _, stats1, state, buf = run_protocol(
                tables, spec, with_stats=True, with_state=True, trace=64
            )
            if not np.array_equal(np.asarray(stats0.probes),
                                  np.asarray(stats1.probes)):
                print("FAIL: tracing changed probe counts", file=sys.stderr)
                return 1
            codes = classify_trials(
                state.lock, tables.n_valid, buf.counts, stats1.worked,
                rounds=default_rounds(n),
            )
            manifest.record_trace(
                buf, scope="wdm8-protocol",
                taxonomy={"scheme": "protocol_lta",
                          "residual_total": int((np.asarray(codes) != 5).sum()),
                          "histogram": taxonomy_histogram(codes),
                          "unknown": taxonomy_histogram(codes)["unknown"]},
            )
            summ = trace_summary(buf)

            # 2) recorded sweep: spans + chunk plan + memory watermark
            rec = PhaseRecorder(measure_memory=True)
            with use_recorder(rec):
                res = sweep(SweepRequest(
                    cfg=cfg, units=units, scheme="seq_retry",
                    axes={"tr_mean": np.linspace(1.0, 6.0, 4,
                                                 dtype=np.float32)},
                ))
            bare = sweep(SweepRequest(
                cfg=cfg, units=units, scheme="seq_retry",
                axes={"tr_mean": np.linspace(1.0, 6.0, 4, dtype=np.float32)},
            ))
            if not np.array_equal(np.asarray(res.data.cafp),
                                  np.asarray(bare.data.cafp)):
                print("FAIL: recorder changed sweep grid", file=sys.stderr)
                return 1
            if not rec.spans:
                print("FAIL: recorded sweep produced no spans",
                      file=sys.stderr)
                return 1
            manifest.record_phases(rec, scope="wdm8-sweep")

            # 3) chaos health matrix
            fspec = FABRIC_TINY
            funits = make_fabric_units(cfg, fspec, 0)
            tl = make_fabric_timeline(
                fspec, 3, n, thermal=0.15, events=[(1, "link_kill", 0)]
            )
            _, cs = run_fabric_timeline(
                cfg, funits, fspec, tl, health=True
            )
            manifest.record_health(cs.health, scope="fabric-tiny")

        report = render_report(manifest.path)
        print(report)
        ok = ("trace [wdm8-protocol]" in report
              and "phases [wdm8-sweep]" in report
              and "health [fabric-tiny]" in report)
        if not ok:
            print("FAIL: report missing a section", file=sys.stderr)
            return 1
        print(f"obs smoke OK: {summ['events_total']} events, "
              f"{len(rec.spans)} spans, "
              f"{np.asarray(cs.health).shape} health matrix")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
