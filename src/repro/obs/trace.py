"""Protocol flight recorder: a fixed-size per-trial event ring buffer.

The protocol engine (``repro.core.protocol``) reports aggregate outcomes —
``ProtocolStats`` says *how many* probes a trial spent, never *which ring
probed what, when, and why it lost*.  The flight recorder closes that gap:
``run_protocol(..., trace=cap)`` threads a ``TraceBuffer`` through the
engine's ``lax.while_loop`` and every phase appends typed events

    (round, ring, kind, entry)    kind in EVENT_KINDS

into a per-trial ring of capacity ``cap``.  Everything is shape-static and
vmap/jit-safe: appends are conditional scatters gated on a per-trial
``fire`` mask, so the recorder composes with the engine's batching exactly
like the state it observes.  Tracing is *off by default* and the disabled
path is the engine's legacy jaxpr, bit for bit (asserted in
``tests/test_obs.py``).

Ring semantics: the write head is ``n % cap`` (``n`` counts every fired
event, so ``n > cap`` means the oldest events were overwritten — the most
recent ``cap`` always survive).  Per-kind totals in ``counts`` are *not*
subject to wraparound, which is what keeps the failure taxonomy
(``repro.obs.taxonomy``) exact on long-running trials.

Event vocabulary (one entry per protocol transaction):

  probe      a starved ring re-searched the masked bus (entry = its cursor)
  lock       a ring captured a line (entry = the locked table entry)
  displace   a donor relocked red-ward to free its line (entry = new entry)
  surrender  a donor gave up its line and became a seeker (entry = old)
  release    a starved ring reset its tuner sweep (entry = old cursor)
  halt       the trial sticky-halted — fixed point or plateau (ring = -1)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

EV_PROBE = 0
EV_LOCK = 1
EV_DISPLACE = 2
EV_SURRENDER = 3
EV_RELEASE = 4
EV_HALT = 5

#: kind code -> name; the order is the on-buffer integer encoding.
EVENT_KINDS = ("probe", "lock", "displace", "surrender", "release", "halt")

#: columns of one ``TraceBuffer.ev`` row.
EVENT_FIELDS = ("round", "ring", "kind", "entry")


class TraceBuffer(NamedTuple):
    """Per-trial event ring (a pytree: carried through ``lax.while_loop``).

    ``ev`` rows are valid only below ``min(n, cap)``; ``counts`` accumulate
    per-kind totals independent of ring wraparound.
    """

    ev: jax.Array      # (T, cap, 4) int32 [round, ring, kind, entry]
    n: jax.Array       # (T,) int32 total events fired (may exceed cap)
    counts: jax.Array  # (T, len(EVENT_KINDS)) int32 per-kind totals


def trace_buffer(n_trials: int, cap: int) -> TraceBuffer:
    """An empty recorder for ``n_trials`` trials of ring capacity ``cap``."""
    if cap < 1:
        raise ValueError(f"trace capacity must be >= 1, got {cap}")
    return TraceBuffer(
        ev=jnp.full((n_trials, cap, 4), -1, jnp.int32),
        n=jnp.zeros((n_trials,), jnp.int32),
        counts=jnp.zeros((n_trials, len(EVENT_KINDS)), jnp.int32),
    )


def trace_append(buf: TraceBuffer, fire, rnd, ring, kind: int, entry
                 ) -> TraceBuffer:
    """Conditionally append one event per trial.

    fire:  (T,) bool — trials that actually record this event;
    rnd:   scalar or (T,) round index;
    ring:  scalar or (T,) acting ring (-1 for trial-level events);
    kind:  static Python int from the EV_* vocabulary;
    entry: scalar or (T,) table-entry payload.

    One conditional scatter + two masked adds — cheap enough to sit inside
    the engine's fori_loops without changing their structure.
    """
    t, cap, _ = buf.ev.shape
    rows = jnp.arange(t)
    fire = fire.astype(bool)
    rec = jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(rnd, jnp.int32), (t,)),
            jnp.broadcast_to(jnp.asarray(ring, jnp.int32), (t,)),
            jnp.full((t,), kind, jnp.int32),
            jnp.broadcast_to(jnp.asarray(entry, jnp.int32), (t,)),
        ],
        axis=1,
    )                                                    # (T, 4)
    idx = buf.n % cap
    old = buf.ev[rows, idx]
    ev = buf.ev.at[rows, idx].set(jnp.where(fire[:, None], rec, old))
    return TraceBuffer(
        ev=ev,
        n=buf.n + fire.astype(jnp.int32),
        counts=buf.counts.at[:, kind].add(fire.astype(jnp.int32)),
    )


def merge_traces(select, a: TraceBuffer, b: TraceBuffer) -> TraceBuffer:
    """Per-trial select: trial i takes ``a``'s trace where ``select[i]``.

    The warm/cold escalation of ``core.temporal.protocol_relock`` merges
    states with exactly this pattern; the recorder follows its state.
    """
    t = a.n.shape[0]
    pick = lambda x, y: jnp.where(
        select.reshape((t,) + (1,) * (y.ndim - 1)), x, y
    )
    return jax.tree_util.tree_map(pick, a, b)


def trace_events(buf: TraceBuffer, trial: int | None = None):
    """Host-side decode: per-trial event arrays, oldest -> newest.

    Returns a list of (k, 4) int32 numpy arrays (columns = EVENT_FIELDS),
    or a single array when ``trial`` is given.  Wrapped rings are unrolled
    so row order is chronological; overwritten events are gone (``n`` vs
    ``cap`` tells how many).
    """
    ev = np.asarray(buf.ev)
    n = np.asarray(buf.n)
    cap = ev.shape[1]

    def one(i: int) -> np.ndarray:
        k = int(n[i])
        if k <= cap:
            return ev[i, :k]
        head = k % cap  # oldest surviving event sits at the write head
        return np.concatenate([ev[i, head:], ev[i, :head]], axis=0)

    if trial is not None:
        return one(int(trial))
    return [one(i) for i in range(ev.shape[0])]


def trace_summary(buf: TraceBuffer) -> dict:
    """Aggregate host-side view of a recorder (manifest/report payload)."""
    n = np.asarray(buf.n)
    counts = np.asarray(buf.counts)
    cap = int(buf.ev.shape[1])
    return {
        "trials": int(n.shape[0]),
        "capacity": cap,
        "events_total": int(n.sum()),
        "events_max_trial": int(n.max()) if n.size else 0,
        "overflowed_trials": int((n > cap).sum()),
        "by_kind": {
            kind: int(counts[:, i].sum())
            for i, kind in enumerate(EVENT_KINDS)
        },
    }


def format_events(events: np.ndarray, limit: int | None = None) -> str:
    """Render one trial's decoded events as aligned text lines."""
    rows = events if limit is None else events[-limit:]
    lines = []
    for rnd, ring, kind, entry in np.asarray(rows):
        name = EVENT_KINDS[int(kind)] if 0 <= kind < len(EVENT_KINDS) else "?"
        lines.append(
            f"  round {int(rnd):3d}  ring {int(ring):3d}  "
            f"{name:<9s} entry {int(entry)}"
        )
    if limit is not None and len(events) > limit:
        lines.insert(0, f"  ... ({len(events) - limit} earlier events)")
    return "\n".join(lines)
