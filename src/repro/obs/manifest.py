"""JSONL run manifests: one append-only file per instrumented run.

A ``RunManifest`` is the durable side of the obs layer: every line is one
self-describing JSON record ``{"kind": ..., "ts": ..., **fields}``, flushed
as written so a killed run (the SIGALRM story in ``benchmarks/run.py``)
still leaves everything up to the interruption on disk.  Kinds in use:

  meta           run header (argv, label, free-form fields)
  phases         a ``PhaseRecorder`` dump: spans, notes, aggregates
  trace          a flight-recorder summary (+ optional taxonomy histogram)
  health         a chaos health-matrix summary
  bench_record   one benchmark JSON record (fig name + derived fields)

``python -m repro.obs.report`` renders the newest manifest (or a given
path) as a terminal report.  Manifests default into ``.obs/`` under the
repo root — scratch output, git-ignored.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Iterator

__all__ = ["RunManifest", "latest_manifest", "read_manifest", "DEFAULT_DIR"]

DEFAULT_DIR = ".obs"


def _jsonable(v):
    import numpy as np

    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    return str(v)


class RunManifest:
    """Append-only JSONL writer for one run."""

    def __init__(self, path: str, *, label: str = "", **meta):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "a")
        self.write("meta", label=label, pid=os.getpid(), **meta)

    @classmethod
    def create(cls, directory: str = DEFAULT_DIR, *, label: str = "run",
               **meta) -> "RunManifest":
        """A fresh timestamped manifest under ``directory``."""
        stamp = time.strftime("%Y%m%d-%H%M%S")
        path = os.path.join(directory, f"{stamp}-{label}-{os.getpid()}.jsonl")
        return cls(path, label=label, **meta)

    # -- core -------------------------------------------------------------
    def write(self, kind: str, **fields) -> None:
        rec = {"kind": kind, "ts": round(time.time(), 3), **fields}
        self._fh.write(json.dumps(rec, default=_jsonable) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "RunManifest":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- typed helpers ----------------------------------------------------
    def record_phases(self, recorder, *, scope: str = "") -> None:
        """Dump a ``repro.obs.phase.PhaseRecorder``."""
        self.write(
            "phases", scope=scope,
            spans=[{"name": s.name, "kind": s.kind, "ms": round(s.ms, 3),
                    **({"extra": s.extra} if s.extra else {})}
                   for s in recorder.spans],
            notes=recorder.notes,
            by_phase=recorder.phase_fields(),
        )

    def record_trace(self, buf, *, scope: str = "", taxonomy=None) -> None:
        """Dump a flight-recorder summary (+ optional taxonomy result)."""
        from repro.obs.trace import trace_summary

        fields: dict[str, Any] = {"summary": trace_summary(buf)}
        if taxonomy is not None:
            fields["taxonomy"] = taxonomy
        self.write("trace", scope=scope, **fields)

    def record_health(self, health, *, scope: str = "") -> None:
        """Dump a chaos health matrix: summary + the full (S, K) codes."""
        import numpy as np

        from repro.obs.health import health_matrix_summary

        self.write(
            "health", scope=scope,
            summary=health_matrix_summary(health),
            codes=np.asarray(health).tolist(),
        )

    def record_bench(self, record: dict) -> None:
        """Mirror one benchmark JSON record into the manifest."""
        self.write("bench_record", record=record)


def latest_manifest(directory: str = DEFAULT_DIR) -> str | None:
    """Newest ``*.jsonl`` under ``directory`` (None when empty/missing)."""
    try:
        names = [n for n in os.listdir(directory) if n.endswith(".jsonl")]
    except FileNotFoundError:
        return None
    if not names:
        return None
    paths = [os.path.join(directory, n) for n in names]
    return max(paths, key=os.path.getmtime)


def read_manifest(path: str) -> Iterator[dict]:
    """Yield the records of a manifest (corrupt tail lines are skipped —
    a SIGKILL mid-write must not take the readable prefix with it)."""
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                continue
