"""Terminal report over a run manifest: ``python -m repro.obs.report``.

Renders the newest manifest in ``.obs/`` (or an explicit path) as plain
text: run header, per-phase timing breakdown (compile vs execute), memory
watermarks vs the chunk budget, flight-recorder summaries with taxonomy
histograms, ASCII chaos health timelines, and the BENCH record trajectory.
Pure stdlib + the manifest reader — safe to run anywhere the repo runs.
"""
from __future__ import annotations

import sys

from repro.obs.health import HEALTH_CODES, HEALTH_GLYPHS
from repro.obs.manifest import DEFAULT_DIR, latest_manifest, read_manifest

__all__ = ["render_report", "main"]


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _phase_section(rec: dict, out: list) -> None:
    scope = rec.get("scope") or "(run)"
    by_phase = rec.get("by_phase") or {}
    if by_phase:
        out.append(f"  phases [{scope}]")
        width = max(len(n) for n in by_phase)
        for name, slot in sorted(
            by_phase.items(), key=lambda kv: -kv[1]["ms"]
        ):
            out.append(
                f"    {name:<{width}}  {slot['ms']:>10.1f} ms"
                f"  {slot['kind']:<8}x{slot['count']}"
            )
    for note in rec.get("notes") or []:
        name = note.get("name", "")
        if name.startswith("memory."):
            line = f"    {name[7:]:<24} {_fmt_bytes(note.get('bytes', 0)):>12}"
            if "budget" in note:
                line += (f"  ({100 * note.get('frac', 0.0):.1f}% of "
                         f"{_fmt_bytes(note['budget'])} budget)")
            out.append(line)
        elif name.endswith(".plan") or name.startswith("chunked_map."):
            kv = ", ".join(f"{k}={v}" for k, v in note.items() if k != "name")
            out.append(f"    {name}: {kv}")


def _trace_section(rec: dict, out: list) -> None:
    scope = rec.get("scope") or "(run)"
    s = rec.get("summary") or {}
    out.append(
        f"  trace [{scope}]: {s.get('events_total', 0)} events over "
        f"{s.get('trials', 0)} trials (cap {s.get('capacity', 0)}, "
        f"{s.get('overflowed_trials', 0)} overflowed)"
    )
    by_kind = s.get("by_kind") or {}
    if by_kind:
        out.append("    " + "  ".join(
            f"{k}:{v}" for k, v in by_kind.items() if v
        ))
    tax = rec.get("taxonomy")
    if tax:
        hist = tax.get("histogram") or {}
        out.append(
            f"    taxonomy[{tax.get('scheme', '?')}]: "
            f"{tax.get('residual_total', 0)} residuals -> "
            + (", ".join(f"{k}={v}" for k, v in hist.items()) or "none")
            + f"  (unknown={tax.get('unknown', 0)})"
        )


def _health_section(rec: dict, out: list) -> None:
    scope = rec.get("scope") or "(run)"
    s = rec.get("summary") or {}
    out.append(
        f"  health [{scope}]: {s.get('steps', 0)} steps x "
        f"{s.get('links', 0)} links, "
        f"{100 * s.get('healthy_frac', 1.0):.1f}% healthy "
        f"(worst step {s.get('worst_step', 0)})"
    )
    codes = rec.get("codes")
    if codes:
        legend = "  ".join(
            f"{HEALTH_GLYPHS[i]}={name}" for i, name in enumerate(HEALTH_CODES)
        )
        out.append(f"    links ->   [{legend}]")
        for step, row in enumerate(codes):
            line = "".join(
                HEALTH_GLYPHS[c] if 0 <= c < len(HEALTH_GLYPHS) else "?"
                for c in row
            )
            out.append(f"    step {step:3d}  {line}")


def _bench_section(recs: list, out: list) -> None:
    out.append(f"  bench trajectory ({len(recs)} records)")
    for rec in recs:
        r = rec.get("record") or {}
        name = r.get("name") or r.get("figure") or "?"
        wall = r.get("module_wall_ms")
        bits = [f"    {name:<28}"]
        if wall is not None:
            bits.append(f"{float(wall):>10.1f} ms")
        derived = r.get("derived") or {}
        if derived.get("timeout"):
            phase = derived.get("phase")
            bits.append("  TIMEOUT" + (f" in {phase}" if phase else ""))
        out.append("".join(bits))


def render_report(path: str) -> str:
    """The manifest at ``path`` as a terminal-ready report string."""
    out: list[str] = []
    bench: list[dict] = []
    n_records = 0
    for rec in read_manifest(path):
        n_records += 1
        kind = rec.get("kind")
        if kind == "meta":
            label = rec.get("label", "")
            out.append(f"== run manifest: {label or path} ==")
            extras = {
                k: v for k, v in rec.items()
                if k not in ("kind", "ts", "label", "pid")
            }
            if extras:
                out.append(
                    "  " + ", ".join(f"{k}={v}" for k, v in extras.items())
                )
        elif kind == "phases":
            _phase_section(rec, out)
        elif kind == "trace":
            _trace_section(rec, out)
        elif kind == "health":
            _health_section(rec, out)
        elif kind == "bench_record":
            bench.append(rec)
    if bench:
        _bench_section(bench, out)
    if not out:
        out.append(f"(empty manifest: {path})")
    out.append(f"-- {n_records} records: {path}")
    return "\n".join(out)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] in ("-h", "--help"):
        print("usage: python -m repro.obs.report [manifest.jsonl | dir]")
        return 0
    target = argv[0] if argv else DEFAULT_DIR
    import os

    path = (latest_manifest(target) if os.path.isdir(target) or not argv
            else target)
    if path is None:
        print(f"no manifests under {target!r}", file=sys.stderr)
        return 1
    print(render_report(path))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
