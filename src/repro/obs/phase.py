"""Phase telemetry: timing spans, compile/execute splits, memory watermarks.

A ``PhaseRecorder`` collects named ``Span``s (wall-clock segments tagged
``host``/``compile``/``execute``) and free-form notes (chunk plans, memory
watermarks vs the 256 MB chunk budget).  It is installed per-scope through a
contextvar (``use_recorder``); instrumented call sites — ``core.sweep``,
``fabric.bringup``, ``benchmarks.common.timed_steady`` — look it up with
``current_recorder()`` and do *nothing* when none is installed, so the
uninstrumented path stays a plain function call with zero overhead and zero
behavior change.

``measured_call`` is the compile/execute splitter: it AOT-lowers a jitted
function (``fn.lower(*args, **kwargs).compile()``), records the compile span
and the compiled program's memory watermarks (``memory_analysis()``), then
executes the compiled artifact with only the *dynamic* arguments (JAX's AOT
contract: static args are baked into the lowered program and must be omitted
from the compiled call).  Any failure along the AOT path falls back to a
plain call, so telemetry can never break a sweep.
"""
from __future__ import annotations

import contextlib
import contextvars
import time
from dataclasses import dataclass, field
from typing import Any

__all__ = [
    "PhaseRecorder",
    "Span",
    "current_recorder",
    "measured_call",
    "note",
    "span",
    "use_recorder",
]


@dataclass
class Span:
    """One timed segment: ``kind`` is ``host``/``compile``/``execute``."""

    name: str
    kind: str
    ms: float
    extra: dict = field(default_factory=dict)


class PhaseRecorder:
    """Collects spans and notes for one run scope (a benchmark module, a
    smoke run, a test).  Not thread-safe; one recorder per scope.

    measure_memory: opt into the AOT lower/compile/execute split in
    ``measured_call`` (it changes dispatch — one extra compile-cache-miss
    cost on first call — so benchmark steady-state timing keeps it off).
    """

    def __init__(self, *, measure_memory: bool = False):
        self.spans: list[Span] = []
        self.notes: list[dict] = []
        self.measure_memory = bool(measure_memory)
        self._open: list[str] = []

    # -- spans ------------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, kind: str = "host", **extra):
        self._open.append(name)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            ms = (time.perf_counter() - t0) * 1e3
            self._open.pop()
            self.spans.append(Span(name=name, kind=kind, ms=ms, extra=extra))

    @property
    def current(self) -> str | None:
        """Innermost open span name (what was executing *right now*) —
        the SIGALRM handler of ``benchmarks.run`` reads this to attribute
        a timeout to the phase it interrupted."""
        return self._open[-1] if self._open else None

    def current_path(self) -> str | None:
        """Full open-span stack as ``outer/inner`` (None when idle)."""
        return "/".join(self._open) if self._open else None

    # -- notes ------------------------------------------------------------
    def note(self, name: str, **fields):
        self.notes.append({"name": name, **fields})

    def memory(self, name: str, nbytes: int, budget: int | None = None):
        """Record a compiled-memory watermark, optionally vs a budget."""
        rec: dict[str, Any] = {"bytes": int(nbytes)}
        if budget:
            rec["budget"] = int(budget)
            rec["frac"] = float(nbytes) / float(budget)
        self.note(f"memory.{name}", **rec)

    # -- aggregation ------------------------------------------------------
    def phase_fields(self) -> dict[str, dict]:
        """Aggregate spans by name -> {kind, ms, count} (benchmark-record
        payload: stable keys, summed durations)."""
        out: dict[str, dict] = {}
        for s in self.spans:
            slot = out.setdefault(s.name, {"kind": s.kind, "ms": 0.0, "count": 0})
            slot["ms"] += s.ms
            slot["count"] += 1
        for slot in out.values():
            slot["ms"] = round(slot["ms"], 3)
        return out

    def memory_fields(self) -> list[dict]:
        return [n for n in self.notes if n["name"].startswith("memory.")]


_CURRENT: contextvars.ContextVar[PhaseRecorder | None] = contextvars.ContextVar(
    "repro_obs_phase_recorder", default=None
)


def current_recorder() -> PhaseRecorder | None:
    return _CURRENT.get()


@contextlib.contextmanager
def use_recorder(rec: PhaseRecorder):
    tok = _CURRENT.set(rec)
    try:
        yield rec
    finally:
        _CURRENT.reset(tok)


@contextlib.contextmanager
def span(name: str, kind: str = "host", **extra):
    """Module-level span: records into the installed recorder, or no-ops."""
    rec = _CURRENT.get()
    if rec is None:
        yield
    else:
        with rec.span(name, kind, **extra):
            yield


def note(name: str, **fields):
    """Module-level note: records into the installed recorder, or no-ops."""
    rec = _CURRENT.get()
    if rec is not None:
        rec.note(name, **fields)


def measured_call(
    label: str,
    fn,
    args: tuple,
    kwargs: dict,
    *,
    dynamic_args: tuple,
    dynamic_kwargs: dict | None = None,
    budget: int | None = None,
):
    """Call a jitted ``fn``, splitting compile from execute when asked.

    Without an installed recorder (or with ``measure_memory`` off) this is
    exactly ``fn(*args, **kwargs)`` — identical dispatch, identical caching.
    With memory measurement on, the call is AOT-split: ``lower + compile``
    under a ``compile`` span (recording ``memory_analysis`` watermarks vs
    ``budget``), then the compiled artifact runs under an ``execute`` span
    with the *dynamic* args only (statics are baked into the program).
    """
    rec = _CURRENT.get()
    if rec is None:
        return fn(*args, **kwargs)
    if not rec.measure_memory:
        with rec.span(label, kind="execute"):
            return fn(*args, **kwargs)
    dynamic_kwargs = dynamic_kwargs or {}
    try:
        with rec.span(f"{label}:compile", kind="compile"):
            compiled = fn.lower(*args, **kwargs).compile()
        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes"):
                val = getattr(mem, attr, None)
                if val is not None:
                    rec.memory(
                        f"{label}.{attr.split('_size')[0]}",
                        int(val),
                        budget=budget if attr == "temp_size_in_bytes" else None,
                    )
    except Exception as exc:  # AOT path is best-effort telemetry
        rec.note(f"{label}.aot_fallback", error=repr(exc))
        with rec.span(label, kind="execute"):
            return fn(*args, **kwargs)
    with rec.span(f"{label}:execute", kind="execute"):
        out = compiled(*dynamic_args, **dynamic_kwargs)
        import jax

        return jax.block_until_ready(out)
