"""Observability layer: flight recorder, phase telemetry, health matrix.

Three jit-compatible instruments threaded through the stack, all off by
default with the disabled paths bit-identical to the uninstrumented code:

- ``repro.obs.trace``    per-trial protocol event rings (``run_protocol(trace=)``)
- ``repro.obs.phase``    timing spans + compiled-memory watermarks (contextvar
                         recorder picked up by ``sweep``/``bringup``/benchmarks)
- ``repro.obs.health``   per-step x per-link chaos health codes
                         (``run_fabric_timeline(health=True)``)
- ``repro.obs.taxonomy`` post-hoc failure classifier over traces
- ``repro.obs.manifest`` JSONL run-manifest writer
- ``repro.obs.report``   terminal report CLI (``python -m repro.obs.report``)

``trace``/``phase``/``health`` are dependency-light and re-exported eagerly;
``taxonomy``/``manifest``/``report`` load lazily (taxonomy pulls in
``repro.core``, which itself imports this package — keep the cycle cold).
"""
from __future__ import annotations

from repro.obs.health import HEALTH_CODES, health_codes, health_matrix_summary
from repro.obs.phase import (
    PhaseRecorder,
    Span,
    current_recorder,
    measured_call,
    note,
    span,
    use_recorder,
)
from repro.obs.trace import (
    EVENT_FIELDS,
    EVENT_KINDS,
    TraceBuffer,
    format_events,
    merge_traces,
    trace_append,
    trace_buffer,
    trace_events,
    trace_summary,
)

_LAZY = {
    "classify_trials": "repro.obs.taxonomy",
    "explain_residuals": "repro.obs.taxonomy",
    "TAXONOMY": "repro.obs.taxonomy",
    "RunManifest": "repro.obs.manifest",
    "latest_manifest": "repro.obs.manifest",
    "read_manifest": "repro.obs.manifest",
    "render_report": "repro.obs.report",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


__all__ = [
    "EVENT_FIELDS",
    "EVENT_KINDS",
    "HEALTH_CODES",
    "PhaseRecorder",
    "Span",
    "TraceBuffer",
    "current_recorder",
    "format_events",
    "health_codes",
    "health_matrix_summary",
    "measured_call",
    "merge_traces",
    "note",
    "span",
    "trace_append",
    "trace_buffer",
    "trace_events",
    "trace_summary",
    "use_recorder",
    *sorted(_LAZY),
]
