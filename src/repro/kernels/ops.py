"""Jitted public wrappers for the arbitration Pallas kernels.

Handles layout (core uses (T, N); kernels put trials on lanes: (N, T)),
padding to the 128-trial lane block, and backend selection:

  backend="pallas"     compiled Pallas (TPU)
  backend="interpret"  Pallas interpret mode (CPU correctness path)
  backend="jnp"        portable pure-jnp oracle (default off-TPU)
  backend="auto"       pallas on TPU else jnp

Every wrapper is **vmap-safe**: layout moves use explicit last-two-axes
swaps (never ``.T``, which reverses all axes), and padding/slicing is
expressed on the trial axis only — so the sweep engine
(``repro.core.sweep``) can map them over sigma/TR grid points under
``backend="jnp"`` and ``"interpret"`` (guarded by tests/test_sweep.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bitmask_match import TRIAL_BLOCK, bottleneck_pallas, match_pallas
from .feasibility import feasibility_pallas
from .probe import research_pallas
from .table_build import table_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("pallas", "interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def _to_cols(a) -> jax.Array:
    """Core (T, N) -> kernel (N, T) layout (swap of the last two axes only,
    so extra leading vmap axes pass through untouched)."""
    return jnp.swapaxes(jnp.asarray(a, jnp.float32), -1, -2)


def _pad_cols(x, t_pad):
    t = x.shape[-1]
    if t == t_pad:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, t_pad - t)]
    return jnp.pad(x, pad)


def _padded_t(t: int) -> int:
    return ((t + TRIAL_BLOCK - 1) // TRIAL_BLOCK) * TRIAL_BLOCK


def feasibility(laser, ring, fsr, tr_unit, *, s, backend="auto"):
    """(T, N) system batch -> per-trial (ltd_min_tr, ltc_min_tr)."""
    backend = _resolve(backend)
    cols = [_to_cols(a) for a in (laser, ring, fsr, tr_unit)]
    if backend == "jnp":
        return ref.feasibility_ref(*cols, s=tuple(int(v) for v in s))
    t = cols[0].shape[1]
    tp = _padded_t(t)
    cols = [_pad_cols(c, tp) for c in cols]
    # Padded trials must stay numerically benign: tr_unit=1 avoids div-by-0.
    if tp != t:
        pad_fix = jnp.zeros((cols[3].shape[0], tp), jnp.float32).at[:, t:].set(1.0)
        cols[3] = cols[3] + pad_fix
        cols[2] = cols[2] + pad_fix  # fsr > 0 for mod
    ltd, ltc = feasibility_pallas(
        *cols, s=tuple(int(v) for v in s), interpret=(backend == "interpret")
    )
    return ltd[:t], ltc[:t]


def perfect_matching(adj, *, backend="auto"):
    """adj: (T, N) int32 ring->line bitmasks -> (match_wl (T, N), ok (T,)).

    Multiword (T, N, W) uint32 adjacencies (N > 32) run the portable core
    path on every backend: the Pallas matching kernel is single-word by
    layout (one int32 lane per ring), so wide systems route to
    ``repro.core.matching.max_matching`` rather than failing.
    """
    backend = _resolve(backend)
    adj = jnp.asarray(adj)
    if adj.ndim >= 3 and adj.dtype == jnp.uint32:      # multiword: core path
        from repro.core.matching import max_matching

        mw, _ = max_matching(adj)
        return mw, jnp.all(mw >= 0, axis=-1)
    adj_c = jnp.swapaxes(adj.astype(jnp.int32), -1, -2)
    if backend == "jnp":
        mw, ok = ref.match_ref(adj_c)
        return jnp.swapaxes(mw, -1, -2), ok
    t = adj_c.shape[1]
    tp = _padded_t(t)
    mw, ok = match_pallas(_pad_cols(adj_c, tp), interpret=(backend == "interpret"))
    return jnp.swapaxes(mw, -1, -2)[:t], ok[:t]


def bottleneck_threshold(weights, *, backend="auto"):
    """weights: (T, N, N) scaled residuals -> (T,) bottleneck thresholds.

    The LtA per-trial minimum mean TR (one single-pass bottleneck matching;
    see ``repro.core.matching``).  Layout move is a last-three-axes
    ``moveaxis`` so extra leading vmap axes pass through untouched.
    """
    backend = _resolve(backend)
    w = jnp.moveaxis(jnp.asarray(weights, jnp.float32), -3, -1)  # (N, N, T)
    if backend == "jnp":
        return ref.bottleneck_ref(w)
    t = w.shape[-1]
    tp = _padded_t(t)
    # Padded trials see all-zero weights: threshold 0, sliced off below.
    thr = bottleneck_pallas(_pad_cols(w, tp), interpret=(backend == "interpret"))
    return thr[:t]


def masked_research(wl, taken, floor, *, backend="auto"):
    """Batched masked re-search (the protocol engine's unit primitive).

    wl (T, C, E) int32 line ids of C search-table rows per trial; taken
    (T, L) bool captured-line mask; floor (T, C) int32 first admissible
    entry.  Returns (first (T, C) int32 entry or -1, found (T, C) bool) —
    semantics of ``repro.core.protocol.masked_first_entry`` (parity-tested).
    Layout moves are last-axes swaps only, so extra leading vmap axes pass
    through untouched.
    """
    backend = _resolve(backend)
    wl_c = jnp.moveaxis(jnp.asarray(wl, jnp.int32), -3, -1)       # (C, E, T)
    taken_c = jnp.swapaxes(jnp.asarray(taken, jnp.int32), -1, -2)  # (L, T)
    floor_c = jnp.swapaxes(jnp.asarray(floor, jnp.int32), -1, -2)  # (C, T)
    if backend == "jnp":
        first, found = ref.research_ref(wl_c, taken_c, floor_c)
    else:
        t = wl_c.shape[-1]
        tp = _padded_t(t)
        # Padded trials: all-invalid tables (wl = -1) -> found = 0, sliced.
        if tp != t:
            wl_c = jnp.pad(wl_c, [(0, 0)] * (wl_c.ndim - 1) + [(0, tp - t)],
                           constant_values=-1)
            taken_c = _pad_cols(taken_c, tp)
            floor_c = _pad_cols(floor_c, tp)
        first, found = research_pallas(
            wl_c, taken_c, floor_c, interpret=(backend == "interpret")
        )
        first, found = first[..., : t], found[..., : t]
    return (
        jnp.swapaxes(first, -1, -2),
        jnp.swapaxes(found, -1, -2).astype(bool),
    )


def build_tables(laser, ring, fsr, tr, *, visible=None, max_alias=8,
                 max_entries=None, backend="auto"):
    """(T, N) inputs (tr = actual per-ring TR) -> core-layout tables.

    visible: optional core-layout bool mask of lines on the bus — (T, N_wl)
    or (T, N_ring, N_wl) — for the masked re-search path (None = all lines).
    Returns (delta (T, N, E), wl (T, N, E), n_valid (T, N)).
    """
    backend = _resolve(backend)
    cols = [_to_cols(a) for a in (laser, ring, fsr, tr)]
    # Core (T, ...) -> kernel trials-last layouts, last-axes moves only.
    vis_cols = None
    if visible is not None:
        vis_cols = (jnp.swapaxes(visible, -1, -2) if visible.ndim == 2
                    else jnp.moveaxis(visible, -3, -1))
    if backend == "jnp":
        d, w, nv = ref.table_ref(
            *cols, visible=vis_cols, max_alias=max_alias, max_entries=max_entries
        )
        to_core = lambda a: jnp.moveaxis(a, -1, -3)  # (N, E, T) -> (T, N, E)
        return to_core(d), to_core(w), jnp.swapaxes(nv, -1, -2)
    t = cols[0].shape[1]
    tp = _padded_t(t)
    cols = [_pad_cols(c, tp) for c in cols]
    if tp != t:
        pad_fix = jnp.zeros((cols[2].shape[0], tp), jnp.float32).at[:, t:].set(1.0)
        cols[2] = cols[2] + pad_fix
    if vis_cols is not None:
        if vis_cols.ndim == 2:  # (N_wl, T) -> per-ring (N_ring, N_wl, T)
            vis_cols = jnp.broadcast_to(
                vis_cols[None], (cols[0].shape[0],) + vis_cols.shape
            )
        # Padded trials see an all-zero mask: empty tables, sliced off below.
        vis_cols = _pad_cols(vis_cols.astype(jnp.int32), tp)
    d, w, nv = table_pallas(
        *cols,
        vis_cols,
        max_alias=max_alias,
        max_entries=max_entries,
        interpret=(backend == "interpret"),
    )
    to_core = lambda a: jnp.moveaxis(a, -1, -3)
    return (
        to_core(d)[:t],
        to_core(w)[:t],
        jnp.swapaxes(nv, -1, -2)[:t],
    )
