"""Pallas TPU kernel: batched masked re-search (the protocol engine's unit
primitive — ``repro.core.protocol.masked_first_entry``).

One kernel invocation re-searches C search tables per trial at once against
the captured-line mask: for each (table row, trial) lane pair it returns the
first entry at-or-after the row's ``floor`` whose line id is valid and not
captured.  The protocol engine issues one such call per displacement-chain
hop (all donor candidates together) and per probe-pass rank — batching the
re-searches is what keeps an O(N^3)-probe protocol round a handful of
kernel launches instead of O(N^2) scalar searches.

Layout follows the house convention (trials on lanes):

  wl     (C, E, TB) int32   line id of each entry, -1 padding
  taken  (L, TB)    int32   0/1 captured-line mask
  floor  (C, TB)    int32   first admissible entry index per row

  first  (C, TB)    int32   chosen entry index, -1 if none visible
  found  (C, TB)    int32   0/1

The captured-line lookup runs as an L-step one-hot accumulation over the
sublane axis (the same no-cross-sublane-gather trick as
``bitmask_match``); the first-visible reduction is a masked iota min over
the entry axis.  No data-dependent control flow anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitmask_match import TRIAL_BLOCK


def _research_kernel(wl_ref, taken_ref, floor_ref, first_ref, found_ref):
    c, e, tb = wl_ref.shape
    n_lines = taken_ref.shape[0]
    wl = wl_ref[...]
    taken = taken_ref[...]
    floor = floor_ref[...]
    eiota = jax.lax.broadcasted_iota(jnp.int32, (c, e, tb), 1)
    liota = jax.lax.broadcasted_iota(jnp.int32, (n_lines, tb), 0)

    def acc_taken(i, acc):
        t_i = jnp.sum(jnp.where(liota == i, taken, 0), axis=0)   # (TB,)
        return acc | ((wl == i) & (t_i[None, None, :] > 0))

    taken_at = jax.lax.fori_loop(
        0, n_lines, acc_taken, jnp.zeros((c, e, tb), jnp.bool_)
    )
    vis = (wl >= 0) & ~taken_at & (eiota >= floor[:, None, :])
    first = jnp.min(jnp.where(vis, eiota, e), axis=1)            # (C, TB)
    found = first < e
    first_ref[...] = jnp.where(found, first, -1)
    found_ref[...] = found.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def research_pallas(wl, taken, floor, *, interpret=False):
    """wl (C, E, T) int32, taken (L, T) int32, floor (C, T) int32;
    T % TRIAL_BLOCK == 0.  Returns (first (C, T) int32, found (C, T) int32).
    """
    c, e, t = wl.shape
    n_lines = taken.shape[0]
    assert t % TRIAL_BLOCK == 0, t
    grid = (t // TRIAL_BLOCK,)
    first, found = pl.pallas_call(
        _research_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, e, TRIAL_BLOCK), lambda b: (0, 0, b)),
            pl.BlockSpec((n_lines, TRIAL_BLOCK), lambda b: (0, b)),
            pl.BlockSpec((c, TRIAL_BLOCK), lambda b: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((c, TRIAL_BLOCK), lambda b: (0, b)),
            pl.BlockSpec((c, TRIAL_BLOCK), lambda b: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, t), jnp.int32),
            jax.ShapeDtypeStruct((c, t), jnp.int32),
        ],
        interpret=interpret,
    )(wl, taken, floor)
    return first, found
