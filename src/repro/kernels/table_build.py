"""Pallas TPU kernel: search-table construction (record-phase hot spot).

For every (trial, ring) the wavelength sweep yields up to K = N*(2J+1)
candidate peaks  delta = laser_k - ring_i - j*FSR_i  with 0 <= delta <= TR_i.
The kernel masks invalid candidates to a big sentinel and bitonic-sorts
(key = delta, payload = line id) on the sublane axis, emitting the first E
entries — identical semantics to ``repro.core.search_table``.

Layout: trials on lanes.  Per ring the candidate tile is (K_pad, TB) f32 —
for N=16, J=4, TB=128 that is 256x128x4 = 128 KiB key + 128 KiB payload in
VMEM, processed ring-at-a-time inside the kernel to bound the working set.
The bitonic network is static (log^2 K stages); each compare-exchange is a
reshape into (blocks, 2, stride, TB) so partners are adjacent — no gathers,
no captured constants, no data-dependent control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TRIAL_BLOCK = 128
BIG = 3.0e38  # python literal: Pallas kernels must not capture array consts


def _bitonic_sort(key, payload):
    """Ascending bitonic sort along axis 0 (static power-of-two length)."""
    k_len, tb = key.shape
    size = 2
    while size <= k_len:
        stride = size // 2
        while stride >= 1:
            blocks = k_len // (2 * stride)
            kr = key.reshape(blocks, 2, stride, tb)
            pr = payload.reshape(blocks, 2, stride, tb)
            a_k, b_k = kr[:, 0], kr[:, 1]
            a_p, b_p = pr[:, 0], pr[:, 1]
            # Ascending iff bit `size` of the element index is 0; within one
            # 2*stride block that bit is constant = f(block index).
            blk = jax.lax.broadcasted_iota(jnp.int32, (blocks, stride, tb), 0)
            asc = (blk * (2 * stride)) & size == 0
            swap = jnp.where(asc, a_k > b_k, a_k < b_k)
            new_a_k = jnp.where(swap, b_k, a_k)
            new_b_k = jnp.where(swap, a_k, b_k)
            new_a_p = jnp.where(swap, b_p, a_p)
            new_b_p = jnp.where(swap, a_p, b_p)
            key = jnp.stack([new_a_k, new_b_k], axis=1).reshape(k_len, tb)
            payload = jnp.stack([new_a_p, new_b_p], axis=1).reshape(k_len, tb)
            stride //= 2
        size *= 2
    return key, payload


def _table_kernel(
    laser_ref, ring_ref, fsr_ref, tr_ref, delta_ref, wl_ref, nv_ref, *, max_alias, k_pad
):
    n, tb = laser_ref.shape
    laser = laser_ref[...]
    j_vals = np.arange(-max_alias, max_alias + 1)
    n_j = len(j_vals)

    for i in range(n):  # static unroll over rings; working set stays (K, TB)
        ring_i = ring_ref[i, :][None, :]
        fsr_i = fsr_ref[i, :][None, :]
        tr_i = tr_ref[i, :][None, :]
        keys, pays = [], []
        for j in j_vals:  # candidate deltas for each FSR alias
            d = laser - ring_i - float(j) * fsr_i               # (N, TB)
            ok = (d >= 0.0) & (d <= tr_i)
            keys.append(jnp.where(ok, d, BIG))
            pays.append(jax.lax.broadcasted_iota(jnp.int32, (n, tb), 0))
        key = jnp.concatenate(keys, axis=0)                      # (N*J, TB)
        pay = jnp.concatenate(pays, axis=0)
        pad = k_pad - n * n_j
        if pad:
            key = jnp.concatenate([key, jnp.full((pad, tb), BIG, jnp.float32)], axis=0)
            pay = jnp.concatenate([pay, jnp.full((pad, tb), -1, jnp.int32)], axis=0)
        key, pay = _bitonic_sort(key, pay)

        e = delta_ref.shape[1]
        valid = key[:e] < BIG
        delta_ref[i, :, :] = jnp.where(valid, key[:e], float("inf"))
        wl_ref[i, :, :] = jnp.where(valid, pay[:e], -1)
        nv_ref[i, :] = jnp.sum(valid.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("max_alias", "max_entries", "interpret"))
def table_pallas(laser, ring, fsr, tr, *, max_alias=8, max_entries=None, interpret=False):
    """laser/ring/fsr/tr: (N, T) f32 (tr = actual per-ring tuning ranges).

    Returns (delta (N, E, T) f32, wl (N, E, T) int32, n_valid (N, T) int32).
    """
    n, t = laser.shape
    assert t % TRIAL_BLOCK == 0, t
    e = 3 * n if max_entries is None else max_entries
    k = n * (2 * max_alias + 1)
    k_pad = 1 << int(np.ceil(np.log2(k)))
    grid = (t // TRIAL_BLOCK,)
    in_spec = pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b))
    delta, wl, nv = pl.pallas_call(
        functools.partial(_table_kernel, max_alias=max_alias, k_pad=k_pad),
        grid=grid,
        in_specs=[in_spec] * 4,
        out_specs=[
            pl.BlockSpec((n, e, TRIAL_BLOCK), lambda b: (0, 0, b)),
            pl.BlockSpec((n, e, TRIAL_BLOCK), lambda b: (0, 0, b)),
            pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, e, t), jnp.float32),
            jax.ShapeDtypeStruct((n, e, t), jnp.int32),
            jax.ShapeDtypeStruct((n, t), jnp.int32),
        ],
        interpret=interpret,
    )(laser, ring, fsr, tr)
    return delta, wl, nv
