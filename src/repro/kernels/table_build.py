"""Pallas TPU kernel: search-table construction (record-phase hot spot).

For every (trial, ring) the wavelength sweep yields up to K = N*(2J+1)
candidate peaks  delta = laser_k - ring_i - j*FSR_i  with 0 <= delta <= TR_i.
The kernel streams the candidate axis in FSR-alias groups and
**rank-merges** each group into a persistent sorted top-E buffer — the
kernel-shaped mirror of ``repro.core.search_table.build_search_tables``:
only the *new* candidates are bitonic-sorted (pow2(N*G) rows, the full
log^2 network), and the buffer join is a single bitonic *merge* of
M = pow2(E + pow2(N*G)) rows.  The merge input [buffer (ascending), BIG
pads, sorted block reversed (descending)] is ascending-then-descending —
a valid bitonic sequence — so one log2(M)-stage ladder suffices instead
of re-running the full log^2 sort over the buffer every group (at N=32,
J=17 that is ~1.3x fewer compare-exchanges; ~2.7x at N=64, where the row
bound forces single-alias groups and the old kernel re-sorted 17 times).
The group size G is the largest that keeps M at or under ``_VMEM_ROWS``
(256), so VMEM per ring is bounded by 256 rows instead of the dense
K_pad = pow2(N*J) (1024 rows at N=32, J=17: a 4x working-set cut).

Sort keys are (delta, flat candidate index = line*J + alias) compared
lexicographically, so the (unstable) bitonic network still reproduces the
dense stable-argsort tie order exactly — merge order cannot perturb the
emitted (delta, wl) entries.

Layout: trials on lanes.  Per merge step the tile is (M, TB) f32 key +
(M, TB) i32 index — at the 256-row bound and TB=128 that is 256 KiB in
VMEM, processed ring-at-a-time inside the kernel.  The bitonic network is
static (log^2 M stages); each compare-exchange is a reshape into
(blocks, 2, stride, TB) so partners are adjacent — no gathers, no captured
constants, no data-dependent control flow.  An optional ``vis`` input
((N_ring, N_wl, T) 0/1 mask) supports the visible-masked re-search path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TRIAL_BLOCK = 128
BIG = 3.0e38  # python literal: Pallas kernels must not capture array consts
_VMEM_ROWS = 256  # per-merge sort-tile row bound (key + index pair in VMEM)


def _bitonic_sort(key, idx):
    """Ascending bitonic sort along axis 0 by (key, idx) lexicographically.

    The compound key makes the order total on distinct candidates, so the
    non-stable network still matches the core builder's stable argsort.
    """
    k_len, tb = key.shape
    size = 2
    while size <= k_len:
        stride = size // 2
        while stride >= 1:
            blocks = k_len // (2 * stride)
            kr = key.reshape(blocks, 2, stride, tb)
            ir = idx.reshape(blocks, 2, stride, tb)
            a_k, b_k = kr[:, 0], kr[:, 1]
            a_i, b_i = ir[:, 0], ir[:, 1]
            # Ascending iff bit `size` of the element index is 0; within one
            # 2*stride block that bit is constant = f(block index).
            blk = jax.lax.broadcasted_iota(jnp.int32, (blocks, stride, tb), 0)
            asc = (blk * (2 * stride)) & size == 0
            gt = (a_k > b_k) | ((a_k == b_k) & (a_i > b_i))
            lt = (a_k < b_k) | ((a_k == b_k) & (a_i < b_i))
            swap = jnp.where(asc, gt, lt)
            new_a_k = jnp.where(swap, b_k, a_k)
            new_b_k = jnp.where(swap, a_k, b_k)
            new_a_i = jnp.where(swap, b_i, a_i)
            new_b_i = jnp.where(swap, a_i, b_i)
            key = jnp.stack([new_a_k, new_b_k], axis=1).reshape(k_len, tb)
            idx = jnp.stack([new_a_i, new_b_i], axis=1).reshape(k_len, tb)
            stride //= 2
        size *= 2
    return key, idx


def _bitonic_merge(key, idx):
    """One ascending bitonic *merge* ladder along axis 0 by (key, idx).

    Input rows must form a bitonic sequence (here: ascending buffer, then
    constant-BIG pads, then a descending block).  log2(M) compare-exchange
    stages — the final merge stage of a bitonic sort, without the log^2
    prefix that builds bitonicity from scratch.
    """
    k_len, tb = key.shape
    stride = k_len // 2
    while stride >= 1:
        blocks = k_len // (2 * stride)
        kr = key.reshape(blocks, 2, stride, tb)
        ir = idx.reshape(blocks, 2, stride, tb)
        a_k, b_k = kr[:, 0], kr[:, 1]
        a_i, b_i = ir[:, 0], ir[:, 1]
        swap = (a_k > b_k) | ((a_k == b_k) & (a_i > b_i))
        new_a_k = jnp.where(swap, b_k, a_k)
        new_b_k = jnp.where(swap, a_k, b_k)
        new_a_i = jnp.where(swap, b_i, a_i)
        new_b_i = jnp.where(swap, a_i, b_i)
        key = jnp.stack([new_a_k, new_b_k], axis=1).reshape(k_len, tb)
        idx = jnp.stack([new_a_i, new_b_i], axis=1).reshape(k_len, tb)
        stride //= 2
    return key, idx


def _table_kernel(*refs, max_alias, m_pad, alias_group, has_vis):
    if has_vis:
        laser_ref, ring_ref, fsr_ref, tr_ref, vis_ref = refs[:5]
        delta_ref, wl_ref, nv_ref = refs[5:]
    else:
        laser_ref, ring_ref, fsr_ref, tr_ref = refs[:4]
        vis_ref = None
        delta_ref, wl_ref, nv_ref = refs[4:]
    n, tb = laser_ref.shape
    laser = laser_ref[...]
    j_vals = np.arange(-max_alias, max_alias + 1)
    n_j = len(j_vals)
    e = delta_ref.shape[1]
    groups = [j_vals[g : g + alias_group] for g in range(0, n_j, alias_group)]
    idx_big = n * n_j  # > every real flat index; pads sort last among BIG ties

    for i in range(n):  # static unroll over rings; working set stays (M, TB)
        ring_i = ring_ref[i, :][None, :]
        fsr_i = fsr_ref[i, :][None, :]
        tr_i = tr_ref[i, :][None, :]
        vis_i = (vis_ref[i, :, :] != 0) if has_vis else None
        key = jnp.full((e, tb), BIG, jnp.float32)
        idx = jnp.full((e, tb), idx_big, jnp.int32)
        for g, group in enumerate(groups):  # streaming rank-merge over groups
            parts_k, parts_i = [], []
            for jj, j in enumerate(group):
                d = laser - ring_i - float(j) * fsr_i           # (N, TB)
                ok = (d >= 0.0) & (d <= tr_i)
                if has_vis:
                    ok = ok & vis_i
                parts_k.append(jnp.where(ok, d, BIG))
                parts_i.append(
                    jax.lax.broadcasted_iota(jnp.int32, (n, tb), 0) * n_j
                    + (g * alias_group + jj)
                )
            gb = n * len(group)
            gb_pad = 1 << int(np.ceil(np.log2(gb)))
            if gb_pad - gb:
                parts_k.append(jnp.full((gb_pad - gb, tb), BIG, jnp.float32))
                parts_i.append(jnp.full((gb_pad - gb, tb), idx_big, jnp.int32))
            # Full sort of the new block only; the buffer is already sorted.
            blk_k, blk_i = _bitonic_sort(
                jnp.concatenate(parts_k, axis=0), jnp.concatenate(parts_i, axis=0)
            )
            # [buffer asc, (BIG, idx_big) pads, block desc] ascends to the
            # compound maximum and then descends — bitonic, so one merge
            # ladder joins buffer and block (masked candidates are
            # (BIG, real idx) < (BIG, idx_big), keeping the pads maximal).
            pad = m_pad - e - gb_pad
            seq_k = [key] + (
                [jnp.full((pad, tb), BIG, jnp.float32)] if pad else []
            ) + [jnp.flip(blk_k, axis=0)]
            seq_i = [idx] + (
                [jnp.full((pad, tb), idx_big, jnp.int32)] if pad else []
            ) + [jnp.flip(blk_i, axis=0)]
            key, idx = _bitonic_merge(
                jnp.concatenate(seq_k, axis=0), jnp.concatenate(seq_i, axis=0)
            )
            key, idx = key[:e], idx[:e]

        valid = key < BIG
        delta_ref[i, :, :] = jnp.where(valid, key, float("inf"))
        wl_ref[i, :, :] = jnp.where(valid, idx // n_j, -1)
        nv_ref[i, :] = jnp.sum(valid.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("max_alias", "max_entries", "interpret"))
def table_pallas(laser, ring, fsr, tr, vis=None, *, max_alias=8, max_entries=None,
                 interpret=False):
    """laser/ring/fsr/tr: (N, T) f32 (tr = actual per-ring tuning ranges);
    vis: optional (N_ring, N_wl, T) 0/1 visibility mask.

    Returns (delta (N, E, T) f32, wl (N, E, T) int32, n_valid (N, T) int32).
    """
    n, t = laser.shape
    assert t % TRIAL_BLOCK == 0, t
    n_j = 2 * max_alias + 1
    k = n * n_j
    e = 3 * n if max_entries is None else max_entries
    e = min(e, k)  # like the dense argsort, at most K entries exist
    # Alias group: as many aliases per rank-merge as fit the VMEM row bound.
    # The merge tile holds the buffer (E) plus the pow2-padded sorted block.
    def tile_rows(g: int) -> int:
        gb_pad = 1 << int(np.ceil(np.log2(n * g)))
        return 1 << int(np.ceil(np.log2(e + gb_pad)))

    rows = max(_VMEM_ROWS, tile_rows(1))
    alias_group = max(g for g in range(1, n_j + 1) if tile_rows(g) <= rows)
    m_pad = tile_rows(alias_group)
    grid = (t // TRIAL_BLOCK,)
    in_spec = pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b))
    has_vis = vis is not None
    in_specs = [in_spec] * 4
    args = [laser, ring, fsr, tr]
    if has_vis:
        in_specs.append(pl.BlockSpec((n, n, TRIAL_BLOCK), lambda b: (0, 0, b)))
        args.append(vis)
    delta, wl, nv = pl.pallas_call(
        functools.partial(
            _table_kernel, max_alias=max_alias, m_pad=m_pad,
            alias_group=alias_group, has_vis=has_vis,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((n, e, TRIAL_BLOCK), lambda b: (0, 0, b)),
            pl.BlockSpec((n, e, TRIAL_BLOCK), lambda b: (0, 0, b)),
            pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, e, t), jnp.float32),
            jax.ShapeDtypeStruct((n, e, t), jnp.int32),
            jax.ShapeDtypeStruct((n, t), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return delta, wl, nv
