"""Pure-jnp oracles for the Pallas kernels (kernel-layout adapters over the
portable implementations in ``repro.core``)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ideal
from repro.core.matching import (
    adjacency_bitmask,
    bottleneck_matching_threshold,
    max_matching,
)
from repro.core.protocol import masked_first_entry
from repro.core.sampling import SystemBatch
from repro.core.search_table import build_search_tables


def _sys_from_cols(laser, ring, fsr, tr_unit) -> SystemBatch:
    """(N, T) kernel layout -> SystemBatch (T, N)."""
    return SystemBatch(laser=laser.T, ring=ring.T, fsr=fsr.T, tr_unit=tr_unit.T)


def feasibility_ref(laser, ring, fsr, tr_unit, *, s):
    """Oracle for kernels.feasibility: (ltd_min_tr, ltc_min_tr) each (T,)."""
    sys = _sys_from_cols(laser, ring, fsr, tr_unit)
    s = jnp.asarray(s)
    return ideal.ltd_min_tr(sys, s), ideal.ltc_min_tr(sys, s)


def match_ref(adj):
    """Oracle for kernels.bitmask_match: adj (N, T) -> (match_wl, perfect)."""
    match_wl, _ = max_matching(adj.T)          # (T, N)
    return match_wl.T, jnp.all(match_wl >= 0, axis=1)


def bottleneck_ref(w):
    """Oracle for kernels.bottleneck_pallas: w (N, N, T) -> (T,) thresholds.

    Delegates to the core dispatcher (Hall for small N, the single-pass
    sweep otherwise) — all formulations are bit-identical.
    """
    return bottleneck_matching_threshold(jnp.moveaxis(w, -1, -3))


def research_ref(wl, taken, floor):
    """Oracle for kernels.probe: kernel-layout batched masked re-search.

    wl (C, E, T), taken (L, T), floor (C, T) -> (first (C, T), found (C, T)),
    delegating to the core primitive the protocol engine runs on — the
    kernel is pinned bit-identical to it.
    """
    first, found = masked_first_entry(
        jnp.moveaxis(wl, -1, 0),                   # (T, C, E)
        jnp.moveaxis(taken != 0, -1, 0),           # (T, L)
        jnp.moveaxis(floor, -1, 0),                # (T, C)
    )
    return first.T, found.T.astype(jnp.int32)


def table_ref(laser, ring, fsr, tr, *, visible=None, max_alias=8, max_entries=None):
    """Oracle for kernels.table_build: (N, T) inputs, actual TR in ``tr``.

    visible: optional kernel-layout bool mask — (N_wl, T) or
    (N_ring, N_wl, T) — for the masked re-search path.
    Returns (delta (N, E, T), wl (N, E, T), n_valid (N, T)).
    """
    # build_search_tables consumes tr_mean * tr_unit; pass unit=tr, mean=1.
    sys = _sys_from_cols(laser, ring, fsr, tr)
    if visible is not None:
        visible = jnp.moveaxis(visible != 0, -1, 0)  # trials back to axis 0
    tables = build_search_tables(
        sys, 1.0, visible=visible, max_alias=max_alias, max_entries=max_entries
    )
    return (
        jnp.transpose(tables.delta, (1, 2, 0)),
        jnp.transpose(tables.wl, (1, 2, 0)),
        tables.n_valid.T,
    )
