"""Pallas TPU kernels for the arbitration Monte-Carlo hot spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated in
interpret mode against the pure-jnp oracles in ref.py; ops.py is the
public jitted wrapper with layout/padding/backends.
"""
from .ops import build_tables, feasibility, perfect_matching  # noqa: F401
