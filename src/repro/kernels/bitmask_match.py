"""Pallas TPU kernels: batched matching for the ideal LtA arbiter.

Two kernels over the (ring x line) graph, each for a lane of 128 trials:

``_match_kernel`` — bitmask Kuhn perfect-matching existence.  All state is
int32 vectors/tiles:

  adj       (N, TB)  per-ring line bitmask           (input)
  match_wl  (N, TB)  ring -> matched line index, -1  (carried in registers)
  match_rg  (N, TB)  line -> matched ring index, -1
  parent    (N, TB)  line -> BFS-discovering ring

Per left vertex: BFS over alternating paths using lane-wise variable shifts
(TPU VPU supports per-lane shift amounts), then an augmenting walk-back of at
most N steps.

``_bottleneck_kernel`` — single-pass bottleneck matching threshold over f32
edge weights (N, N, TB), mirroring
``repro.core.matching._bottleneck_threshold_sweep``: per left vertex a
Dijkstra-style search minimizing the max edge weight on an alternating path
(``dist``/``parent``/``visited`` all (N, TB)), then the same walk-back.
Selection argmins run as min-reductions over the sublane axis with an iota
tie-break, so results stay bit-identical to the jnp path.

Dynamic row selects use the one-hot reduce trick so nothing requires
cross-sublane gathers.  No data-dependent control flow: fixed fori_loop trip
counts, masks everywhere — the kernels are oblivious to which trials already
finished, exactly like the batched hardware arbiter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TRIAL_BLOCK = 128


def _row_iota(n, tb):
    return jax.lax.broadcasted_iota(jnp.int32, (n, tb), 0)


def _select_row(mat, idx):
    """mat: (N, TB), idx: (TB,) row index per lane -> (TB,) gathered values."""
    n, tb = mat.shape
    hit = _row_iota(n, tb) == idx[None, :]
    return jnp.sum(jnp.where(hit, mat, 0), axis=0)


def _match_kernel(adj_ref, match_wl_ref, ok_ref):
    n, tb = adj_ref.shape
    adj = adj_ref[...]
    match_wl = jnp.full((n, tb), -1, jnp.int32)   # ring -> line
    match_rg = jnp.full((n, tb), -1, jnp.int32)   # line -> ring
    riota = _row_iota(n, tb)

    def per_vertex(i, carry):
        match_wl, match_rg = carry
        matched_mask = jnp.sum(
            jnp.where(match_rg >= 0, jnp.int32(1) << riota, 0), axis=0
        )
        start = _select_row(adj, jnp.full((tb,), i, jnp.int32))
        parent = jnp.where((start[None, :] >> riota) & 1 == 1, i, -1)
        free_wl = jnp.full((tb,), -1, jnp.int32)

        def bfs(_, c):
            frontier, visited, parent, free_wl = c
            free_hit = frontier & ~matched_mask
            lsb = free_hit & -free_hit
            found = (free_hit != 0) & (free_wl < 0)
            lsb_idx = 31 - jax.lax.clz(jnp.maximum(lsb, 1))
            free_wl = jnp.where(found, lsb_idx, free_wl)

            # Expand through matched rings whose line is in the frontier.
            in_front = (match_wl >= 0) & (
                (frontier[None, :] >> jnp.maximum(match_wl, 0)) & 1 == 1
            )
            newly = jnp.where(in_front, adj & ~visited[None, :], 0)

            def per_ring(r, c2):
                nf, parent = c2
                newly_r = _select_row(newly, jnp.full((tb,), r, jnp.int32))
                fresh = newly_r & ~nf
                parent = jnp.where((fresh[None, :] >> riota) & 1 == 1, r, parent)
                return nf | fresh, parent

            union, parent_new = jax.lax.fori_loop(
                0, n, per_ring, (jnp.zeros((tb,), jnp.int32), parent)
            )
            cont = free_wl < 0
            parent = jnp.where(cont[None, :], parent_new, parent)
            new_frontier = jnp.where(cont, union & ~visited, 0)
            visited = visited | union
            return new_frontier, visited, parent, free_wl

        _, _, parent, free_wl = jax.lax.fori_loop(
            0, n, bfs, (start, start, parent, free_wl)
        )

        def walk(_, c):
            match_wl, match_rg, k, active = c
            k_safe = jnp.maximum(k, 0)
            r = _select_row(parent, k_safe)
            r_safe = jnp.maximum(r, 0)
            prev = _select_row(match_wl, r_safe)
            upd_wl = active[None, :] & (riota == r_safe[None, :])
            match_wl = jnp.where(upd_wl, k_safe[None, :], match_wl)
            upd_rg = active[None, :] & (riota == k_safe[None, :])
            match_rg = jnp.where(upd_rg, r_safe[None, :], match_rg)
            active = active & (r_safe != i) & (prev >= 0)
            return match_wl, match_rg, jnp.where(active, prev, k), active

        match_wl, match_rg, _, _ = jax.lax.fori_loop(
            0, n, walk, (match_wl, match_rg, free_wl, free_wl >= 0)
        )
        return match_wl, match_rg

    match_wl, match_rg = jax.lax.fori_loop(0, n, per_vertex, (match_wl, match_rg))
    match_wl_ref[...] = match_wl
    ok_ref[0, :] = jnp.all(match_wl >= 0, axis=0).astype(jnp.int32)


def _bottleneck_kernel(w_ref, thr_ref):
    n, _, tb = w_ref.shape
    w = w_ref[...]                                    # (ring, wl, trial) f32
    riota = _row_iota(n, tb)
    iota3 = jax.lax.broadcasted_iota(jnp.int32, (n, n, tb), 0)
    inf = jnp.float32(jnp.inf)

    def ring_row(r):
        """(TB,) ring index per lane -> (N, TB) that ring's weight row."""
        return jnp.sum(jnp.where(iota3 == r[None, None, :], w, 0.0), axis=0)

    def first_min(d):
        """(N, TB) -> per-lane (min value, lowest index attaining it)."""
        dmin = jnp.min(d, axis=0)
        idx = jnp.min(jnp.where(d == dmin[None, :], riota, n), axis=0)
        return dmin, idx

    def per_vertex(i, carry):
        match_wl, match_rg, thr = carry
        dist = jnp.sum(jnp.where(iota3 == i, w, 0.0), axis=0)   # w[i] (N, TB)
        parent = jnp.full((n, tb), i, jnp.int32)
        visited = jnp.zeros((n, tb), jnp.int32)

        def select_relax(_, c):
            dist, parent, visited = c
            d = jnp.where(visited == 1, inf, dist)
            dk, k = first_min(d)
            visited = jnp.where(riota == k[None, :], 1, visited)
            r = _select_row(match_rg, k)              # matched ring or -1
            r_safe = jnp.maximum(r, 0)
            cand = jnp.maximum(dk[None, :], ring_row(r_safe))
            better = (r[None, :] >= 0) & (visited == 0) & (cand < dist)
            dist = jnp.where(better, cand, dist)
            parent = jnp.where(better, r_safe[None, :], parent)
            return dist, parent, visited

        dist, parent, _ = jax.lax.fori_loop(
            0, n, select_relax, (dist, parent, visited)
        )
        df = jnp.where(match_rg < 0, dist, inf)
        best, k0 = first_min(df)
        thr = jnp.maximum(thr, best)

        def walk(_, c):
            match_wl, match_rg, k, active = c
            r = _select_row(parent, k)
            r_safe = jnp.maximum(r, 0)
            prev = _select_row(match_wl, r_safe)
            upd_wl = active[None, :] & (riota == r_safe[None, :])
            match_wl = jnp.where(upd_wl, k[None, :], match_wl)
            upd_rg = active[None, :] & (riota == k[None, :])
            match_rg = jnp.where(upd_rg, r_safe[None, :], match_rg)
            active = active & (r != i)
            return match_wl, match_rg, jnp.where(active, jnp.maximum(prev, 0), k), active

        match_wl, match_rg, _, _ = jax.lax.fori_loop(
            0, n, walk, (match_wl, match_rg, k0, jnp.ones((tb,), bool))
        )
        return match_wl, match_rg, thr

    _, _, thr = jax.lax.fori_loop(
        0, n, per_vertex,
        (
            jnp.full((n, tb), -1, jnp.int32),
            jnp.full((n, tb), -1, jnp.int32),
            jnp.full((tb,), -jnp.inf, jnp.float32),
        ),
    )
    thr_ref[0, :] = thr


@functools.partial(jax.jit, static_argnames=("interpret",))
def bottleneck_pallas(w, *, interpret=False):
    """w: (N, N, T) f32 edge weights (ring x wl x trial), T % TRIAL_BLOCK == 0.

    Returns (T,) f32 bottleneck matching thresholds.
    """
    n, _, t = w.shape
    assert t % TRIAL_BLOCK == 0, t
    grid = (t // TRIAL_BLOCK,)
    thr = pl.pallas_call(
        _bottleneck_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, n, TRIAL_BLOCK), lambda b: (0, 0, b))],
        out_specs=pl.BlockSpec((1, TRIAL_BLOCK), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, t), jnp.float32),
        interpret=interpret,
    )(w)
    return thr[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def match_pallas(adj, *, interpret=False):
    """adj: (N, T) int32 per-ring line bitmasks, T % TRIAL_BLOCK == 0.

    Returns (match_wl (N, T) int32, perfect (T,) bool).
    """
    n, t = adj.shape
    assert t % TRIAL_BLOCK == 0, t
    grid = (t // TRIAL_BLOCK,)
    match_wl, ok = pl.pallas_call(
        _match_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b))],
        out_specs=[
            pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b)),
            pl.BlockSpec((1, TRIAL_BLOCK), lambda b: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t), jnp.int32),
            jax.ShapeDtypeStruct((1, t), jnp.int32),
        ],
        interpret=interpret,
    )(adj)
    return match_wl, ok[0].astype(bool)
