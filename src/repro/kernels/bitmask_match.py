"""Pallas TPU kernel: batched bitmask Kuhn matching (ideal LtA arbiter).

Perfect-matching existence over the (ring x line) reachability graph, for a
lane of 128 trials at once.  All state is int32 vectors/tiles:

  adj       (N, TB)  per-ring line bitmask           (input)
  match_wl  (N, TB)  ring -> matched line index, -1  (carried in registers)
  match_rg  (N, TB)  line -> matched ring index, -1
  parent    (N, TB)  line -> BFS-discovering ring

Per left vertex: BFS over alternating paths using lane-wise variable shifts
(TPU VPU supports per-lane shift amounts), then an augmenting walk-back of at
most N steps.  Dynamic row selects use the one-hot reduce trick so nothing
requires cross-sublane gathers.  No data-dependent control flow: fixed
fori_loop trip counts, masks everywhere — the kernel is oblivious to which
trials already finished, exactly like the batched hardware arbiter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TRIAL_BLOCK = 128


def _row_iota(n, tb):
    return jax.lax.broadcasted_iota(jnp.int32, (n, tb), 0)


def _select_row(mat, idx):
    """mat: (N, TB), idx: (TB,) row index per lane -> (TB,) gathered values."""
    n, tb = mat.shape
    hit = _row_iota(n, tb) == idx[None, :]
    return jnp.sum(jnp.where(hit, mat, 0), axis=0)


def _match_kernel(adj_ref, match_wl_ref, ok_ref):
    n, tb = adj_ref.shape
    adj = adj_ref[...]
    match_wl = jnp.full((n, tb), -1, jnp.int32)   # ring -> line
    match_rg = jnp.full((n, tb), -1, jnp.int32)   # line -> ring
    riota = _row_iota(n, tb)

    def per_vertex(i, carry):
        match_wl, match_rg = carry
        matched_mask = jnp.sum(
            jnp.where(match_rg >= 0, jnp.int32(1) << riota, 0), axis=0
        )
        start = _select_row(adj, jnp.full((tb,), i, jnp.int32))
        parent = jnp.where((start[None, :] >> riota) & 1 == 1, i, -1)
        free_wl = jnp.full((tb,), -1, jnp.int32)

        def bfs(_, c):
            frontier, visited, parent, free_wl = c
            free_hit = frontier & ~matched_mask
            lsb = free_hit & -free_hit
            found = (free_hit != 0) & (free_wl < 0)
            lsb_idx = 31 - jax.lax.clz(jnp.maximum(lsb, 1))
            free_wl = jnp.where(found, lsb_idx, free_wl)

            # Expand through matched rings whose line is in the frontier.
            in_front = (match_wl >= 0) & (
                (frontier[None, :] >> jnp.maximum(match_wl, 0)) & 1 == 1
            )
            newly = jnp.where(in_front, adj & ~visited[None, :], 0)

            def per_ring(r, c2):
                nf, parent = c2
                newly_r = _select_row(newly, jnp.full((tb,), r, jnp.int32))
                fresh = newly_r & ~nf
                parent = jnp.where((fresh[None, :] >> riota) & 1 == 1, r, parent)
                return nf | fresh, parent

            union, parent_new = jax.lax.fori_loop(
                0, n, per_ring, (jnp.zeros((tb,), jnp.int32), parent)
            )
            cont = free_wl < 0
            parent = jnp.where(cont[None, :], parent_new, parent)
            new_frontier = jnp.where(cont, union & ~visited, 0)
            visited = visited | union
            return new_frontier, visited, parent, free_wl

        _, _, parent, free_wl = jax.lax.fori_loop(
            0, n, bfs, (start, start, parent, free_wl)
        )

        def walk(_, c):
            match_wl, match_rg, k, active = c
            k_safe = jnp.maximum(k, 0)
            r = _select_row(parent, k_safe)
            r_safe = jnp.maximum(r, 0)
            prev = _select_row(match_wl, r_safe)
            upd_wl = active[None, :] & (riota == r_safe[None, :])
            match_wl = jnp.where(upd_wl, k_safe[None, :], match_wl)
            upd_rg = active[None, :] & (riota == k_safe[None, :])
            match_rg = jnp.where(upd_rg, r_safe[None, :], match_rg)
            active = active & (r_safe != i) & (prev >= 0)
            return match_wl, match_rg, jnp.where(active, prev, k), active

        match_wl, match_rg, _, _ = jax.lax.fori_loop(
            0, n, walk, (match_wl, match_rg, free_wl, free_wl >= 0)
        )
        return match_wl, match_rg

    match_wl, match_rg = jax.lax.fori_loop(0, n, per_vertex, (match_wl, match_rg))
    match_wl_ref[...] = match_wl
    ok_ref[0, :] = jnp.all(match_wl >= 0, axis=0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def match_pallas(adj, *, interpret=False):
    """adj: (N, T) int32 per-ring line bitmasks, T % TRIAL_BLOCK == 0.

    Returns (match_wl (N, T) int32, perfect (T,) bool).
    """
    n, t = adj.shape
    assert t % TRIAL_BLOCK == 0, t
    grid = (t // TRIAL_BLOCK,)
    match_wl, ok = pl.pallas_call(
        _match_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b))],
        out_specs=[
            pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b)),
            pl.BlockSpec((1, TRIAL_BLOCK), lambda b: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t), jnp.int32),
            jax.ShapeDtypeStruct((1, t), jnp.int32),
        ],
        interpret=interpret,
    )(adj)
    return match_wl, ok[0].astype(bool)
