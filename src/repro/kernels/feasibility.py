"""Pallas TPU kernel: ideal LtD/LtC feasibility (per-trial minimum TR).

This is the inner loop of every policy-level Monte-Carlo sweep (Fig. 4-8):
millions of trials, each reducing an (N x N) scaled-residual matrix.  The
TPU-native layout puts TRIALS on the lane axis (128-wide) and channels on
sublanes, so each (N, TB) tile is a handful of VREGs and the whole working
set stays in VMEM:

  inputs   laser/ring/fsr/tr_unit : (N, TB) f32 tiles   (4 * N*TB*4 bytes)
  scratch  scaled residual        : (N, N, TB) f32      (N^2*TB*4 bytes)
  outputs  ltd/ltc min-TR         : (1, TB) f32

For N=16, TB=128 the residual scratch is 128 KiB — comfortably in VMEM with
room for double-buffered input tiles.  The target spectral ordering ``s`` is
compile-time static (one arbiter FSM per ordering, as in hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TRIAL_BLOCK = 128


def _feasibility_kernel(laser_ref, ring_ref, fsr_ref, tru_ref, ltd_ref, ltc_ref, *, s):
    n = laser_ref.shape[0]
    laser = laser_ref[...]          # (N, TB) lines x trials
    ring = ring_ref[...]
    fsr = fsr_ref[...]
    tru = tru_ref[...]

    # scaled_res[i][k] : red-shift of ring i onto line k, / TR multiplier.
    # Unrolled over rings (N is small and static); each row is one VREG op.
    inv_tru = 1.0 / tru
    rows = []
    for i in range(n):
        d = laser - ring[i][None, :]                    # (N, TB)
        res = d - fsr[i][None, :] * jnp.floor(d / fsr[i][None, :])
        rows.append(res * inv_tru[i][None, :])

    # LtD: ring i must take line s_i exactly.
    ltd = rows[0][s[0]]
    for i in range(1, n):
        ltd = jnp.maximum(ltd, rows[i][s[i]])
    ltd_ref[0, :] = ltd

    # LtC: best cyclic shift of the target ordering.
    best = None
    for c in range(n):
        req = rows[0][(s[0] + c) % n]
        for i in range(1, n):
            req = jnp.maximum(req, rows[i][(s[i] + c) % n])
        best = req if best is None else jnp.minimum(best, req)
    ltc_ref[0, :] = best


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def feasibility_pallas(laser, ring, fsr, tr_unit, *, s, interpret=False):
    """laser/ring/fsr/tr_unit: (N, T) f32, T % TRIAL_BLOCK == 0.

    Returns (ltd_min_tr, ltc_min_tr): each (T,) f32.
    """
    n, t = laser.shape
    assert t % TRIAL_BLOCK == 0, t
    grid = (t // TRIAL_BLOCK,)
    in_spec = pl.BlockSpec((n, TRIAL_BLOCK), lambda b: (0, b))
    out_spec = pl.BlockSpec((1, TRIAL_BLOCK), lambda b: (0, b))
    ltd, ltc = pl.pallas_call(
        functools.partial(_feasibility_kernel, s=s),
        grid=grid,
        in_specs=[in_spec] * 4,
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1, t), jnp.float32),
            jax.ShapeDtypeStruct((1, t), jnp.float32),
        ],
        interpret=interpret,
    )(laser, ring, fsr, tr_unit)
    return ltd[0], ltc[0]
