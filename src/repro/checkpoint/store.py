"""Sharded checkpointing with atomic commit and reshard-on-restore.

Layout:  <dir>/step_<N>/host_<i>.npz   (one file per host: its addressable
shards, keyed by flattened param path + shard index) and meta.json with the
step, mesh shape and tree structure.  ``commit`` is a directory rename, so a
crash mid-save never corrupts the latest checkpoint; ``restore`` accepts a
different mesh/pod count and reassembles from per-shard keys (elastic
restart, DESIGN.md §8).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flat(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def save(ckpt_dir: str | Path, step: int, tree, *, host_id: int = 0,
         keep: int = 3) -> Path:
    """Write this host's addressable shards; atomic rename commit."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"_tmp_step_{step:08d}"
    final = ckpt_dir / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    arrays: Dict[str, np.ndarray] = {}
    index: Dict[str, Dict] = {}
    for key, leaf in _flat(tree).items():
        if hasattr(leaf, "addressable_shards"):
            seen = set()
            for sh in leaf.addressable_shards:
                sig = _slice_repr(sh.index)
                tag = json.dumps(sig)
                if tag in seen:  # replicated copy — store once
                    continue
                seen.add(tag)
                arrays[f"{key}||{sh.device.id}"] = np.asarray(sh.data)
                index[f"{key}||{sh.device.id}"] = {
                    "key": key,
                    "slice": sig,
                    "global_shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                }
        else:
            arrays[f"{key}||-1"] = np.asarray(leaf)
            index[f"{key}||-1"] = {
                "key": key,
                "slice": None,
                "global_shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(leaf).dtype),
            }
    np.savez(tmp / f"host_{host_id}.npz", **arrays)
    (tmp / f"index_{host_id}.json").write_text(json.dumps(index))
    (tmp / "meta.json").write_text(
        json.dumps({"step": step, "time": time.time()})
    )
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _slice_repr(index) -> list:
    out = []
    for s in index:
        out.append([s.start, s.stop, s.step])
    return out


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, target_tree, shardings=None):
    """Rebuild the tree (optionally resharded onto new ``shardings``).

    target_tree provides structure + shapes/dtypes (abstract ok).
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    arrays: Dict[str, np.ndarray] = {}
    index: Dict[str, Dict] = {}
    for f in sorted(d.glob("host_*.npz")):
        with np.load(f) as z:
            arrays.update({k: z[k] for k in z.files})
    for f in sorted(d.glob("index_*.json")):
        index.update(json.loads(f.read_text()))

    # assemble per-key global arrays
    globals_: Dict[str, np.ndarray] = {}
    for k, info in index.items():
        key = info["key"]
        if key not in globals_:
            globals_[key] = np.zeros(
                info["global_shape"], dtype=np.dtype(info["dtype"])
            )
        if info["slice"] is None:
            globals_[key] = arrays[k]
        else:
            sl = tuple(slice(a, b, c) for a, b, c in info["slice"])
            globals_[key][sl] = arrays[k]

    flat_target, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    flat_sh = (
        treedef.flatten_up_to(shardings) if shardings is not None else None
    )
    leaves = []
    for i, (path, leaf) in enumerate(flat_target):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = globals_[key]
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves)


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(
        p for p in ckpt_dir.iterdir() if p.name.startswith("step_")
    )
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
