"""Config module for --arch internvl2-76b (see archs.py for source)."""
from .archs import INTERNVL2_76B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
