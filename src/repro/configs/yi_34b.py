"""Config module for --arch yi-34b (see archs.py for source)."""
from .archs import YI_34B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
