"""Named fabric topologies for tests, demos and the fig21 benchmark.

Each preset pairs a WDM config key with a ``FabricSpec`` (see
``repro.fabric.spec``).  Link counts: a ``pods``-pod fabric has
``pods*(pods-1)/2`` bundles of ``links_per_pair`` links each.
"""
from __future__ import annotations

from repro.fabric import FabricSpec


def ring_routes(pods: int, hops: int = 2) -> tuple:
    """One ``hops``-hop route starting at every pod around the pod ring.

    The WDM-ring scheduling topology of the related work (*Scheduling
    Light-trails on WDM Rings*): route i traverses pods
    ``i, i+1, ..., i+hops`` modulo ``pods`` — every hop a distinct bundle,
    every bundle covered, so the route-continuity metric exercises the
    whole fabric.
    """
    if not 1 <= hops < pods:
        raise ValueError(f"ring routes need 1 <= hops < pods, got {hops}")
    return tuple(
        tuple((i + j) % pods for j in range(hops + 1)) for i in range(pods)
    )


# Tiny fabric for tests and the make-ci fig21 smoke: 3 bundles x 2 links,
# shared combs per bundle, one 2-hop route (WDM8: 6 links, 12 trials).
FABRIC_TINY = FabricSpec(
    pods=3, links_per_pair=2, comb_group="bundle",
    routes=ring_routes(3, 1) + ((0, 1, 2),),
)

# The fig21 headline fabric: 8 pods, 28 bundles x 36 links = 1008 links
# (2016 transceiver trials — one 256 MB chunk at WDM16; the >= 1k-link
# acceptance scale), bundle-shared combs, 2-hop ring routes.
FABRIC_1K = FabricSpec(
    pods=8, links_per_pair=36, comb_group="bundle", routes=ring_routes(8, 2),
)

# Pod-level comb sharing at 10k links (16 pods, 120 bundles x 84 links) —
# the 10k-100k regime of the scalability argument; the link axis chunks
# internally, so memory stays at one chunk regardless of fabric size.
FABRIC_10K = FabricSpec(
    pods=16, links_per_pair=84, comb_group="pod", routes=ring_routes(16, 3),
)

# Mid-size chaos fabric for the fig22 scenario gates: 4 pods x 6 bundles
# x 8 links = 48 links at WDM16 — big enough that a comb outage takes a
# whole bundle down, small enough for per-scenario warm-vs-cold gates in
# CI.  Every 2-hop ring route declares the opposite-way fallback around
# the pod ring, so the degraded-mode metrics have a real reroute to find
# when a bundle dies.
FABRIC_MID = FabricSpec(
    pods=4, links_per_pair=8, comb_group="bundle",
    routes=ring_routes(4, 2),
    fallbacks=tuple(
        (tuple((i + j) % 4 for j in (0, 3, 2)),) for i in range(4)
    ),
)

FABRIC_CONFIGS = {
    "tiny-wdm8": ("wdm8-g200", FABRIC_TINY),
    "mid-wdm16": ("wdm16-g200", FABRIC_MID),
    "fabric1k-wdm16": ("wdm16-g200", FABRIC_1K),
    "fabric10k-wdm16": ("wdm16-g200", FABRIC_10K),
}

# --- fabric chaos scenarios (fig22: fault injection + warm re-lock)
#
# Each entry: (fabric config key, timeline spec).  Drift magnitudes are
# multiples of the config's grid spacing, resolved to nm by
# ``chaos_timeline`` exactly like ``wdm.drift_timeline``; events are the
# ``repro.fabric.chaos.make_fabric_timeline`` forms, with liveness
# persisting from the event's step onward.
CHAOS_SCENARIOS = {
    # kill-and-heal: one link flaps dead for two steps mid-ramp — post-heal
    # bandwidth must recover to the pre-fault value (the fig22 heal gate)
    "mid-linkflap": (
        "mid-wdm16",
        dict(n_steps=6, thermal=0.3, events=((2, "link_flap", 3, 2),)),
    ),
    # comb-source outage: bundle (0,1)'s comb dies and every link drawing
    # its light loses all lines together, then the spare comb comes up —
    # the two primary routes crossing that bundle go down (``route_up``
    # dips) but ``route_served`` rides the declared fallbacks through the
    # outage
    "mid-combout": (
        "mid-wdm16",
        dict(n_steps=6, comb=(0.2, 6.0),
             events=((2, "comb_kill", 0), (4, "comb_heal", 0))),
    ),
    # correlated pod heating: every link touching pod 1 ramps together
    # while the rest of the fabric idles — only the hot links re-lock
    "mid-podheat": (
        "mid-wdm16",
        dict(n_steps=6, pod_thermal={1: 0.8}),
    ),
    # ring death: two rings on one endpoint die permanently under a mild
    # fabric-wide ramp; the link degrades but its survivors stay locked
    "mid-ringdeath": (
        "mid-wdm16",
        dict(n_steps=6, thermal=0.3,
             events=((2, "ring_kill", 5, 0, 3), (2, "ring_kill", 5, 0, 9))),
    ),
    # tiny WDM8 kill-and-heal for the make-ci smoke and tests
    "tiny-flap": (
        "tiny-wdm8",
        dict(n_steps=4, thermal=0.2, events=((1, "link_flap", 1, 2),)),
    ),
}


def chaos_timeline(name: str):
    """Resolve a ``CHAOS_SCENARIOS`` entry -> (cfg, spec, FabricTimeline)
    with drift multipliers scaled by the config's grid spacing [nm]."""
    from repro.fabric.chaos import make_fabric_timeline  # avoid import cycle

    from .wdm import WDM_CONFIGS

    fab_key, tspec = CHAOS_SCENARIOS[name]
    cfg_key, spec = FABRIC_CONFIGS[fab_key]
    cfg = WDM_CONFIGS[cfg_key]
    sp = cfg.grid.grid_spacing
    kw = dict(tspec)
    n_steps = kw.pop("n_steps")
    if "thermal" in kw:
        kw["thermal"] = kw["thermal"] * sp
    if "pod_thermal" in kw:
        kw["pod_thermal"] = {
            pod: prof * sp for pod, prof in kw["pod_thermal"].items()
        }
    if "comb" in kw:
        amp, period = kw["comb"]
        kw["comb"] = (amp * sp, period)
    return cfg, spec, make_fabric_timeline(
        spec, n_steps, cfg.grid.n_ch, **kw
    )
