"""Named fabric topologies for tests, demos and the fig21 benchmark.

Each preset pairs a WDM config key with a ``FabricSpec`` (see
``repro.fabric.spec``).  Link counts: a ``pods``-pod fabric has
``pods*(pods-1)/2`` bundles of ``links_per_pair`` links each.
"""
from __future__ import annotations

from repro.fabric import FabricSpec


def ring_routes(pods: int, hops: int = 2) -> tuple:
    """One ``hops``-hop route starting at every pod around the pod ring.

    The WDM-ring scheduling topology of the related work (*Scheduling
    Light-trails on WDM Rings*): route i traverses pods
    ``i, i+1, ..., i+hops`` modulo ``pods`` — every hop a distinct bundle,
    every bundle covered, so the route-continuity metric exercises the
    whole fabric.
    """
    if not 1 <= hops < pods:
        raise ValueError(f"ring routes need 1 <= hops < pods, got {hops}")
    return tuple(
        tuple((i + j) % pods for j in range(hops + 1)) for i in range(pods)
    )


# Tiny fabric for tests and the make-ci fig21 smoke: 3 bundles x 2 links,
# shared combs per bundle, one 2-hop route (WDM8: 6 links, 12 trials).
FABRIC_TINY = FabricSpec(
    pods=3, links_per_pair=2, comb_group="bundle",
    routes=ring_routes(3, 1) + ((0, 1, 2),),
)

# The fig21 headline fabric: 8 pods, 28 bundles x 36 links = 1008 links
# (2016 transceiver trials — one 256 MB chunk at WDM16; the >= 1k-link
# acceptance scale), bundle-shared combs, 2-hop ring routes.
FABRIC_1K = FabricSpec(
    pods=8, links_per_pair=36, comb_group="bundle", routes=ring_routes(8, 2),
)

# Pod-level comb sharing at 10k links (16 pods, 120 bundles x 84 links) —
# the 10k-100k regime of the scalability argument; the link axis chunks
# internally, so memory stays at one chunk regardless of fabric size.
FABRIC_10K = FabricSpec(
    pods=16, links_per_pair=84, comb_group="pod", routes=ring_routes(16, 3),
)

FABRIC_CONFIGS = {
    "tiny-wdm8": ("wdm8-g200", FABRIC_TINY),
    "fabric1k-wdm16": ("wdm16-g200", FABRIC_1K),
    "fabric10k-wdm16": ("wdm16-g200", FABRIC_10K),
}
