"""Assigned input-shape set (the 4 LM-transformer shape cells per arch).

train_*   lower ``train_step``; decode_* / long_* lower ``serve_step``
(one new token against a seq_len KV cache); prefill_* lowers the batched
prompt-ingestion step.  ``long_500k`` requires sub-quadratic sequence
handling and is SKIPped for pure full-attention archs (DESIGN.md
§Arch-applicability) — the skip is recorded, not silently dropped.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason).  long_500k only for SSM/hybrid (sub-quadratic)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "SKIP: pure full-attention arch at 500k decode (O(L) KV per token "
            "with quadratic-prefill family; spec directs skip; see DESIGN.md)"
        )
    return True, ""


def microbatches_for(cfg: ModelConfig, cell: ShapeCell, n_data_shards: int) -> int:
    """Gradient-accumulation split for train cells: keep per-device live
    activation footprint bounded.  Tuned per size class (see §Perf)."""
    if cell.kind != "train":
        return 1
    per_shard = cell.global_batch // n_data_shards
    # target <= 1 sequence per device per microbatch for >=30B, <= 4 for small
    big = cfg.d_model >= 7000 or cfg.n_layers >= 60
    target = 1 if big else 4
    return max(1, per_shard // target)
