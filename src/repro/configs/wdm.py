"""The paper's own DWDM system configurations (Table I / Fig. 5)."""
from repro.core.grid import ArbitrationConfig, wdm_config

WDM8_G200 = wdm_config(n_ch=8, ghz=200)     # paper default (Table I)
WDM8_G400 = wdm_config(n_ch=8, ghz=400)
WDM16_G200 = wdm_config(n_ch=16, ghz=200)
WDM16_G400 = wdm_config(n_ch=16, ghz=400)

WDM_CONFIGS = {
    "wdm8-g200": WDM8_G200,
    "wdm8-g400": WDM8_G400,
    "wdm16-g200": WDM16_G200,
    "wdm16-g400": WDM16_G400,
}
