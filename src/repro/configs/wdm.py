"""The paper's own DWDM system configurations (Table I / Fig. 5)."""
from repro.core.grid import ArbitrationConfig, wdm_config

WDM8_G200 = wdm_config(n_ch=8, ghz=200)     # paper default (Table I)
WDM8_G400 = wdm_config(n_ch=8, ghz=400)
WDM16_G200 = wdm_config(n_ch=16, ghz=200)
WDM16_G400 = wdm_config(n_ch=16, ghz=400)
# Beyond-paper scale (§V scaling discussion): 32 channels, served by the
# N > 10 single-pass bottleneck matching in repro.core.matching.
WDM32_G200 = wdm_config(n_ch=32, ghz=200)
WDM32_G400 = wdm_config(n_ch=32, ghz=400)
# 64 channels (§VII scalability; the channel counts deployment studies in
# PAPERS.md operate at).  The rank-merge streaming tables keep a scheme
# point inside the sweep engine's chunk budget here, and the multiword
# (2x uint32) adjacency bitmasks in repro.core.matching carry the ideal-LtA
# matching path to this width — see the ROADMAP backend matrix.
WDM64_G200 = wdm_config(n_ch=64, ghz=200)
WDM64_G400 = wdm_config(n_ch=64, ghz=400)

WDM_CONFIGS = {
    "wdm8-g200": WDM8_G200,
    "wdm8-g400": WDM8_G400,
    "wdm16-g200": WDM16_G200,
    "wdm16-g400": WDM16_G400,
    "wdm32-g200": WDM32_G200,
    "wdm32-g400": WDM32_G400,
    "wdm64-g200": WDM64_G200,
    "wdm64-g400": WDM64_G400,
}

# --- temporal drift scenarios (re-arbitration under drift / aging / failure)
#
# Each entry: (wdm config key, timeline spec).  Drift magnitudes are stored
# as multiples of the config's grid spacing so a scenario means the same
# thing at 200 and 400 GHz; ``drift_timeline`` resolves them to nm and
# builds the concrete ``repro.core.temporal.Timeline``.  Events are
# (step, kind, channel) with liveness persisting from ``step`` on.
DRIFT_SCENARIOS = {
    # slow uniform thermal ramp: every lock drifts red-ward together
    "wdm16-thermal": ("wdm16-g200", dict(n_steps=8, thermal=0.6)),
    # differential aging tilt: high-index rings outrun their locks first
    "wdm16-aging": ("wdm16-g200", dict(n_steps=8, aging=0.5)),
    # comb-source wander: sinusoidal, locks break then become feasible again
    "wdm16-comb": ("wdm16-g200", dict(n_steps=8, comb=(0.4, 8.0))),
    # mild ramp plus a lane failure and hot-swap recovery mid-timeline
    "wdm16-hotswap": (
        "wdm16-g200",
        dict(n_steps=8, thermal=0.3,
             events=((3, "lane_kill", 5), (6, "lane_swap", 5))),
    ),
    "wdm32-thermal": ("wdm32-g200", dict(n_steps=6, thermal=0.6)),
    "wdm32-hotswap": (
        "wdm32-g200",
        dict(n_steps=6, comb=(0.3, 6.0),
             events=((2, "lane_kill", 11), (4, "lane_swap", 11))),
    ),
}


def drift_timeline(name: str):
    """Resolve a ``DRIFT_SCENARIOS`` entry -> (cfg, Timeline) with drift
    multipliers scaled by the config's grid spacing [nm]."""
    from repro.core.temporal import make_timeline  # local: avoid import cycle

    cfg_key, spec = DRIFT_SCENARIOS[name]
    cfg = WDM_CONFIGS[cfg_key]
    sp = cfg.grid.grid_spacing
    kw = dict(spec)
    n_steps = kw.pop("n_steps")
    for key in ("thermal", "aging"):
        if key in kw:
            kw[key] = kw[key] * sp
    if "comb" in kw:
        amp, period = kw["comb"]
        kw["comb"] = (amp * sp, period)
    return cfg, make_timeline(n_steps, len(cfg.s), **kw)
