"""The paper's own DWDM system configurations (Table I / Fig. 5)."""
from repro.core.grid import ArbitrationConfig, wdm_config

WDM8_G200 = wdm_config(n_ch=8, ghz=200)     # paper default (Table I)
WDM8_G400 = wdm_config(n_ch=8, ghz=400)
WDM16_G200 = wdm_config(n_ch=16, ghz=200)
WDM16_G400 = wdm_config(n_ch=16, ghz=400)
# Beyond-paper scale (§V scaling discussion): 32 channels, served by the
# N > 10 single-pass bottleneck matching in repro.core.matching.
WDM32_G200 = wdm_config(n_ch=32, ghz=200)
WDM32_G400 = wdm_config(n_ch=32, ghz=400)
# 64 channels (§VII scalability; the channel counts deployment studies in
# PAPERS.md operate at).  The rank-merge streaming tables keep a scheme
# point inside the sweep engine's chunk budget here; note the LtA ideal
# path's int32 adjacency bitmask tops out at N=32, so 64-channel sweeps use
# LtC-conditioned schemes (e.g. vtrs_ssm) — see the ROADMAP backend matrix.
WDM64_G200 = wdm_config(n_ch=64, ghz=200)
WDM64_G400 = wdm_config(n_ch=64, ghz=400)

WDM_CONFIGS = {
    "wdm8-g200": WDM8_G200,
    "wdm8-g400": WDM8_G400,
    "wdm16-g200": WDM16_G200,
    "wdm16-g400": WDM16_G400,
    "wdm32-g200": WDM32_G200,
    "wdm32-g400": WDM32_G400,
    "wdm64-g200": WDM64_G200,
    "wdm64-g400": WDM64_G400,
}
