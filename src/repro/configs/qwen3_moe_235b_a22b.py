"""Config module for --arch qwen3-moe-235b-a22b (see archs.py for source)."""
from .archs import QWEN3_MOE_235B_A22B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
