"""Config module for --arch internlm2-1.8b (see archs.py for source)."""
from .archs import INTERNLM2_1_8B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
