"""Config module for --arch mamba2-130m (see archs.py for source)."""
from .archs import MAMBA2_130M as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
