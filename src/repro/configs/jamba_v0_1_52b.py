"""Config module for --arch jamba-v0.1-52b (see archs.py for source)."""
from .archs import JAMBA_V01_52B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
