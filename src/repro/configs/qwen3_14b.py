"""Config module for --arch qwen3-14b (see archs.py for source)."""
from .archs import QWEN3_14B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
