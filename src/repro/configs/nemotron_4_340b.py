"""Config module for --arch nemotron-4-340b (see archs.py for source)."""
from .archs import NEMOTRON_4_340B as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
