"""Config module for --arch llama4-scout-17b-a16e (see archs.py for source)."""
from .archs import LLAMA4_SCOUT_17B_A16E as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
