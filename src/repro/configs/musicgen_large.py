"""Config module for --arch musicgen-large (see archs.py for source)."""
from .archs import MUSICGEN_LARGE as CONFIG, smoke_variant

SMOKE = smoke_variant(CONFIG)
