"""Assigned architecture pool: exact configs from public literature.

Sources per the assignment sheet; shapes verified against HF configs /
papers where available.  Each entry also carries numerics choices scaled to
its size (bf16 params+moments for >=30B total params, fp32 otherwise).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import (
    BlockSpec,
    ModelConfig,
    dense_pattern,
    jamba_pattern,
    mamba_pattern,
    moe_pattern,
)

_BIG = dict(
    param_dtype="bfloat16",
    moment_dtype="bfloat16",
    accum_dtype="bfloat16",
    seq_shard_carry=True,
)
_SMALL = dict(param_dtype="float32", moment_dtype="float32", accum_dtype="float32")


INTERNLM2_1_8B = ModelConfig(
    name="internlm2-1.8b",            # arXiv:2403.17297 [dense, GQA]
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92544, head_dim=128,
    pattern=dense_pattern(), act="swiglu", rope_theta=1e6, **_SMALL,
)

QWEN3_14B = ModelConfig(
    name="qwen3-14b",                 # hf:Qwen/Qwen3-14B [dense, GQA, qk_norm]
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    pattern=dense_pattern(), act="swiglu", qk_norm=True, rope_theta=1e6, **_SMALL,
)

YI_34B = ModelConfig(
    name="yi-34b",                    # arXiv:2403.04652 [dense, llama-arch GQA]
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    pattern=dense_pattern(), act="swiglu", rope_theta=5e6, **_BIG,
)

NEMOTRON_4_340B = ModelConfig(
    name="nemotron-4-340b",           # arXiv:2402.16819 [dense, squared-ReLU]
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, head_dim=192,
    pattern=dense_pattern(), act="squared_relu", **_BIG,
)

MAMBA2_130M = ModelConfig(
    name="mamba2-130m",               # arXiv:2405.21060 [ssm, SSD]
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,  # heads unused (attn-free)
    d_ff=0, vocab=50280, head_dim=64,
    pattern=mamba_pattern(),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, **_SMALL,
)

LLAMA4_SCOUT_17B_A16E = ModelConfig(
    name="llama4-scout-17b-a16e",     # hf:meta-llama/Llama-4-Scout [moe 16e top-1]
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    pattern=moe_pattern(every=1), act="swiglu",
    n_experts=16, top_k=1, n_shared_experts=1, rope_theta=5e5, **_BIG,
)

QWEN3_MOE_235B_A22B = ModelConfig(
    name="qwen3-moe-235b-a22b",       # hf:Qwen/Qwen3-235B-A22B [moe 128e top-8]
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    pattern=moe_pattern(every=1), act="swiglu", qk_norm=True,
    n_experts=128, top_k=8, rope_theta=1e6, **_BIG,
)

JAMBA_V01_52B = ModelConfig(
    name="jamba-v0.1-52b",            # arXiv:2403.19887 [hybrid 1:7 + MoE 16e top-2]
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    pattern=jamba_pattern(),
    n_experts=16, top_k=2,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, **_BIG,
)

INTERNVL2_76B = ModelConfig(
    name="internvl2-76b",             # arXiv:2404.16821 [vlm backbone: llama3-70b]
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    pattern=dense_pattern(), act="swiglu", rope_theta=5e5,
    frontend="vit", frontend_len=256, **_BIG,
)

MUSICGEN_LARGE = ModelConfig(
    name="musicgen-large",            # arXiv:2306.05284 [audio decoder over EnCodec]
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64,
    pattern=dense_pattern(), act="gelu", **_SMALL,
)

ALL = (
    INTERNLM2_1_8B,
    QWEN3_14B,
    YI_34B,
    NEMOTRON_4_340B,
    MAMBA2_130M,
    LLAMA4_SCOUT_17B_A16E,
    QWEN3_MOE_235B_A22B,
    JAMBA_V01_52B,
    INTERNVL2_76B,
    MUSICGEN_LARGE,
)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: small width/depth, few experts, tiny
    vocab — used by CPU smoke tests; the full configs are exercised only via
    the dry-run (ShapeDtypeStruct, no allocation)."""
    n_layers = len(cfg.pattern)  # one super-block
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 96,
        vocab=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        # drop-free routing so prefill/decode consistency is exact in tests
        capacity_factor=8.0 if cfg.n_experts else cfg.capacity_factor,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        frontend_len=8 if cfg.frontend_len else 0,
        q_chunk=16,
        kv_chunk=16,
        param_dtype="float32",
        moment_dtype="float32",
        accum_dtype="float32",
    )
