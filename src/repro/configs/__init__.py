"""Config registry: ``--arch <id>`` resolution for launchers and tests."""
from __future__ import annotations

from repro.models.config import ModelConfig

from . import archs
from .archs import ALL, smoke_variant  # noqa: F401
from .shapes import SHAPES, SHAPES_BY_NAME, ShapeCell, applicable, microbatches_for  # noqa: F401
from .fabric import FABRIC_CONFIGS  # noqa: F401
from .wdm import WDM_CONFIGS  # noqa: F401

REGISTRY = {cfg.name: cfg for cfg in ALL}
ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}") from None


def get_smoke(arch_id: str) -> ModelConfig:
    return smoke_variant(get_config(arch_id))
