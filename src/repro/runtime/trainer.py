"""Fault-tolerant training runtime.

Composes the substrates: data pipeline -> jitted train step (microbatched,
sharded) -> optimizer, with production behaviors:

  * periodic + emergency checkpointing (atomic, sharded, resharding restore)
  * straggler detection: per-step wall-time EWMA; a step slower than
    ``straggler_factor`` x EWMA raises a flag consumed by the scheduler
    (in simulation: logged + counted)
  * optical-fabric awareness: bring-up arbitration before the first step;
    injected link-degradation events trigger LtC re-arbitration and, if
    lanes remain lost, a bandwidth-degradation note for the collective
    scheduler (chunk-size rescale)
  * elastic restart: restore() accepts a different data-parallel extent
    than the checkpoint was written with.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs.wdm import WDM8_G200
from repro.distributed.ctx import activation_axes
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optics import interconnect
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 2.0
    n_microbatch: int = 1
    seed: int = 0
    # optical fabric (simulated when pods <= 1 on test hardware)
    pods: int = 2
    links_per_pod_pair: int = 8
    link_failure_prob_per_step: float = 0.0  # injected fault rate


@dataclasses.dataclass
class TrainerState:
    params: Any
    opt_state: adamw.OptState
    step: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        opt_cfg: adamw.AdamWConfig,
        mesh,
        train_step: Callable,
        param_shardings,
        opt_shardings,
    ):
        self.cfg, self.tcfg, self.opt_cfg = cfg, tcfg, opt_cfg
        self.mesh = mesh
        self.train_step = train_step
        self.param_shardings = param_shardings
        self.opt_shardings = opt_shardings
        self.fabric: Optional[interconnect.FabricState] = None
        self.metrics_log: list = []
        self.straggler_events = 0
        self.rearb_rounds = 0
        self._ewma: Optional[float] = None
        self._emergency = False
        self._rng = np.random.default_rng(tcfg.seed)

    # ------------------------------------------------------------ bring-up
    def bringup_fabric(self):
        """Wavelength-arbitrate every inter-pod optical link (paper §V)."""
        self.fabric = interconnect.bringup(
            pods=self.tcfg.pods,
            links_per_pod_pair=self.tcfg.links_per_pod_pair,
            cfg=WDM8_G200,
            scheme="vtrs_ssm",
            seed=self.tcfg.seed,
        )
        deg = self.fabric.degraded_links()
        if deg:
            self.fabric, rounds = interconnect.rearbitrate(
                self.fabric, WDM8_G200, seed=self.tcfg.seed + 1
            )
            self.rearb_rounds += rounds
        return self.fabric

    # ---------------------------------------------------------- init/restore
    def init_state(self) -> TrainerState:
        latest = store.latest_step(self.tcfg.ckpt_dir)
        abstract_p = M.param_shapes(self.cfg)
        if latest is not None:
            params = store.restore(
                self.tcfg.ckpt_dir, latest, abstract_p, self.param_shardings
            )
            opt_abs = jax.eval_shape(
                lambda p: adamw.init(self.opt_cfg, p), abstract_p
            )
            opt = store.restore(
                Path(self.tcfg.ckpt_dir) / "opt", latest, opt_abs,
                self.opt_shardings,
            )
            return TrainerState(params=params, opt_state=opt, step=latest)
        with self.mesh:
            params = jax.jit(
                lambda k: M.init_params(k, self.cfg),
                out_shardings=self.param_shardings,
            )(jax.random.key(self.tcfg.seed))
            opt = jax.jit(
                lambda p: adamw.init(self.opt_cfg, p),
                out_shardings=self.opt_shardings,
            )(params)
        return TrainerState(params=params, opt_state=opt, step=0)

    def save(self, state: TrainerState):
        store.save(self.tcfg.ckpt_dir, state.step, state.params)
        store.save(Path(self.tcfg.ckpt_dir) / "opt", state.step, state.opt_state)

    # ------------------------------------------------------------- main loop
    def fit(self, state: TrainerState, batches: Iterator[Dict[str, np.ndarray]]):
        tcfg = self.tcfg
        old = signal.signal(signal.SIGTERM, self._on_term)
        try:
            with self.mesh, activation_axes(self.mesh, dp=("pod", "data")):
                while state.step < tcfg.total_steps:
                    batch = next(batches)
                    t0 = time.time()
                    params, opt, metrics = self.train_step(
                        state.params, state.opt_state, batch
                    )
                    jax.block_until_ready(metrics["loss"])
                    dt = time.time() - t0
                    state = TrainerState(params=params, opt_state=opt,
                                         step=state.step + 1)
                    self._track_step_time(dt, state.step)
                    self._maybe_link_event(state.step)
                    if state.step % tcfg.log_every == 0:
                        self.metrics_log.append(
                            {"step": state.step,
                             "loss": float(metrics["loss"]),
                             "grad_norm": float(metrics["grad_norm"]),
                             "sec_per_step": dt}
                        )
                    if state.step % tcfg.ckpt_every == 0 or self._emergency:
                        self.save(state)
                        if self._emergency:
                            break
        finally:
            signal.signal(signal.SIGTERM, old)
        return state

    # ------------------------------------------------------------- internals
    def _on_term(self, *_):
        self._emergency = True  # emergency checkpoint at next step boundary

    def _track_step_time(self, dt: float, step: int):
        if self._ewma is None:
            self._ewma = dt
        if dt > self.tcfg.straggler_factor * self._ewma and step > 3:
            self.straggler_events += 1
        self._ewma = 0.9 * self._ewma + 0.1 * dt

    def _maybe_link_event(self, step: int):
        if (
            self.fabric is not None
            and self.tcfg.link_failure_prob_per_step > 0
            and self._rng.random() < self.tcfg.link_failure_prob_per_step
        ):
            # knock lanes off a random link, then re-arbitrate in place
            i = int(self._rng.integers(len(self.fabric.links)))
            link = self.fabric.links[i]
            self.fabric.links[i] = dataclasses.replace(
                link, lanes_up=max(0, link.lanes_up - 2), failure="zero_lock"
            )
            self.fabric, rounds = interconnect.rearbitrate(
                self.fabric, WDM8_G200, seed=self.tcfg.seed + 997 + step
            )
            self.rearb_rounds += rounds
