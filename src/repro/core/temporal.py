"""Temporal re-arbitration — time as a first-class simulation axis.

The paper treats arbitration as one-shot initialization, but its premise —
algorithms "resilient to system variability" — only holds if a locked system
survives *time*: thermal ramps, comb wander, ring aging, lane failure.  Mak
et al., *Automatic Resonance Alignment of High-Order Microring Filters*
(PAPERS.md), is this loop at the device level — feedback-driven continuous
alignment without wavelength knowledge.  This module runs it at the
protocol level: a drift/event ``Timeline`` driven by a ``lax.scan`` whose
carry is the protocol engine's live ``ProtocolState`` pytree.

Each timeline step:

1. applies the step's drift offsets through the registered variation axes
   (``thermal_drift`` for ring offsets, ``comb_wander`` for the comb — the
   same ``Variations`` transform hooks static sweeps use),
2. rebuilds the streaming search tables against the *live* bus (dead lanes
   and dead rings masked via the tables' ``visible`` hook),
3. revalidates the carried locks (``protocol.revalidate_state``): a held
   line missing from the rebuilt table — drifted out of range, killed, or
   the holder dead — is a *broken* lock; an optional ``hysteresis`` margin
   breaks locks early, before drift pushes them over the edge,
4. re-arbitrates with ``run_protocol`` **from the carried state** (warm,
   incremental — the default) or from scratch (cold — the baseline the
   incremental path is measured against in
   ``benchmarks/fig20_temporal_relock.py``).

Warm re-arbitration runs the augment phase transactionally
(``run_protocol(transactional=True)``): after a lane loss leaves a ring
unlockable, its displacement chains can never close, and non-transactional
eager yields would walk the starvation hole through every still-feasible
lock on the bus.

Everything is shape-static and jit/vmap-safe; the sweep engine maps whole
timelines over variation grids via ``SweepRequest(timeline=...)``.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .matching import adjacency_bitmask, max_matching
from .protocol import (
    ProtocolState,
    cold_state,
    revalidate_state,
    run_protocol,
)
from .reach import reach_matrix
from .relation import chain_spec
from .sampling import UnitSamples, instantiate
from .variations import Variations, apply_axis_transforms, as_variations


class Timeline(NamedTuple):
    """A batched drift/event trajectory: per-step offsets and liveness.

    All fields are (S, N) over S timeline steps and N channels; offsets are
    in nm and *absolute* relative to the undrifted system (not per-step
    increments), so a timeline slice replays identically from a checkpoint.
    """

    ring_drift: jax.Array   # (S, N) added to every trial's ring resonances
    laser_drift: jax.Array  # (S, N) added to every trial's laser lines
    lane_alive: jax.Array   # (S, N) bool: laser line present on the bus
    ring_alive: jax.Array   # (S, N) bool: ring controller powered

    @property
    def n_steps(self) -> int:
        return self.ring_drift.shape[0]

    @property
    def n_ch(self) -> int:
        return self.ring_drift.shape[1]


class TemporalStats(NamedTuple):
    """Per-step accounting of one ``run_timeline`` call (all (S, T)).

    ``probes``/``rounds`` count only each step's incremental spend (the
    re-lock latency vs a cold start); ``broken`` counts locks invalidated
    at the step's revalidation gate (drift-out, hysteresis, kill events);
    ``churn`` counts rings whose lock survived revalidation but ended the
    step on a different line anyway — the thrash a hysteresis margin is
    meant to buy down; ``feasible`` marks trials where the live bus still
    admits a perfect matching of live rings onto live lines.
    """

    probes: jax.Array    # (S, T) int32
    rounds: jax.Array    # (S, T) int32
    locked: jax.Array    # (S, T) int32
    broken: jax.Array    # (S, T) int32
    churn: jax.Array     # (S, T) int32
    feasible: jax.Array  # (S, T) bool


def _ramp(n_steps: int, spec, n_ch: int | None = None) -> np.ndarray:
    """Resolve a drift spec to a (S,) profile.

    ``spec`` may be a scalar (linear ramp 0 -> spec), a sequence of
    (step, value) breakpoints (piecewise-linear), or a (S,) array.
    """
    steps = np.arange(n_steps, dtype=np.float32)
    if spec is None:
        return np.zeros(n_steps, np.float32)
    arr = np.asarray(spec, np.float32)
    if arr.ndim == 0:
        last = max(1, n_steps - 1)
        return arr * steps / last
    if arr.ndim == 2 and arr.shape[1] == 2:
        return np.interp(steps, arr[:, 0], arr[:, 1]).astype(np.float32)
    if arr.shape != (n_steps,):
        raise ValueError(
            f"drift spec must be scalar, (K, 2) breakpoints or ({n_steps},); "
            f"got shape {arr.shape}"
        )
    return arr


_EVENT_KINDS = ("lane_kill", "lane_swap", "ring_kill", "ring_swap")


def make_timeline(
    n_steps: int,
    n_ch: int,
    *,
    thermal=None,
    aging=None,
    comb=None,
    events: Sequence[tuple] = (),
) -> Timeline:
    """Deterministic host-side timeline builder.

    thermal: uniform ring red-shift profile [nm] — scalar (linear ramp to
             that value), (K, 2) ``(step, value)`` breakpoints, or (S,).
    aging:   differential aging: ring i accumulates ``profile * i/(N-1)``
             (the ``ring_aging`` axis shape); same spec forms as thermal.
    comb:    uniform laser-line wander [nm] — ``(amplitude, period)`` for a
             sinusoid, or the same spec forms as thermal.
    events:  ``(step, kind, channel)`` with kind one of lane_kill /
             lane_swap / ring_kill / ring_swap; liveness changes persist
             from ``step`` onward (a kill followed by a swap is a hot-swap).
    """
    thermal_t = _ramp(n_steps, thermal)
    aging_t = _ramp(n_steps, aging)
    if isinstance(comb, tuple) and len(comb) == 2 and np.ndim(comb[0]) == 0:
        amp, period = comb
        comb_t = np.float32(amp) * np.sin(
            2.0 * np.pi * np.arange(n_steps) / float(period)
        ).astype(np.float32)
    else:
        comb_t = _ramp(n_steps, comb)

    tilt = np.arange(n_ch, dtype=np.float32) / max(1, n_ch - 1)
    ring_drift = thermal_t[:, None] + aging_t[:, None] * tilt[None, :]
    laser_drift = np.broadcast_to(comb_t[:, None], (n_steps, n_ch)).copy()

    lane = np.ones((n_steps, n_ch), bool)
    ring = np.ones((n_steps, n_ch), bool)
    for step, kind, ch in events:
        if kind not in _EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r}; valid: {_EVENT_KINDS}")
        target = lane if kind.startswith("lane") else ring
        target[step:, ch] = kind.endswith("swap")
    return Timeline(
        ring_drift=jnp.asarray(ring_drift, jnp.float32),
        laser_drift=jnp.asarray(laser_drift, jnp.float32),
        lane_alive=jnp.asarray(lane),
        ring_alive=jnp.asarray(ring),
    )


def slice_timeline(tl: Timeline, start: int, stop: int | None = None) -> Timeline:
    """Steps ``[start, stop)`` of a timeline (offsets are absolute, so a
    slice resumes bit-identically from a checkpointed carry state)."""
    return jax.tree_util.tree_map(lambda a: a[start:stop], tl)


def _protocol_kwargs(scheme: str) -> dict | None:
    """Static ``run_protocol`` kwargs for a registered protocol scheme, or
    None for one-shot schemes (cold-only re-arbitration, no probe stats)."""
    from .api import scheme_spec  # local: api imports this module's deps

    spec = scheme_spec(scheme)  # validates the name either way
    if not scheme.startswith("protocol_"):
        return None
    if scheme == "protocol_ltd":
        return {"depth": 0, "n_rounds": 1, "order": "chain"}
    return dict(spec.params)


def protocol_relock(
    tables,
    spec,
    start: ProtocolState,
    *,
    warm: bool,
    backend: str | None = None,
    transactional: bool = True,
    patience: int | None = 4,
    kw: dict | None = None,
    trace: int | None = None,
):
    """One re-lock pass of the protocol engine from ``start``.

    Returns ``(new_state, probes, rounds)`` — with ``trace`` set (a
    flight-recorder ring capacity, see ``run_protocol``), the merged
    ``TraceBuffer`` is appended: trials the escalation resolved cold carry
    the cold pass's trace, exactly as they carry its state.  With
    ``warm=True`` the pass
    includes the cold-fallback escalation: a warm start is *more*
    constrained than a cold one (surviving locks are pinned wherever drift
    left them, and donors only relock red-ward), so occasionally an
    augmenting path exists that incremental re-arbitration cannot reach.
    Trials the warm pass left unresolved rerun from scratch and pay both
    passes' probes/rounds — the escalation a real controller would run, and
    the warm path is only a win if it beats cold *including* this cost.
    (Trials whose warm start held no locks would rerun the identical cold
    procedure — nothing to escalate.)

    Shared by the per-transceiver timeline scan (``run_timeline_impl``) and
    the fabric chaos scan (``repro.fabric.chaos``) so the escalation
    semantics cannot drift between the two layers.
    """
    t, n = start.lock.shape
    kw = kw or {}
    tracing = trace is not None
    out = run_protocol(
        tables, spec, backend=backend, with_stats=True,
        with_state=True, init_state=start,
        transactional=transactional, patience=patience, trace=trace, **kw,
    )
    _, stats, new = out[:3]
    buf = out[3] if tracing else None
    probes, rounds = stats.probes, stats.worked
    if warm:
        unresolved = jnp.any(
            (new.lock < 0) & (tables.n_valid > 0), axis=1
        ) & jnp.any(start.lock >= 0, axis=1)
        cout = run_protocol(
            tables, spec, backend=backend, with_stats=True,
            with_state=True, init_state=cold_state(t, n),
            transactional=transactional, patience=patience, trace=trace,
            **kw,
        )
        _, cstats, cnew = cout[:3]
        use_cold = unresolved & (cstats.locked > stats.locked)
        new = jax.tree_util.tree_map(
            lambda c, w: jnp.where(
                use_cold.reshape((t,) + (1,) * (w.ndim - 1)), c, w
            ),
            cnew, new,
        )
        if tracing:
            from repro.obs.trace import merge_traces

            buf = merge_traces(use_cold, cout[3], buf)
        probes = probes + jnp.where(unresolved, cstats.probes, 0)
        rounds = rounds + jnp.where(unresolved, cstats.worked, 0)
    if tracing:
        return new, probes, rounds, buf
    return new, probes, rounds


def run_timeline_impl(
    cfg,
    units: UnitSamples,
    timeline: Timeline,
    variations=None,
    *,
    scheme: str = "protocol_lta",
    warm: bool = True,
    transactional: bool = True,
    patience: int | None = 4,
    hysteresis=0.0,
    backend: str | None = None,
    init_state: ProtocolState | None = None,
    trace: int | None = None,
):
    """Drive the protocol engine along a drift/event timeline.

    warm=True re-arbitrates incrementally from the carried lock state;
    warm=False is the cold baseline (full re-init every step; the carry
    still threads through so broken/churn are measured step over step).
    Both run the engine with the same ``transactional``/``patience``
    settings so the probe comparison is apples to apples.  Returns
    ``(final_state, TemporalStats)`` — the state is resumable via
    ``init_state`` after ``slice_timeline`` (see ``save_campaign``).

    trace: flight-recorder ring capacity per step (see ``run_protocol``);
    the return gains a third element — a ``TraceBuffer`` with a leading
    (S,) step axis (the scan stacks each step's ring).  None (default)
    keeps the legacy two-element return and the legacy jaxpr bit for bit.
    Only protocol schemes record (one-shot arbiters run no engine).
    """
    from .api import _build_tables, scheme_spec  # local: avoid import cycle

    over = as_variations(variations)
    tr = over.resolve("tr_mean", cfg)
    sys = instantiate(cfg, units, over)
    spec = chain_spec(cfg.s)
    t, n = sys.laser.shape
    kw = _protocol_kwargs(scheme)
    if kw is None and warm:
        raise ValueError(
            f"scheme {scheme!r} is one-shot: it carries no protocol state, "
            "so only cold (warm=False) re-arbitration is defined"
        )
    if kw is None and trace is not None:
        raise ValueError(
            f"scheme {scheme!r} is one-shot: it never runs the protocol "
            "engine, so there is no flight recorder to enable (trace=None)"
        )
    tracing = trace is not None
    arbiter = scheme_spec(scheme).arbiter
    state0 = cold_state(t, n) if init_state is None else init_state

    def step(state, tl):
        sys_s = apply_axis_transforms(
            sys,
            Variations(thermal_drift=tl.ring_drift, comb_wander=tl.laser_drift),
            cfg,
        )
        vis = jnp.broadcast_to(
            tl.lane_alive[None, None, :] & tl.ring_alive[None, :, None],
            (t, n, n),
        )
        tables = _build_tables(cfg, sys_s, tr, backend, visible=vis)
        prev_lock = state.lock
        reval, kept = revalidate_state(
            tables, state, tr=tr * sys_s.tr_unit, hysteresis=hysteresis
        )
        broken = jnp.sum(
            ((prev_lock >= 0) & (reval.lock < 0)).astype(jnp.int32), axis=1
        )
        if kw is None:
            asg = arbiter(cfg, tables, spec, backend=backend)
            new = ProtocolState(
                lock=asg.wl.astype(jnp.int32),
                entry=asg.entry.astype(jnp.int32),
                cursor=jnp.maximum(asg.entry.astype(jnp.int32), 0),
                probes=jnp.zeros((t,), jnp.int32),
            )
            probes = jnp.zeros((t,), jnp.int32)
            rounds = jnp.zeros((t,), jnp.int32)
        else:
            start = (reval if warm else cold_state(t, n))._replace(
                probes=jnp.zeros((t,), jnp.int32)
            )
            relock = protocol_relock(
                tables, spec, start, warm=warm, backend=backend,
                transactional=transactional, patience=patience, kw=kw,
                trace=trace,
            )
            new, probes, rounds = relock[:3]
        churn = jnp.sum(
            (kept & (new.lock != prev_lock)).astype(jnp.int32), axis=1
        )
        # Feasibility of the live bus: every live ring matchable to a
        # distinct live line within TR (dead rings exempt, dead lanes gone).
        reach = (
            reach_matrix(sys_s, tr)
            & tl.lane_alive[None, None, :]
            & tl.ring_alive[None, :, None]
        )
        match_wl, _ = max_matching(adjacency_bitmask(reach))
        n_live = jnp.sum(tl.ring_alive.astype(jnp.int32))
        feasible = jnp.sum((match_wl >= 0).astype(jnp.int32), axis=1) >= n_live
        out = TemporalStats(
            probes=probes,
            rounds=rounds,
            locked=jnp.sum((new.lock >= 0).astype(jnp.int32), axis=1),
            broken=broken,
            churn=churn,
            feasible=feasible,
        )
        return (new, (out, relock[3])) if tracing else (new, out)

    final, ys = jax.lax.scan(step, state0, timeline)
    if tracing:
        return final, ys[0], ys[1]
    return final, ys


run_timeline = jax.jit(
    run_timeline_impl,
    static_argnames=(
        "cfg", "scheme", "warm", "transactional", "patience", "backend",
        "trace",
    ),
)


def save_campaign(ckpt_dir, step: int, state: ProtocolState) -> None:
    """Checkpoint a timeline campaign's carry state after ``step`` steps
    (``checkpoint/store.py`` is the carrier; atomic, latest-k retained)."""
    from repro.checkpoint import store

    store.save(ckpt_dir, step, state)


def restore_campaign(
    ckpt_dir, n_trials: int, n_ch: int, step: int | None = None
) -> tuple[int, ProtocolState]:
    """Load ``(step, state)`` to resume a campaign: continue with
    ``run_timeline(..., timeline=slice_timeline(tl, step), init_state=state)``."""
    from repro.checkpoint import store

    if step is None:
        step = store.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no campaign checkpoint under {ckpt_dir}")
    return step, store.restore(ckpt_dir, step, cold_state(n_trials, n_ch))
