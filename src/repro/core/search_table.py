"""Microring search tables (paper §V-A, Fig. 9-10).

During a wavelength search the tuner sweeps delta in [0, TR_i]; a peak in
intra-cavity power occurs whenever any comb resonance
lambda_ring,i + j*FSR_i + delta aligns with a *visible* laser line.  The
recorded "tuner codes" are monotone in delta, so the wavelength-domain search
table is the ascending list of (delta, wavelength-id) peaks.

The oblivious algorithms only ever use entry *indices* and masking events —
the wavelength ids carried here are simulator-side ground truth used by the
evaluator (outcome classification), never by the arbiter.

Tables are fixed-size (MAX_E entries) with sentinel padding for batching:
delta = +inf, wl = -1.  If TR > FSR a laser line aliases into multiple
entries (multi-FSR, paper §V-B); MAX_E = 3*N covers TR up to ~2.5 FSR,
beyond every sweep in the paper.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .sampling import SystemBatch

SENTINEL = jnp.float32(jnp.inf)


class SearchTables(NamedTuple):
    delta: jax.Array   # (T, N, E) ascending tuning distances; +inf padded
    wl: jax.Array      # (T, N, E) laser line index of each peak; -1 padded
    n_valid: jax.Array  # (T, N) number of valid entries per ring

    @property
    def max_entries(self) -> int:
        return self.delta.shape[-1]


def max_entries_for(n_ch: int) -> int:
    return 3 * n_ch


def build_search_tables(
    sys: SystemBatch,
    tr_mean: float,
    *,
    visible: jax.Array | None = None,
    max_alias: int = 8,
    max_entries: int | None = None,
) -> SearchTables:
    """Construct per-ring search tables for a batch of trials.

    visible: optional bool array of lines present on the bus — (T, N_wl)
      (same for every ring) or (T, N_ring, N_wl) (per searching ring, for
      position-dependent capture).  None = all lines visible.  Used for
      re-searches while other rings hold locks.
    """
    T, N = sys.laser.shape
    E = max_entries_for(N) if max_entries is None else max_entries
    j = jnp.arange(-max_alias, max_alias + 1, dtype=jnp.float32)  # (J,)

    # delta[t, i, k, j] = laser_k - ring_i - j*FSR_i
    d = sys.laser[:, None, :, None] - sys.ring[:, :, None, None] - (
        j[None, None, None, :] * sys.fsr[:, :, None, None]
    )  # (T, N, N, J)
    tr = (tr_mean * sys.tr_unit)[:, :, None, None]
    ok = (d >= 0.0) & (d <= tr)
    if visible is not None:
        vis = visible[:, None, :, None] if visible.ndim == 2 else visible[:, :, :, None]
        ok = ok & vis

    dflat = jnp.where(ok, d, SENTINEL).reshape(T, N, -1)
    kflat = jnp.broadcast_to(
        jnp.arange(N, dtype=jnp.int32)[None, None, :, None], d.shape
    ).reshape(T, N, -1)

    order = jnp.argsort(dflat, axis=-1)[..., :E]
    delta = jnp.take_along_axis(dflat, order, axis=-1)
    wl = jnp.where(jnp.isfinite(delta), jnp.take_along_axis(kflat, order, axis=-1), -1)
    n_valid = jnp.sum(jnp.isfinite(delta), axis=-1).astype(jnp.int32)
    return SearchTables(delta=delta, wl=wl, n_valid=n_valid)


def mask_wavelength(tables: SearchTables, ring: int | jax.Array, wl_id: jax.Array) -> jax.Array:
    """Indices of entries of ``ring``'s table whose line equals wl_id.

    Returns (T,) int32 index of the *first* masked entry, or -1 if none —
    exactly what a victim ring observes when an aggressor captures a line
    (the victim re-runs its search and diffs against its original table).
    """
    wl_row = tables.wl[:, ring, :]                       # (T, E)
    hit = wl_row == wl_id[:, None]
    first = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    return jnp.where(hit.any(axis=-1), first, -1)
