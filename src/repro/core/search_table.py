"""Microring search tables (paper §V-A, Fig. 9-10).

During a wavelength search the tuner sweeps delta in [0, TR_i]; a peak in
intra-cavity power occurs whenever any comb resonance
lambda_ring,i + j*FSR_i + delta aligns with a *visible* laser line.  The
recorded "tuner codes" are monotone in delta, so the wavelength-domain search
table is the ascending list of (delta, wavelength-id) peaks.

The oblivious algorithms only ever use entry *indices* and masking events —
the wavelength ids carried here are simulator-side ground truth used by the
evaluator (outcome classification), never by the arbiter.

Tables are fixed-size (MAX_E entries) with sentinel padding for batching:
delta = +inf, wl = -1.  If TR > FSR a laser line aliases into multiple
entries (multi-FSR, paper §V-B); MAX_E = 3*N covers TR up to ~2.5 FSR,
beyond every sweep in the paper.

Memory model
------------

A ring sees K = N * J candidate peaks (J = 2*max_alias + 1 FSR aliases per
line) of which only E = 3*N survive, so materializing the full (T, N, K)
candidate tensor plus an argsort — the pre-streaming implementation, kept
below as ``build_search_tables_dense`` — costs O(T*N*(N*J + E)) while the
answer only needs O(T*N*E).  ``build_search_tables`` instead *streams* the
candidate axis: a ``lax.fori_loop`` walks (line-block, ring-block) tiles,
materializes one small (T, R, L*J) candidate block at a time, and
**rank-merges** it into the persistent sorted (T, N, E) table: the block is
put in ascending order (a stable width-L*J sort — or, for single-line
blocks, a sort-free rotation; see ``build_search_tables``), a
``searchsorted``-style rank pass places each candidate against the buffer,
and the E survivors are materialized by gathering through the merge-path
inverse (candidates ranked past E drop out).  No E-wide sort ever runs:
per step the table-width work is a log-depth batched bisection plus two
gathers instead of the former stable sort of width E + L*J, which is what
buys the paper-scale speedup at forced L=1 tilings.  (Everything is
phrased gather-only on purpose: CPU XLA lowers both scatter and vmapped
``searchsorted`` to serial per-element loops, measured ~10x slower than
this formulation at paper scale.)  Peak working set is the
persistent table (8 bytes/entry: f32 delta + i32 wl) plus a bounded merge
transient chosen by ``merge_plan`` — O(T*N*E + T*R*(E + L*J)) — which is
what lets a paper-scale (100x100 trial) WDM32 point fit the sweep engine's
256 MB chunk budget (~6x below the dense build; see
``repro.core.sweep.scheme_point_bytes``).

Bit-exactness: the dense path's stable argsort orders candidates by
(delta, flat candidate index) with flat index = line*J + alias.  The
rank-merge preserves exactly that order:

  * blocks are consumed in ascending line-major order, so every buffer
    entry has a smaller flat index than every incoming candidate;
  * the rank pass counts buffer entries ``<=`` each candidate
    (``searchsorted(buffer, block, side="right")``), so buffer entries win
    all delta ties — the flat-index order;
  * within a block, a *stable* delta sort keeps tied candidates in flat
    order (blocks are laid out line-major/alias-minor);
  * buffer and candidate positions tile [0, E + L*J) with no collisions
    (the classic merge-path bijection), so gathering through its inverse
    reproduces the first E entries of the full stable sort exactly.

For L=1 the block sort is elided entirely: within one line, delta is
monotone in the alias index (step = FSR >= 0), so enumerating aliases in
*descending* j order yields ascending deltas with the masked (+inf)
entries in one run at each end, and a single rotation moves them to the
back.  Tied candidates inside one line carry identical (delta, wl)
payloads (same line id; FSR == 0 collapses the deltas too), so any
within-line tie order produces bit-identical tables.  All of this is
guarded by always-on deterministic oracle tests, hypothesis variants, and
the kernel parity suite.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .sampling import SystemBatch

SENTINEL = jnp.float32(jnp.inf)

#: Merge-transient sizing for the streaming builder: the per-step sort
#: scratch is kept under min(max(table bytes, FLOOR), CAP).  The 20 MiB cap
#: is what leaves a paper-scale WDM32 point inside the sweep engine's
#: 256 MiB chunk budget next to its 245.8 MB persistent tables.
_MERGE_FLOOR_BYTES = 4 * 1024 * 1024
_MERGE_CAP_BYTES = 20 * 1024 * 1024

#: Max per-row compare-reduction size (block width x table width) for the
#: rank-merge's fused small-block path; larger tiles bisect instead (see
#: ``build_search_tables``).
_RANK_FUSE_MAX = 4096


class SearchTables(NamedTuple):
    delta: jax.Array   # (T, N, E) ascending tuning distances; +inf padded
    wl: jax.Array      # (T, N, E) laser line index of each peak; -1 padded
    n_valid: jax.Array  # (T, N) number of valid entries per ring

    @property
    def max_entries(self) -> int:
        return self.delta.shape[-1]


def max_entries_for(n_ch: int) -> int:
    return 3 * n_ch


class MergePlan(NamedTuple):
    """Static tiling of the streaming builder at one (T, N, J, E) shape.

    line_block (L) and ring_block (R) divide N; each fori_loop step
    rank-merges the (T, R, L*J) candidate tile of one (line-block,
    ring-block) pair into the table.  ``table_bytes`` is the persistent
    output footprint (f32 delta + i32 wl + i32 n_valid);
    ``transient_bytes`` bounds the per-step scratch (buffer slice in +
    scatter out at width E, block/sorted/rank arrays at width L*J).
    """

    line_block: int
    ring_block: int
    table_bytes: int
    transient_bytes: int

    @property
    def total_bytes(self) -> int:
        return self.table_bytes + self.transient_bytes


def _divisors_desc(n: int) -> list[int]:
    return [d for d in range(n, 0, -1) if n % d == 0]


def merge_plan(
    n_trials: int, n_ch: int, *, max_alias: int = 8, max_entries: int | None = None
) -> MergePlan:
    """Choose the streaming tile sizes for a (T, N) system batch.

    Step count (N^2 / (L*R)) falls with the largest line block, so L is the
    largest divisor of N whose transient fits the cap; R then grows to cut
    the step count further while still fitting.  The same plan drives the
    builder and the sweep engine's ``scheme_point_bytes`` accounting, so
    the two cannot drift.
    """
    n_j = 2 * max_alias + 1
    e_req = max_entries_for(n_ch) if max_entries is None else max_entries
    e = min(e_req, n_ch * n_j)
    table = n_trials * n_ch * (e * 8 + 4)  # f32 delta + i32 wl + i32 n_valid

    def transient(l: int, r: int) -> int:
        # E-wide tiles: buffer slice in + merged tile out (f32 + i32 each)
        # plus the buffer-rank scatter positions; L*J-wide: the candidate
        # block, its sorted copy, and the rank/position arrays (validated
        # against compiled memory_analysis in tests/test_memory)
        return n_trials * r * (16 * e + 24 * l * n_j)

    cap = min(max(table, _MERGE_FLOOR_BYTES), _MERGE_CAP_BYTES)
    line = 1
    for l in _divisors_desc(n_ch):
        if transient(l, 1) <= cap:
            line = l
            break
    ring = 1
    for r in _divisors_desc(n_ch):
        if transient(line, r) <= cap:
            ring = r
            break
    return MergePlan(
        line_block=line,
        ring_block=ring,
        table_bytes=table,
        transient_bytes=transient(line, ring),
    )


def _candidate_block(laser_b, ring_b, fsr_b, tr_b, j):
    """Masked candidate deltas of one (line-block, ring-block) tile.

    laser_b: (T, L) lines; ring_b/fsr_b/tr_b: (T, R) rings; j: (J,) aliases.
    Returns (delta (T, R, L, J) with +inf where unreachable, ok (T, R, L, J)).
    Arithmetic matches the dense build term-for-term ((laser - ring) -
    j*FSR, then the [0, TR] window) so values are bit-identical.
    """
    d = (laser_b[:, None, :, None] - ring_b[:, :, None, None]) - (
        j[None, None, None, :] * fsr_b[:, :, None, None]
    )
    ok = (d >= 0.0) & (d <= tr_b[:, :, None, None])
    return d, ok


def _searchsorted_rows(keys: jax.Array, vals: jax.Array, side: str) -> jax.Array:
    """Row-wise ``searchsorted`` over the last axis, phrased as a fixed-depth
    vectorized bisection.

    keys: (..., A) ascending per row; vals: (..., V) (same leading dims);
    returns (..., V) int32 insertion points.  ``jnp.searchsorted`` (vmapped)
    and scatter both lower to serial per-element loops on CPU XLA, and a
    broadcast compare-reduction materializes the (..., V, A) tensor — this
    keeps per-step scratch at O(V) rows and runs as log2(A) batched gathers.
    """
    a = keys.shape[-1]
    lo = jnp.zeros(vals.shape, jnp.int32)
    hi = jnp.full(vals.shape, a, jnp.int32)
    for _ in range(max(1, math.ceil(math.log2(a + 1)))):
        mid = (lo + hi) >> 1
        km = jnp.take_along_axis(keys, jnp.minimum(mid, a - 1), axis=-1)
        pred = (km <= vals) if side == "right" else (km < vals)
        active = lo < hi                       # mid is in-range iff active
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
    return lo




def build_search_tables(
    sys: SystemBatch,
    tr_mean: float,
    *,
    visible: jax.Array | None = None,
    max_alias: int = 8,
    max_entries: int | None = None,
) -> SearchTables:
    """Construct per-ring search tables for a batch of trials (streaming).

    visible: optional bool array of lines present on the bus — (T, N_wl)
      (same for every ring) or (T, N_ring, N_wl) (per searching ring, for
      position-dependent capture).  None = all lines visible.  Used for
      re-searches while other rings hold locks.

    Bit-identical to ``build_search_tables_dense`` (the retired full-tensor
    implementation, kept as the oracle) with ~6x less peak memory; see the
    module docstring for the merge scheme and the tie-order argument.
    """
    T, N = sys.laser.shape
    n_j = 2 * max_alias + 1
    e_req = max_entries_for(N) if max_entries is None else max_entries
    e = min(e_req, N * n_j)  # dense argsort also yields min(E, K) columns
    plan = merge_plan(T, N, max_alias=max_alias, max_entries=max_entries)
    lb, rb = plan.line_block, plan.ring_block
    n_lb, n_rb = N // lb, N // rb
    m = lb * n_j

    # L == 1 needs no block sort: descending-j enumeration makes the one
    # line's deltas ascend, and a rotation parks the masked run at the end
    # (see the module docstring for why within-line tie order is free).
    j = jnp.arange(-max_alias, max_alias + 1, dtype=jnp.float32)  # (J,)
    if lb == 1:
        j = j[::-1]
    tr = tr_mean * sys.tr_unit                                    # (T, N)
    laser, ring, fsr = sys.laser, sys.ring, sys.fsr

    def body(step, carry):
        delta, wl = carry
        # Line blocks ascend for each ring block: the rank-merge then sees
        # candidates in dense flat order (line-major, alias-minor), so
        # buffer entries always hold the smaller flat indices.
        l0 = (step // n_rb) * lb
        r0 = (step % n_rb) * rb
        laser_b = jax.lax.dynamic_slice_in_dim(laser, l0, lb, axis=1)
        ring_b = jax.lax.dynamic_slice_in_dim(ring, r0, rb, axis=1)
        fsr_b = jax.lax.dynamic_slice_in_dim(fsr, r0, rb, axis=1)
        tr_b = jax.lax.dynamic_slice_in_dim(tr, r0, rb, axis=1)
        d, ok = _candidate_block(laser_b, ring_b, fsr_b, tr_b, j)
        if visible is not None:
            if visible.ndim == 2:
                vis = jax.lax.dynamic_slice_in_dim(visible, l0, lb, axis=1)
                ok = ok & vis[:, None, :, None]
            else:
                vis = jax.lax.dynamic_slice_in_dim(visible, r0, rb, axis=1)
                vis = jax.lax.dynamic_slice_in_dim(vis, l0, lb, axis=2)
                ok = ok & vis[:, :, :, None]
        blk_d = jnp.where(ok, d, SENTINEL).reshape(d.shape[0], rb, m)
        if lb == 1:
            # Ascending already, except the +inf run of the below-window
            # aliases at the front: rotate it behind the valid run.  One
            # line per block, so wl is constant and needs no permutation.
            s = jnp.argmax(ok.reshape(d.shape[0], rb, m), axis=-1)
            idx = (s[..., None] + jnp.arange(m, dtype=jnp.int32)) % m
            blk_d = jnp.take_along_axis(blk_d, idx, axis=-1)
            # Masked entries carry wl = -1 already (the dense output
            # convention), so the loop carry needs no post-pass and XLA can
            # alias it straight into the output buffer.
            blk_w = jnp.where(jnp.isinf(blk_d), -1, l0.astype(jnp.int32))
        else:
            blk_w = jnp.where(
                ok,
                l0 + jnp.arange(lb, dtype=jnp.int32)[None, None, :, None],
                -1,
            ).reshape(d.shape[0], rb, m)
            # Stable: tied candidates stay in flat (line-major/alias-minor)
            # order, exactly like the dense stable argsort.
            blk_d, blk_w = jax.lax.sort(
                (blk_d, blk_w), dimension=-1, is_stable=True, num_keys=1
            )

        buf_d = jax.lax.dynamic_slice_in_dim(delta, r0, rb, axis=1)
        buf_w = jax.lax.dynamic_slice_in_dim(wl, r0, rb, axis=1)
        # Merge-path ranks: rank_c = searchsorted(buf_d, blk_d, "right").
        # "right" semantics make every buffer entry win delta ties against
        # the block — the flat-index order — and block candidate k lands at
        # pos_c[k], strictly ascending, tiling [0, e + m) with the buffer
        # positions.  nc(g) inverts that map: the number of block
        # candidates placed before output slot g, i.e.
        # searchsorted(pos_c, g, "left").  Narrow blocks (the forced L=1
        # tiling of paper-scale points) use a compare-reduction XLA fuses
        # row-wise — measured ~4x faster than the bisection there — while
        # wide blocks switch to the bisection so the (T, R, E, M) compare
        # tensor is never materialized.
        giota = jnp.arange(e, dtype=jnp.int32)
        if m * e <= _RANK_FUSE_MAX:
            rank_c = jnp.sum(
                buf_d[..., None, :] <= blk_d[..., :, None], axis=-1,
                dtype=jnp.int32,
            )
            pos_c = rank_c + jnp.arange(m, dtype=jnp.int32)       # (T, R, M)
            nc = jnp.sum(
                pos_c[..., None, :] < giota[:, None], axis=-1, dtype=jnp.int32
            )                                                     # (T, R, E)
        else:
            rank_c = _searchsorted_rows(buf_d, blk_d, "right")
            pos_c = rank_c + jnp.arange(m, dtype=jnp.int32)
            nc = _searchsorted_rows(
                pos_c, jnp.broadcast_to(giota, buf_d.shape), "left"
            )
        at_g = jnp.take_along_axis(pos_c, jnp.minimum(nc, m - 1), axis=-1)
        src = jnp.where((nc < m) & (at_g == giota), e + nc, giota - nc)
        out_d = jnp.take_along_axis(
            jnp.concatenate([buf_d, blk_d], axis=-1), src, axis=-1
        )
        out_w = jnp.take_along_axis(
            jnp.concatenate([buf_w, blk_w], axis=-1), src, axis=-1
        )
        delta = jax.lax.dynamic_update_slice_in_dim(delta, out_d, r0, axis=1)
        wl = jax.lax.dynamic_update_slice_in_dim(wl, out_w, r0, axis=1)
        return delta, wl

    delta0 = jnp.full((T, N, e), SENTINEL, jnp.float32)
    wl0 = jnp.full((T, N, e), -1, jnp.int32)
    delta, wl = jax.lax.fori_loop(0, n_lb * n_rb, body, (delta0, wl0))
    # Sentinel wl is maintained inside the loop (blocks mask to -1 before
    # the merge), so both carries alias the outputs — no full-table temps.
    n_valid = jnp.sum(jnp.isfinite(delta), axis=-1).astype(jnp.int32)
    return SearchTables(delta=delta, wl=wl, n_valid=n_valid)


def build_search_tables_dense(
    sys: SystemBatch,
    tr_mean: float,
    *,
    visible: jax.Array | None = None,
    max_alias: int = 8,
    max_entries: int | None = None,
) -> SearchTables:
    """Full-tensor reference builder (the pre-streaming implementation).

    Materializes the (T, N, N, J) candidate tensor and argsorts the whole
    candidate axis to keep the first E entries — O(T*N*(N*J + E)) peak
    memory.  Kept as the golden oracle for ``build_search_tables``; never
    use on a hot path at paper scale.
    """
    T, N = sys.laser.shape
    E = max_entries_for(N) if max_entries is None else max_entries
    j = jnp.arange(-max_alias, max_alias + 1, dtype=jnp.float32)  # (J,)

    # delta[t, i, k, j] = laser_k - ring_i - j*FSR_i
    d = sys.laser[:, None, :, None] - sys.ring[:, :, None, None] - (
        j[None, None, None, :] * sys.fsr[:, :, None, None]
    )  # (T, N, N, J)
    tr = (tr_mean * sys.tr_unit)[:, :, None, None]
    ok = (d >= 0.0) & (d <= tr)
    if visible is not None:
        vis = visible[:, None, :, None] if visible.ndim == 2 else visible[:, :, :, None]
        ok = ok & vis

    dflat = jnp.where(ok, d, SENTINEL).reshape(T, N, -1)
    kflat = jnp.broadcast_to(
        jnp.arange(N, dtype=jnp.int32)[None, None, :, None], d.shape
    ).reshape(T, N, -1)

    order = jnp.argsort(dflat, axis=-1)[..., :E]
    delta = jnp.take_along_axis(dflat, order, axis=-1)
    wl = jnp.where(jnp.isfinite(delta), jnp.take_along_axis(kflat, order, axis=-1), -1)
    n_valid = jnp.sum(jnp.isfinite(delta), axis=-1).astype(jnp.int32)
    return SearchTables(delta=delta, wl=wl, n_valid=n_valid)


def mask_wavelength(tables: SearchTables, ring: int | jax.Array, wl_id: jax.Array) -> jax.Array:
    """Indices of entries of ``ring``'s table whose line equals wl_id.

    Returns (T,) int32 index of the *first* masked entry, or -1 if none —
    exactly what a victim ring observes when an aggressor captures a line
    (the victim re-runs its search and diffs against its original table).
    """
    wl_row = tables.wl[:, ring, :]                       # (T, E)
    hit = wl_row == wl_id[:, None]
    first = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    return jnp.where(hit.any(axis=-1), first, -1)
