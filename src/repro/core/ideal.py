"""Ideal, wavelength-aware arbitration models (paper §III-A).

These evaluate the *policy* layer: given full wavelength knowledge, can the
system be arbitrated under LtD / LtC / LtA?  Used for AFP and as the
conditioning event of CAFP.  Each policy also exposes a per-trial *minimum
mean tuning range* — the smallest TR mean achieving success — from which the
paper's Fig. 5-8 "minimum tuning range" curves are direct max-reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .matching import bottleneck_matching_threshold, has_perfect_matching
from .reach import reach_matrix, scaled_residual
from .sampling import SystemBatch


def _gather_order(res: jax.Array, s: jax.Array, shift: jax.Array | int) -> jax.Array:
    """res[t, i, (s_i + shift) % N] for each ring i -> (T, N)."""
    n = res.shape[-1]
    idx = (jnp.asarray(s) + shift) % n
    return res[:, jnp.arange(n), idx]


def ltd_min_tr(sys: SystemBatch, s: jax.Array) -> jax.Array:
    """(T,) minimum mean TR for Lock-to-Deterministic success."""
    res = scaled_residual(sys)
    return _gather_order(res, s, 0).max(axis=-1)


def ltc_min_tr(sys: SystemBatch, s: jax.Array) -> jax.Array:
    """(T,) minimum mean TR for Lock-to-Cyclic success (best cyclic shift)."""
    res = scaled_residual(sys)
    n = res.shape[-1]
    per_shift = jax.vmap(lambda c: _gather_order(res, s, c).max(axis=-1))(jnp.arange(n))
    return per_shift.min(axis=0)


def ltc_best_shift(sys: SystemBatch, s: jax.Array) -> jax.Array:
    """(T,) argmin cyclic shift c — the wavelength-aware LtC assignment."""
    res = scaled_residual(sys)
    n = res.shape[-1]
    per_shift = jax.vmap(lambda c: _gather_order(res, s, c).max(axis=-1))(jnp.arange(n))
    return jnp.argmin(per_shift, axis=0).astype(jnp.int32)


def lta_min_tr(sys: SystemBatch) -> jax.Array:
    """(T,) minimum mean TR for Lock-to-Any success (bottleneck matching)."""
    return bottleneck_matching_threshold(scaled_residual(sys))


def success(sys: SystemBatch, policy: str, s: jax.Array, tr_mean: float) -> jax.Array:
    """(T,) bool ideal arbitration success at the given mean tuning range."""
    if policy == "ltd":
        return ltd_min_tr(sys, s) <= tr_mean
    if policy == "ltc":
        return ltc_min_tr(sys, s) <= tr_mean
    if policy == "lta":
        return has_perfect_matching(reach_matrix(sys, tr_mean))
    raise ValueError(f"unknown policy {policy!r}")


def min_tr(sys: SystemBatch, policy: str, s: jax.Array) -> jax.Array:
    """(T,) per-trial minimum mean tuning range for the policy."""
    if policy == "ltd":
        return ltd_min_tr(sys, s)
    if policy == "ltc":
        return ltc_min_tr(sys, s)
    if policy == "lta":
        return lta_min_tr(sys)
    raise ValueError(f"unknown policy {policy!r}")
