"""High-level arbitration API: the entry points used by benchmarks, the
optics runtime and the examples.

All heavy functions are jitted with the (hashable, frozen) ArbitrationConfig
static; sigma values and tuning ranges are traced scalars so parameter sweeps
reuse one compilation.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ideal, metrics
from .grid import ArbitrationConfig
from .outcomes import Outcome, classify
from .relation import chain_spec, relation_search
from .sampling import SystemBatch, UnitSamples, draw_unit_samples, instantiate
from .lta_retry import sequential_retry
from .search_table import build_search_tables
from .sequential import sequential_tuning
from .ssm import Assignment, single_step_matching

SCHEMES = ("seq", "rs_ssm", "vtrs_ssm", "seq_retry")
SCHEME_POLICY = {"seq": "ltc", "rs_ssm": "ltc", "vtrs_ssm": "ltc",
                 "seq_retry": "lta"}


def oblivious_arbitrate(
    cfg: ArbitrationConfig, sys: SystemBatch, tr_mean, scheme: str
) -> Assignment:
    """Run a wavelength-oblivious arbitration scheme on a system batch."""
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    spec = chain_spec(cfg.s)
    if scheme == "seq":
        return sequential_tuning(tables, spec)
    if scheme == "rs_ssm":
        ri = relation_search(tables, spec, variation_tolerant=False)
        return single_step_matching(tables, ri, spec)
    if scheme == "vtrs_ssm":
        ri = relation_search(tables, spec, variation_tolerant=True)
        return single_step_matching(tables, ri, spec)
    if scheme == "seq_retry":   # beyond-paper oblivious LtA (§V-E future work)
        return sequential_retry(tables)
    raise ValueError(f"unknown scheme {scheme!r}")


class EvalResult(NamedTuple):
    afp: jax.Array          # policy-level failure probability (ideal LtC)
    cafp: jax.Array         # conditional algorithmic failure (Eq. 6)
    lock_err: jax.Array     # CAFP portion from zero/dup lock errors
    order_err: jax.Array    # CAFP portion from lane-order errors
    alg_success: jax.Array  # (T,) bool
    ideal_ok: jax.Array     # (T,) bool


@partial(jax.jit, static_argnames=("cfg", "scheme"))
def evaluate_scheme(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    scheme: str,
    tr_mean,
    sigma_rlv=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    sigma_go=None,
    sigma_llv_frac=None,
) -> EvalResult:
    """Instantiate systems, run the scheme, and score CAFP vs ideal LtC."""
    sys = instantiate(
        cfg,
        units,
        sigma_rlv=sigma_rlv,
        sigma_fsr_frac=sigma_fsr_frac,
        sigma_tr_frac=sigma_tr_frac,
        sigma_go=sigma_go,
        sigma_llv_frac=sigma_llv_frac,
    )
    s = jnp.asarray(cfg.s)
    policy = SCHEME_POLICY[scheme]
    if policy == "lta":
        ideal_ok = ideal.lta_min_tr(sys) <= tr_mean
    else:
        ideal_ok = ideal.ltc_min_tr(sys, s) <= tr_mean
    assign = oblivious_arbitrate(cfg, sys, tr_mean, scheme)
    out = classify(assign, s, policy=policy)
    lock = (out.zero_lock | out.dup_lock) & ideal_ok
    order = out.order_err & ideal_ok
    return EvalResult(
        afp=metrics.afp(ideal_ok),
        cafp=metrics.cafp(out.success, ideal_ok),
        lock_err=jnp.mean(lock.astype(jnp.float32)),
        order_err=jnp.mean(order.astype(jnp.float32)),
        alg_success=out.success,
        ideal_ok=ideal_ok,
    )


@partial(jax.jit, static_argnames=("cfg", "policy"))
def evaluate_policy(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    tr_mean,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
):
    """Ideal-model policy evaluation: AFP at a given mean tuning range."""
    sys = instantiate(
        cfg,
        units,
        sigma_rlv=sigma_rlv,
        sigma_go=sigma_go,
        sigma_llv_frac=sigma_llv_frac,
        sigma_fsr_frac=sigma_fsr_frac,
        sigma_tr_frac=sigma_tr_frac,
        fsr_mean=fsr_mean,
    )
    ok = ideal.success(sys, policy, jnp.asarray(cfg.s), tr_mean)
    return metrics.afp(ok)


@partial(jax.jit, static_argnames=("cfg", "policy"))
def policy_min_tr(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
):
    """Minimum mean TR for complete arbitration success over the batch."""
    sys = instantiate(
        cfg,
        units,
        sigma_rlv=sigma_rlv,
        sigma_go=sigma_go,
        sigma_llv_frac=sigma_llv_frac,
        sigma_fsr_frac=sigma_fsr_frac,
        sigma_tr_frac=sigma_tr_frac,
        fsr_mean=fsr_mean,
    )
    per_trial = ideal.min_tr(sys, policy, jnp.asarray(cfg.s))
    return metrics.min_tr_for_complete_success(per_trial)


def make_units(cfg: ArbitrationConfig, seed: int, n_laser: int, n_ring: int) -> UnitSamples:
    return draw_unit_samples(jax.random.key(seed), cfg.grid.n_ch, n_laser, n_ring)


def shmoo(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    sigma_rlv_values: np.ndarray,
    tr_values: np.ndarray,
    *,
    policy: str | None = None,
    scheme: str | None = None,
) -> np.ndarray:
    """AFP (policy) or CAFP (scheme) over a sigma_rLV x TR grid — Fig. 4/14."""
    assert (policy is None) != (scheme is None)
    rows = []
    for srlv in sigma_rlv_values:
        row = []
        for tr in tr_values:
            if policy is not None:
                row.append(evaluate_policy(cfg, units, policy, tr, sigma_rlv=srlv))
            else:
                row.append(evaluate_scheme(cfg, units, scheme, tr, sigma_rlv=srlv).cafp)
        rows.append(jnp.stack(row))
    return np.asarray(jnp.stack(rows))
