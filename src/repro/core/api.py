"""High-level arbitration API: the entry points used by benchmarks, the
optics runtime and the examples.

All heavy functions are jitted with the (hashable, frozen) ArbitrationConfig
static; sigma values and tuning ranges are traced scalars so parameter sweeps
reuse one compilation.  The un-jitted ``*_impl`` bodies are exported for the
sweep engine (``repro.core.sweep``), which vmaps them over whole sigma x TR
grids inside a single jit.

Schemes are pluggable: ``register_scheme`` adds a wavelength-oblivious
arbiter to the dispatch registry used by ``oblivious_arbitrate`` and
``evaluate_scheme`` — no core edits needed to experiment with a new scheme.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ideal, metrics
from .grid import ArbitrationConfig
from .matching import adjacency_bitmask
from .outcomes import Outcome, classify
from .reach import reach_matrix, scaled_residual
from .relation import ChainSpec, chain_spec, relation_search
from .sampling import SystemBatch, UnitSamples, draw_unit_samples, instantiate
from .lta_retry import sequential_retry
from .search_table import SearchTables, build_search_tables
from .sequential import sequential_tuning
from .ssm import Assignment, single_step_matching

# An arbiter maps (cfg, tables, spec) -> Assignment using only oblivious
# primitives (entry indices and masking events; never wavelength values).
Arbiter = Callable[[ArbitrationConfig, SearchTables, ChainSpec], Assignment]


class SchemeSpec(NamedTuple):
    """Registry record for a wavelength-oblivious arbitration scheme."""

    name: str
    arbiter: Arbiter
    policy: str  # conditioning ideal policy for CAFP: "ltc" | "lta" | "ltd"


_SCHEME_REGISTRY: dict[str, SchemeSpec] = {}


def register_scheme(name: str, arbiter: Arbiter, *, policy: str = "ltc") -> SchemeSpec:
    """Register an oblivious arbitration scheme under ``name``.

    ``policy`` selects the ideal arbiter the scheme is scored against (CAFP
    conditioning event).  Registered names are accepted everywhere a scheme
    string is: ``oblivious_arbitrate``, ``evaluate_scheme`` and the sweep
    engine.  Names are jit-static cache keys, so re-binding a name after it
    has been evaluated would silently serve stale compiled code — duplicate
    registration is therefore an error; pick a fresh name to iterate.
    """
    if name in _SCHEME_REGISTRY:
        raise ValueError(f"scheme {name!r} already registered")
    if policy not in ("ltd", "ltc", "lta"):
        raise ValueError(f"unknown conditioning policy {policy!r}")
    spec = SchemeSpec(name=name, arbiter=arbiter, policy=policy)
    _SCHEME_REGISTRY[name] = spec
    return spec


def scheme_spec(name: str) -> SchemeSpec:
    try:
        return _SCHEME_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {registered_schemes()}"
        ) from None


def registered_schemes() -> tuple[str, ...]:
    return tuple(_SCHEME_REGISTRY)


register_scheme("seq", lambda cfg, tables, spec: sequential_tuning(tables, spec))
register_scheme(
    "rs_ssm",
    lambda cfg, tables, spec: single_step_matching(
        tables, relation_search(tables, spec, variation_tolerant=False), spec
    ),
)
register_scheme(
    "vtrs_ssm",
    lambda cfg, tables, spec: single_step_matching(
        tables, relation_search(tables, spec, variation_tolerant=True), spec
    ),
)
# beyond-paper oblivious LtA (§V-E future work)
register_scheme(
    "seq_retry", lambda cfg, tables, spec: sequential_retry(tables), policy="lta"
)

# Back-compat module-level views (the built-in schemes; later registrations
# are visible through registered_schemes()/scheme_spec()).
SCHEMES = registered_schemes()
SCHEME_POLICY = {n: s.policy for n, s in _SCHEME_REGISTRY.items()}


def _build_tables(cfg, sys: SystemBatch, tr_mean, backend: str | None):
    """Search tables via core jnp (backend=None) or the kernel wrappers."""
    if backend is None:
        return build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    from repro.kernels import ops  # local import: kernels layer is optional

    delta, wl, nv = ops.build_tables(
        sys.laser, sys.ring, sys.fsr, tr_mean * sys.tr_unit,
        max_alias=cfg.max_fsr_alias, backend=backend,
    )
    return SearchTables(delta=delta, wl=wl, n_valid=nv)


def _ideal_min_tr(cfg, sys: SystemBatch, policy: str, backend: str | None):
    """(T,) per-trial ideal minimum mean TR, optionally via the kernels."""
    if backend is None:
        return ideal.min_tr(sys, policy, jnp.asarray(cfg.s))
    from repro.kernels import ops

    if policy == "lta":
        return ops.bottleneck_threshold(scaled_residual(sys), backend=backend)
    ltd, ltc = ops.feasibility(
        sys.laser, sys.ring, sys.fsr, sys.tr_unit,
        s=tuple(int(v) for v in cfg.s), backend=backend,
    )
    return ltd if policy == "ltd" else ltc


def _ideal_success(cfg, sys: SystemBatch, policy: str, tr_mean, backend: str | None):
    """(T,) bool ideal arbitration success at the given mean tuning range."""
    if backend is None:
        return ideal.success(sys, policy, jnp.asarray(cfg.s), tr_mean)
    if policy == "lta":
        from repro.kernels import ops

        adj = adjacency_bitmask(reach_matrix(sys, tr_mean))
        _, ok = ops.perfect_matching(adj, backend=backend)
        return ok
    return _ideal_min_tr(cfg, sys, policy, backend) <= tr_mean


def oblivious_arbitrate(
    cfg: ArbitrationConfig,
    sys: SystemBatch,
    tr_mean,
    scheme: str,
    *,
    backend: str | None = None,
) -> Assignment:
    """Run a wavelength-oblivious arbitration scheme on a system batch."""
    tables = _build_tables(cfg, sys, tr_mean, backend)
    spec = chain_spec(cfg.s)
    return scheme_spec(scheme).arbiter(cfg, tables, spec)


class EvalResult(NamedTuple):
    afp: jax.Array          # policy-level failure probability (ideal LtC)
    cafp: jax.Array         # conditional algorithmic failure (Eq. 6)
    lock_err: jax.Array     # CAFP portion from zero/dup lock errors
    order_err: jax.Array    # CAFP portion from lane-order errors
    alg_success: jax.Array  # (T,) bool
    ideal_ok: jax.Array     # (T,) bool


def evaluate_scheme_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    scheme: str,
    tr_mean,
    sigma_rlv=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    sigma_go=None,
    sigma_llv_frac=None,
    fsr_mean=None,
    backend: str | None = None,
) -> EvalResult:
    """Instantiate systems, run the scheme, and score CAFP vs ideal LtC.

    Un-jitted body; vmap-safe (the sweep engine maps it over grid points).
    """
    sys = instantiate(
        cfg,
        units,
        sigma_rlv=sigma_rlv,
        sigma_fsr_frac=sigma_fsr_frac,
        sigma_tr_frac=sigma_tr_frac,
        sigma_go=sigma_go,
        sigma_llv_frac=sigma_llv_frac,
        fsr_mean=fsr_mean,
    )
    s = jnp.asarray(cfg.s)
    policy = scheme_spec(scheme).policy
    ideal_ok = _ideal_success(cfg, sys, policy, tr_mean, backend)
    assign = oblivious_arbitrate(cfg, sys, tr_mean, scheme, backend=backend)
    out = classify(assign, s, policy=policy)
    lock = (out.zero_lock | out.dup_lock) & ideal_ok
    order = out.order_err & ideal_ok
    return EvalResult(
        afp=metrics.afp(ideal_ok),
        cafp=metrics.cafp(out.success, ideal_ok),
        lock_err=jnp.mean(lock.astype(jnp.float32)),
        order_err=jnp.mean(order.astype(jnp.float32)),
        alg_success=out.success,
        ideal_ok=ideal_ok,
    )


evaluate_scheme = jax.jit(
    evaluate_scheme_impl, static_argnames=("cfg", "scheme", "backend")
)


def evaluate_policy_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    tr_mean,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
    backend: str | None = None,
):
    """Ideal-model policy evaluation: AFP at a given mean tuning range."""
    sys = instantiate(
        cfg,
        units,
        sigma_rlv=sigma_rlv,
        sigma_go=sigma_go,
        sigma_llv_frac=sigma_llv_frac,
        sigma_fsr_frac=sigma_fsr_frac,
        sigma_tr_frac=sigma_tr_frac,
        fsr_mean=fsr_mean,
    )
    ok = _ideal_success(cfg, sys, policy, tr_mean, backend)
    return metrics.afp(ok)


evaluate_policy = jax.jit(
    evaluate_policy_impl, static_argnames=("cfg", "policy", "backend")
)


def policy_trial_min_tr_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
    backend: str | None = None,
):
    """(T,) per-trial ideal minimum mean TR at the given sigma overrides.

    The sweep engine's TR-axis fast path: ideal success at mean TR t is
    exactly ``trial_min_tr <= t`` for every policy, so one min-TR evaluation
    prices the entire TR axis.
    """
    sys = instantiate(
        cfg,
        units,
        sigma_rlv=sigma_rlv,
        sigma_go=sigma_go,
        sigma_llv_frac=sigma_llv_frac,
        sigma_fsr_frac=sigma_fsr_frac,
        sigma_tr_frac=sigma_tr_frac,
        fsr_mean=fsr_mean,
    )
    return _ideal_min_tr(cfg, sys, policy, backend)


def policy_min_tr_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
    backend: str | None = None,
):
    """Minimum mean TR for complete arbitration success over the batch."""
    per_trial = policy_trial_min_tr_impl(
        cfg, units, policy,
        sigma_rlv=sigma_rlv, sigma_go=sigma_go, sigma_llv_frac=sigma_llv_frac,
        sigma_fsr_frac=sigma_fsr_frac, sigma_tr_frac=sigma_tr_frac,
        fsr_mean=fsr_mean, backend=backend,
    )
    return metrics.min_tr_for_complete_success(per_trial)


policy_min_tr = jax.jit(
    policy_min_tr_impl, static_argnames=("cfg", "policy", "backend")
)


def make_units(cfg: ArbitrationConfig, seed: int, n_laser: int, n_ring: int) -> UnitSamples:
    return draw_unit_samples(jax.random.key(seed), cfg.grid.n_ch, n_laser, n_ring)


def shmoo(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    sigma_rlv_values: np.ndarray,
    tr_values: np.ndarray,
    *,
    policy: str | None = None,
    scheme: str | None = None,
) -> np.ndarray:
    """AFP (policy) or CAFP (scheme) over a sigma_rLV x TR grid — Fig. 4/14.

    One jitted call via the sweep engine (see ``repro.core.sweep``).
    """
    from .sweep import sweep_policy, sweep_scheme  # avoid import cycle

    assert (policy is None) != (scheme is None)
    axes = {"sigma_rlv": sigma_rlv_values, "tr_mean": tr_values}
    if policy is not None:
        return np.asarray(sweep_policy(cfg, units, policy, axes))
    return np.asarray(sweep_scheme(cfg, units, scheme, axes).cafp)
