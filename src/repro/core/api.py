"""High-level arbitration API: the entry points used by benchmarks, the
optics runtime and the examples.

Evaluation is declarative: all variation/TR overrides travel in a single
``Variations`` pytree (``repro.core.variations``) instead of per-sigma
keyword arguments —

    from repro.core import Variations, evaluate_scheme
    r = evaluate_scheme(cfg, units, "vtrs_ssm",
                        variations=Variations(tr_mean=5.0, sigma_rlv=2.24))

``tr_mean`` may still be passed positionally as the operating point
(``evaluate_scheme(cfg, units, "seq", 5.0)``); the old ``sigma_* =``
keywords survive as deprecated shims with bit-identical numerics.  New
variation axes registered with ``register_axis`` are picked up here and by
the sweep engine with no signature changes.

All heavy functions are jitted with the (hashable, frozen) ArbitrationConfig
static; the ``Variations`` key set is part of the treedef (also static)
while its values are traced, so parameter sweeps reuse one compilation.
The un-jitted ``*_impl`` bodies are exported for the sweep engine
(``repro.core.sweep``), which vmaps them over whole grids inside one jit.

Schemes are pluggable and parametrizable: ``register_scheme`` adds a
wavelength-oblivious arbiter to the dispatch registry, and
``register_scheme_family`` stamps out parametrized variants (e.g. the
retry-budgeted ``seq_retry_r{1,2,4}``) whose static parameters are baked
into the registered name — names stay jit-static cache keys, so every
variant compiles once and shmoo grids / CAFP scoring come for free.
``SCHEMES`` and ``SCHEME_POLICY`` are live views of the registry: schemes
registered after import are immediately visible.
"""
from __future__ import annotations

import inspect
from collections.abc import Mapping as _MappingABC
from collections.abc import Sequence as _SequenceABC
from typing import Any, Callable, Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ideal, metrics
from .grid import ArbitrationConfig
from .matching import adjacency_bitmask
from .outcomes import Outcome, classify
from .reach import reach_matrix, scaled_residual
from .relation import ChainSpec, chain_spec, relation_search
from .sampling import SystemBatch, UnitSamples, draw_unit_samples, instantiate
from .lta_retry import sequential_retry
from .protocol import run_protocol
from .search_table import SearchTables, build_search_tables
from .sequential import sequential_tuning
from .ssm import Assignment, single_step_matching
from .variations import Variations, merge_legacy_overrides

# An arbiter maps (cfg, tables, spec) -> Assignment using only oblivious
# primitives (entry indices and masking events; never wavelength values).
# Registered arbiters additionally receive the engine's ``backend=`` keyword
# (None | "jnp" | "pallas" | "interpret"); ``register_scheme`` wraps legacy
# 3-argument arbiters so pure-jnp schemes may simply ignore it.
Arbiter = Callable[..., Assignment]


def _normalize_arbiter(arbiter: Callable[..., Assignment]) -> Arbiter:
    """Ensure a registered arbiter accepts the engine's ``backend`` keyword.

    Arbiters that already take ``backend`` (or ``**kwargs``) pass through
    untouched; legacy 3-argument arbiters are wrapped to swallow it, so
    existing registrations (and user schemes) keep working unchanged.
    """
    try:
        params = inspect.signature(arbiter).parameters
    except (TypeError, ValueError):
        params = None
    if params is not None and (
        "backend" in params
        or any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
    ):
        return arbiter

    def legacy(cfg, tables, spec, *, backend=None):
        del backend  # pure-jnp arbiter: backend selection has nothing to reach
        return arbiter(cfg, tables, spec)

    return legacy


class SchemeSpec(NamedTuple):
    """Registry record for a wavelength-oblivious arbitration scheme.

    ``params`` carries the static parameters a parametrized variant was
    built with (introspection only — the values are already baked into the
    arbiter closure, which is what keeps them jit-static).
    """

    name: str
    arbiter: Arbiter
    policy: str  # conditioning ideal policy for CAFP: "ltc" | "lta" | "ltd"
    params: tuple = ()


_SCHEME_REGISTRY: dict[str, SchemeSpec] = {}


def register_scheme(
    name: str,
    arbiter: Arbiter,
    *,
    policy: str = "ltc",
    params: Mapping[str, Any] | None = None,
) -> SchemeSpec:
    """Register an oblivious arbitration scheme under ``name``.

    ``policy`` selects the ideal arbiter the scheme is scored against (CAFP
    conditioning event).  ``params`` records the static parameters of a
    parametrized variant (see ``register_scheme_family``).  Registered names
    are accepted everywhere a scheme string is: ``oblivious_arbitrate``,
    ``evaluate_scheme`` and the sweep engine.  Names are jit-static cache
    keys, so re-binding a name after it has been evaluated would silently
    serve stale compiled code — duplicate registration is therefore an
    error; pick a fresh name to iterate.
    """
    if name in _SCHEME_REGISTRY:
        raise ValueError(f"scheme {name!r} already registered")
    if policy not in ("ltd", "ltc", "lta"):
        raise ValueError(f"unknown conditioning policy {policy!r}")
    frozen = tuple(sorted(dict(params or {}).items()))
    spec = SchemeSpec(name=name, arbiter=_normalize_arbiter(arbiter),
                      policy=policy, params=frozen)
    _SCHEME_REGISTRY[name] = spec
    return spec


def register_scheme_family(
    base: str,
    factory: Callable[..., Arbiter],
    variants: Mapping[str, Mapping[str, Any]],
    *,
    policy: str = "ltc",
) -> tuple[SchemeSpec, ...]:
    """Register a family of parametrized schemes in one call.

    ``factory(**params) -> Arbiter`` builds one concrete arbiter per
    variant; ``variants`` maps a name suffix to its static params, and each
    variant is registered as ``f"{base}_{suffix}"``.  Because the params are
    closed over before registration, every variant is an ordinary scheme —
    a distinct jit-static name with its own compilation cache entry — and
    gets shmoo grids and CAFP scoring through the sweep engine for free::

        register_scheme_family(
            "seq_retry", make_seq_retry,
            {"r1": {"n_rounds": 1}, "r2": {"n_rounds": 2}}, policy="lta")

    Any duplicate variant name fails the whole call (schemes registered
    before the clash stay registered; re-running with a fresh base fixes it).
    """
    return tuple(
        register_scheme(f"{base}_{suffix}", factory(**dict(params)),
                        policy=policy, params=params)
        for suffix, params in variants.items()
    )


def scheme_spec(name: str) -> SchemeSpec:
    try:
        return _SCHEME_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {registered_schemes()}"
        ) from None


def registered_schemes() -> tuple[str, ...]:
    return tuple(_SCHEME_REGISTRY)


register_scheme("seq", lambda cfg, tables, spec: sequential_tuning(tables, spec))
register_scheme(
    "rs_ssm",
    lambda cfg, tables, spec: single_step_matching(
        tables, relation_search(tables, spec, variation_tolerant=False), spec
    ),
)
register_scheme(
    "vtrs_ssm",
    lambda cfg, tables, spec: single_step_matching(
        tables, relation_search(tables, spec, variation_tolerant=True), spec
    ),
)


def make_seq_retry(n_rounds: int | None = None,
                   constrained_first: bool = True) -> Arbiter:
    """Factory for retry-budgeted oblivious LtA arbiters (§V-E future work).

    ``n_rounds`` caps the conflict-retry sweeps (None = N_ch, enough for
    convergence); ``constrained_first`` picks the lock order.  Both are
    static — bake them here and register the result under its own name.
    """
    def arbiter(cfg, tables, spec):
        return sequential_retry(
            tables, n_rounds=n_rounds, constrained_first=constrained_first
        )
    return arbiter


# beyond-paper oblivious LtA (§V-E future work): the full-budget arbiter
# plus a retry-budget family for the budget/CAFP trade-off study
# (benchmarks/fig17_retry_budget.py).
register_scheme("seq_retry", make_seq_retry(), policy="lta")
register_scheme_family(
    "seq_retry",
    make_seq_retry,
    {
        "r1": {"n_rounds": 1},
        "r2": {"n_rounds": 2},
        "r4": {"n_rounds": 4},
        "phys": {"n_rounds": None, "constrained_first": False},
    },
    policy="lta",
)


def make_protocol(
    depth: int | None = None,
    n_rounds: int | None = None,
    order: str = "constrained",
    backend: str | None = None,
) -> Arbiter:
    """Factory for protocol-engine arbiters (``repro.core.protocol``).

    ``depth`` bounds the displacement chains of the augment phase (None = N,
    full multi-hop; 0 = probe/release only), ``n_rounds`` the static round
    budget, ``order`` the probe-phase controller order.  All static — bake
    them here and register the result under its own jit-static name.

    ``backend`` is only a *default*: at call time the engine's backend
    (``SweepRequest.backend`` / ``oblivious_arbitrate(backend=)``) takes
    precedence when set, so registered protocol schemes honor
    ``backend="pallas"``/``"interpret"`` sweeps without re-registration.
    """
    baked = backend

    def arbiter(cfg, tables, spec, *, backend=None):
        return run_protocol(
            tables, spec, order=order, depth=depth, n_rounds=n_rounds,
            backend=baked if backend is None else backend,
        )

    return arbiter


# Protocol-engine schemes (the multi-hop augmenting LtA that closes
# seq_retry's residual mid-TR CAFP, plus its chain-depth family for the
# probe-budget trade-off and the LtD-conditioned chain-order variant) —
# benchmarked in benchmarks/fig19_lta_protocol.py.
register_scheme("protocol_lta", make_protocol(), policy="lta")
register_scheme_family(
    "protocol_lta",
    make_protocol,
    {
        "h1": {"depth": 1},
        "h2": {"depth": 2},
        "h4": {"depth": 4},
    },
    policy="lta",
)
register_scheme(
    "protocol_ltd",
    make_protocol(depth=0, n_rounds=1, order="chain"),
    policy="ltd",
)


class _SchemeNamesView(_SequenceABC):
    """Live, read-only, tuple-like view of the registered scheme names.

    Replaces the old module-level snapshot that was frozen at import time
    (schemes registered later were invisible through it)."""

    def __getitem__(self, i):
        return tuple(_SCHEME_REGISTRY)[i]

    def __len__(self) -> int:
        return len(_SCHEME_REGISTRY)

    def __contains__(self, name) -> bool:
        return name in _SCHEME_REGISTRY

    def __iter__(self):
        return iter(tuple(_SCHEME_REGISTRY))

    def __eq__(self, other):
        try:
            return tuple(self) == tuple(other)
        except TypeError:
            return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return f"SCHEMES{tuple(_SCHEME_REGISTRY)}"


class _SchemePolicyView(_MappingABC):
    """Live, read-only mapping view: scheme name -> conditioning policy."""

    def __getitem__(self, name: str) -> str:
        return _SCHEME_REGISTRY[name].policy

    def __len__(self) -> int:
        return len(_SCHEME_REGISTRY)

    def __iter__(self):
        return iter(tuple(_SCHEME_REGISTRY))

    def __repr__(self) -> str:
        return f"SCHEME_POLICY({dict(self)})"


SCHEMES = _SchemeNamesView()
SCHEME_POLICY = _SchemePolicyView()


def _build_tables(cfg, sys: SystemBatch, tr_mean, backend: str | None,
                  visible=None):
    """Search tables via core jnp (backend=None) or the kernel wrappers.

    ``visible`` ((T, N_wl) or (T, N_ring, N_wl) bool) restricts the search
    to lines still on the bus — the masked re-search a ring runs while
    other rings hold locks.  Every backend threads it to the same
    streaming top-E builder semantics (parity-tested).
    """
    if backend is None:
        return build_search_tables(
            sys, tr_mean, visible=visible, max_alias=cfg.max_fsr_alias
        )
    from repro.kernels import ops  # local import: kernels layer is optional

    delta, wl, nv = ops.build_tables(
        sys.laser, sys.ring, sys.fsr, tr_mean * sys.tr_unit,
        visible=visible, max_alias=cfg.max_fsr_alias, backend=backend,
    )
    return SearchTables(delta=delta, wl=wl, n_valid=nv)


def _ideal_min_tr(cfg, sys: SystemBatch, policy: str, backend: str | None):
    """(T,) per-trial ideal minimum mean TR, optionally via the kernels."""
    if backend is None:
        return ideal.min_tr(sys, policy, jnp.asarray(cfg.s))
    from repro.kernels import ops

    if policy == "lta":
        return ops.bottleneck_threshold(scaled_residual(sys), backend=backend)
    ltd, ltc = ops.feasibility(
        sys.laser, sys.ring, sys.fsr, sys.tr_unit,
        s=tuple(int(v) for v in cfg.s), backend=backend,
    )
    return ltd if policy == "ltd" else ltc


def _ideal_success(cfg, sys: SystemBatch, policy: str, tr_mean, backend: str | None):
    """(T,) bool ideal arbitration success at the given mean tuning range."""
    if backend is None:
        return ideal.success(sys, policy, jnp.asarray(cfg.s), tr_mean)
    if policy == "lta":
        from repro.kernels import ops

        adj = adjacency_bitmask(reach_matrix(sys, tr_mean))
        _, ok = ops.perfect_matching(adj, backend=backend)
        return ok
    return _ideal_min_tr(cfg, sys, policy, backend) <= tr_mean


def _eval_variations(
    variations, tr_mean, legacy: dict, *, caller: str, allow_tr: bool = True
) -> Variations:
    """Normalize an evaluator's (tr_mean, variations, legacy-kwarg) inputs."""
    # stacklevel 4: this helper adds a frame between the user and the warn
    over = merge_legacy_overrides(variations, legacy, caller=caller,
                                  stacklevel=4)
    if tr_mean is not None:
        if "tr_mean" in over:
            raise ValueError(
                f"{caller}: tr_mean passed both positionally and in variations"
            )
        over = over.replace(tr_mean=tr_mean)
    if not allow_tr and "tr_mean" in over:
        raise ValueError(
            f"{caller}: min-TR evaluation solves for the tuning range; "
            "'tr_mean' cannot be overridden"
        )
    return over


def oblivious_arbitrate(
    cfg: ArbitrationConfig,
    sys: SystemBatch,
    tr_mean,
    scheme: str,
    *,
    visible=None,
    backend: str | None = None,
) -> Assignment:
    """Run a wavelength-oblivious arbitration scheme on a system batch.

    ``visible`` ((T, N_wl) or (T, N_ring, N_wl) bool) runs the scheme on
    masked re-search tables — the arbitration a late-joining ring performs
    while earlier locks have already captured lines.

    ``backend`` selects the kernel backend for table build *and* is
    forwarded to the scheme's arbiter, so backend-aware schemes (the
    protocol engine) run their hot loop on the same backend.
    """
    tables = _build_tables(cfg, sys, tr_mean, backend, visible=visible)
    spec = chain_spec(cfg.s)
    return scheme_spec(scheme).arbiter(cfg, tables, spec, backend=backend)


class EvalResult(NamedTuple):
    afp: jax.Array          # policy-level failure probability (ideal LtC)
    cafp: jax.Array         # conditional algorithmic failure (Eq. 6)
    lock_err: jax.Array     # CAFP portion from zero/dup lock errors
    order_err: jax.Array    # CAFP portion from lane-order errors
    alg_success: jax.Array  # (T,) bool
    ideal_ok: jax.Array     # (T,) bool


def evaluate_scheme_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    scheme: str,
    tr_mean=None,
    variations: Variations | None = None,
    sigma_rlv=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    sigma_go=None,
    sigma_llv_frac=None,
    fsr_mean=None,
    backend: str | None = None,
) -> EvalResult:
    """Instantiate systems, run the scheme, and score CAFP vs ideal LtC.

    Un-jitted body; vmap-safe (the sweep engine maps it over grid points).
    Overrides come from ``variations``; ``tr_mean`` may also be given
    positionally; the ``sigma_* =`` kwargs are deprecated shims.
    """
    over = _eval_variations(
        variations, tr_mean,
        dict(sigma_rlv=sigma_rlv, sigma_fsr_frac=sigma_fsr_frac,
             sigma_tr_frac=sigma_tr_frac, sigma_go=sigma_go,
             sigma_llv_frac=sigma_llv_frac, fsr_mean=fsr_mean),
        caller="evaluate_scheme",
    )
    tr = over.resolve("tr_mean", cfg)
    sys = instantiate(cfg, units, over)
    s = jnp.asarray(cfg.s)
    policy = scheme_spec(scheme).policy
    ideal_ok = _ideal_success(cfg, sys, policy, tr, backend)
    assign = oblivious_arbitrate(cfg, sys, tr, scheme, backend=backend)
    out = classify(assign, s, policy=policy)
    lock = (out.zero_lock | out.dup_lock) & ideal_ok
    order = out.order_err & ideal_ok
    return EvalResult(
        afp=metrics.afp(ideal_ok),
        cafp=metrics.cafp(out.success, ideal_ok),
        lock_err=jnp.mean(lock.astype(jnp.float32)),
        order_err=jnp.mean(order.astype(jnp.float32)),
        alg_success=out.success,
        ideal_ok=ideal_ok,
    )


evaluate_scheme = jax.jit(
    evaluate_scheme_impl, static_argnames=("cfg", "scheme", "backend")
)


def evaluate_policy_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    tr_mean=None,
    variations: Variations | None = None,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
    backend: str | None = None,
):
    """Ideal-model policy evaluation: AFP at a given mean tuning range."""
    over = _eval_variations(
        variations, tr_mean,
        dict(sigma_rlv=sigma_rlv, sigma_go=sigma_go,
             sigma_llv_frac=sigma_llv_frac, sigma_fsr_frac=sigma_fsr_frac,
             sigma_tr_frac=sigma_tr_frac, fsr_mean=fsr_mean),
        caller="evaluate_policy",
    )
    tr = over.resolve("tr_mean", cfg)
    sys = instantiate(cfg, units, over)
    ok = _ideal_success(cfg, sys, policy, tr, backend)
    return metrics.afp(ok)


evaluate_policy = jax.jit(
    evaluate_policy_impl, static_argnames=("cfg", "policy", "backend")
)


def policy_trial_min_tr_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    variations: Variations | None = None,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
    backend: str | None = None,
):
    """(T,) per-trial ideal minimum mean TR at the given variation overrides.

    The sweep engine's TR-axis fast path: ideal success at mean TR t is
    exactly ``trial_min_tr <= t`` for every policy, so one min-TR evaluation
    prices the entire TR axis.
    """
    over = _eval_variations(
        variations, None,
        dict(sigma_rlv=sigma_rlv, sigma_go=sigma_go,
             sigma_llv_frac=sigma_llv_frac, sigma_fsr_frac=sigma_fsr_frac,
             sigma_tr_frac=sigma_tr_frac, fsr_mean=fsr_mean),
        caller="policy_min_tr", allow_tr=False,
    )
    sys = instantiate(cfg, units, over)
    return _ideal_min_tr(cfg, sys, policy, backend)


def policy_min_tr_impl(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    policy: str,
    variations: Variations | None = None,
    sigma_rlv=None,
    sigma_go=None,
    sigma_llv_frac=None,
    sigma_fsr_frac=None,
    sigma_tr_frac=None,
    fsr_mean=None,
    backend: str | None = None,
):
    """Minimum mean TR for complete arbitration success over the batch."""
    per_trial = policy_trial_min_tr_impl(
        cfg, units, policy, variations,
        sigma_rlv=sigma_rlv, sigma_go=sigma_go, sigma_llv_frac=sigma_llv_frac,
        sigma_fsr_frac=sigma_fsr_frac, sigma_tr_frac=sigma_tr_frac,
        fsr_mean=fsr_mean, backend=backend,
    )
    return metrics.min_tr_for_complete_success(per_trial)


policy_min_tr = jax.jit(
    policy_min_tr_impl, static_argnames=("cfg", "policy", "backend")
)


def make_units(cfg: ArbitrationConfig, seed: int, n_laser: int, n_ring: int) -> UnitSamples:
    return draw_unit_samples(jax.random.key(seed), cfg.grid.n_ch, n_laser, n_ring)


def shmoo(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    sigma_rlv_values: np.ndarray,
    tr_values: np.ndarray,
    *,
    policy: str | None = None,
    scheme: str | None = None,
) -> np.ndarray:
    """AFP (policy) or CAFP (scheme) over a sigma_rLV x TR grid — Fig. 4/14.

    One jitted call via the sweep engine (see ``repro.core.sweep``).
    """
    from .sweep import SweepRequest, sweep  # avoid import cycle

    assert (policy is None) != (scheme is None)
    req = SweepRequest(
        cfg=cfg, units=units, policy=policy, scheme=scheme,
        axes={"sigma_rlv": sigma_rlv_values, "tr_mean": tr_values},
    )
    res = sweep(req)
    return np.asarray(res.data if policy is not None else res.data.cafp)
