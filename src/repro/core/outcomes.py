"""Arbitration outcome classification (paper Fig. 9(c)-(f)).

Given a per-ring assignment, classify each trial as success or one of:
  * zero-lock   — some ring locked nothing (Fig. 9(e))
  * dup-lock    — two rings locked the same laser line (Fig. 9(d))
  * order error — spectral-ordering requirement violated (Fig. 9(f))
The classifier is wavelength-aware (it is part of the evaluator, not the
arbiter).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .ssm import Assignment


class Outcome(NamedTuple):
    success: jax.Array     # (T,) bool
    zero_lock: jax.Array   # (T,) bool
    dup_lock: jax.Array    # (T,) bool
    order_err: jax.Array   # (T,) bool


def classify(assign: Assignment, s: jax.Array, policy: str = "ltc") -> Outcome:
    wl = assign.wl                                   # (T, N)
    T, n = wl.shape
    zero = jnp.any(wl < 0, axis=1)

    onehot = jax.nn.one_hot(jnp.clip(wl, 0, n - 1), n, dtype=jnp.int32)
    counts = jnp.sum(onehot * (wl >= 0)[..., None], axis=1)      # (T, N) per line
    dup = jnp.any(counts > 1, axis=1)

    s = jnp.asarray(s)
    if policy == "ltd":
        order_ok = jnp.all(wl == s[None, :], axis=1)
    elif policy == "ltc":
        shift = (wl - s[None, :]) % n
        order_ok = jnp.all(shift == shift[:, :1], axis=1)
    elif policy == "lta":
        order_ok = jnp.ones((T,), bool)
    else:
        raise ValueError(policy)
    order_err = ~zero & ~dup & ~order_ok
    success = ~zero & ~dup & order_ok
    return Outcome(success=success, zero_lock=zero, dup_lock=dup, order_err=order_err)
