"""Oblivious arbitration protocol engine — round-driven distributed
wavelength arbitration (beyond-paper; the §V-E future work the paper defers).

The paper's schemes are *one-shot*: a record phase plus a single assignment
step.  ``benchmarks/beyond_lta`` shows why that is not enough for LtA —
depth-1 conflict retry (``seq_retry``) leaves residual mid-TR CAFP that only
multi-hop augmenting can close.  This module contributes the missing layer: a
batched, jit-compatible simulator of a *protocol* — many rounds of
probe / release / augment messages between per-ring controllers — on top of
which multi-hop augmenting Lock-to-Any (and an LtD-conditioned variant) are
ordinary registered schemes.

Observables (wavelength-oblivious, as in §V-A)
----------------------------------------------
A controller only ever sees its own search table (entry indices and tuning
codes — never wavelength values) and *masking events*: a re-search against
the live bus in which previously-recorded peaks are missing because another
ring holds that line (lock-monitor power at the holder, none at the
searcher).  Coordination — "release line, let me re-search, restore" — is a
control-plane message exchange, the same unit-search transactions the
paper's record phase is built from; the engine counts every such transaction
as a *probe* so the probe/CAFP trade-off is measurable.  Capture is modeled
globally (a held line is invisible to every other searcher): the protocol
serializes lock movements explicitly, so the upstream/downstream precedence
asymmetry of free-running rings is subsumed by protocol messages.

Round structure (a ``lax.while_loop``; all phases vectorized over trials)
-------------------------------------------------------------------------
  probe    — in a fixed controller order, every starved ring re-searches the
             masked bus red-ward of its tuner ``cursor`` and locks the first
             visible peak.
  augment  — every still-starved ring runs a *displacement chain* of up to
             ``depth`` hops: scan its table for a donor line; the donor
             either relocks red-ward of its current entry (chain closed), or
             surrenders the line and becomes the seeker of the next hop.
             Free lines and red-ward-relockable donors are preferred over
             surrender, so chains close as early as possible.
  release  — starved rings reset their tuner cursor to entry 0 (a sweep
             restart is an explicit protocol event, not a silent blue-ward
             drift).

Termination is provable: within a round every displaced ring moves strictly
red-ward (its cursor is monotone non-decreasing between releases), so a
round performs at most N*E displacements; rounds are statically bounded by
``n_rounds``.  These invariants — red-ward monotonicity, the static round
bound, and dup-lock freedom (a searcher can only lock a *visible* line, and
every donor hand-off is atomic) — are property-tested in
``tests/test_protocol.py``.

Complexity: a full augmenting sweep interrogates O(N) donors per seeker and
O(N) seekers per round over O(N) rounds — the O(N^3)-probe protocol
``benchmarks/beyond_lta`` calls for.  ``depth`` and ``n_rounds`` are static
knobs (baked into registered scheme names via ``register_scheme_family``),
giving the probe-budget/CAFP trade-off of ``benchmarks/fig19_lta_protocol``.

Everything is shape-static and vmap-safe: the sweep engine maps
``run_protocol`` over whole TR/sigma grids inside one jit, exactly like the
one-shot schemes.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .relation import ChainSpec
from .search_table import SearchTables
from .ssm import Assignment

#: research(wl (T, C, E), taken (T, L), floor (T, C)) ->
#:   (first entry >= floor per row, found mask), each (T, C).
ResearchFn = Callable[[jax.Array, jax.Array, jax.Array], tuple]

_ORDERS = ("constrained", "physical", "chain")


class ProtocolState(NamedTuple):
    """Per-trial controller state between protocol phases."""

    lock: jax.Array    # (T, N) held laser-line id, -1 if starved
    entry: jax.Array   # (T, N) table entry of the held line, -1 if starved
    cursor: jax.Array  # (T, N) red-ward tuner floor (monotone within a round)
    probes: jax.Array  # (T,) cumulative unit-search transaction count


class ProtocolStats(NamedTuple):
    """Cost/outcome accounting of one ``run_protocol`` call."""

    probes: jax.Array  # (T,) unit-search transactions spent
    rounds: jax.Array  # (T,) rounds until complete (round bound if never)
    locked: jax.Array  # (T,) rings holding a line at exit
    worked: jax.Array  # (T,) rounds actually executed (complete, halt or bound)


def cold_state(n_trials: int, n_ch: int) -> ProtocolState:
    """The protocol's initial state: every ring starved, sweep at entry 0."""
    return ProtocolState(
        lock=jnp.full((n_trials, n_ch), -1, jnp.int32),
        entry=jnp.full((n_trials, n_ch), -1, jnp.int32),
        cursor=jnp.zeros((n_trials, n_ch), jnp.int32),
        probes=jnp.zeros((n_trials,), jnp.int32),
    )


def revalidate_state(
    tables: SearchTables,
    state: ProtocolState,
    *,
    tr=None,
    hysteresis=0.0,
) -> tuple[ProtocolState, jax.Array]:
    """Match a carried lock state against freshly rebuilt search tables.

    The temporal re-arbitration entry gate: after drift/failure the tables
    are rebuilt from the live bus, and a held line is *broken* when it no
    longer appears in its ring's table (drifted out of the TR window, lane
    killed, or the ring itself dead — an empty table).  Surviving locks are
    re-anchored to the line's entry in the NEW table (the nearest alias may
    have moved) with the cursor following, so a warm ``run_protocol`` resumes
    exactly where the controller physically is.  Broken rings reset to the
    cold per-ring state (starved, cursor 0).

    ``hysteresis`` (with ``tr`` = (T, N) actual per-ring tuning ranges)
    proactively breaks locks whose tuning distance sits within ``hysteresis``
    of either window edge: the ring re-arbitrates *before* drift pushes it
    out, trading one early re-lock for repeated break/relock thrash.

    Returns ``(state, kept)`` — ``kept`` (T, N) bool marks locks that
    survived (the still-feasible locks that lock-churn accounting is
    measured against).  Probes are carried through untouched.
    """
    e = tables.wl.shape[-1]
    held = state.lock >= 0
    hit = (tables.wl == state.lock[:, :, None]) & held[:, :, None]
    found = hit.any(axis=-1)
    new_entry = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    kept = found
    if tr is not None:
        delta = jnp.take_along_axis(
            tables.delta, jnp.clip(new_entry, 0, e - 1)[..., None], axis=-1
        )[..., 0]
        kept = kept & (delta >= hysteresis) & (delta <= tr - hysteresis)
    return state._replace(
        lock=jnp.where(kept, state.lock, -1),
        entry=jnp.where(kept, new_entry, -1),
        cursor=jnp.where(kept, new_entry, 0),
    ), kept


def _taken_lines(lock: jax.Array, n_lines: int) -> jax.Array:
    """(T, N) locks -> (T, L) bool: line captured by some ring."""
    onehot = jax.nn.one_hot(jnp.clip(lock, 0, n_lines - 1), n_lines, dtype=bool)
    return jnp.any(onehot & (lock >= 0)[..., None], axis=1)


def _taken_at(taken: jax.Array, wl: jax.Array) -> jax.Array:
    """Gather ``taken`` (T, L) at line ids ``wl`` (T, ...); -1 ids -> False
    (invalid ids route to the all-False pad column)."""
    t, n_lines = taken.shape
    pad = jnp.pad(taken, ((0, 0), (0, 1)))
    rows = jnp.arange(t).reshape((t,) + (1,) * (wl.ndim - 1))
    idx = jnp.where((wl < 0) | (wl >= n_lines), n_lines, wl)
    return pad[rows, idx]


def masked_first_entry(wl: jax.Array, taken: jax.Array, floor: jax.Array):
    """Batched masked re-search: first visible entry at-or-after ``floor``.

    wl: (T, C, E) line ids of C search tables per trial (-1 padding);
    taken: (T, L) captured-line mask; floor: (T, C) minimum entry index.
    Returns (first (T, C) int32 entry or -1, found (T, C) bool).

    This is the protocol's unit primitive — one call re-searches a whole
    batch of tables at once (every donor candidate of an augmenting chain in
    one shot), which is what keeps a round O(1) jaxpr in N.  The kernel
    mirror is ``repro.kernels.ops.masked_research`` (parity-tested).
    """
    e = wl.shape[-1]
    eiota = jnp.arange(e, dtype=jnp.int32)
    vis = (wl >= 0) & ~_taken_at(taken, wl) & (eiota >= floor[..., None])
    found = vis.any(axis=-1)
    first = jnp.argmax(vis, axis=-1).astype(jnp.int32)
    return jnp.where(found, first, -1), found


def _line_holder(lock: jax.Array, n_lines: int) -> jax.Array:
    """(T, N) locks -> (T, L) int32: ring holding each line, -1 if free.

    Safe under the engine's dup-lock-freedom invariant (each line has at
    most one holder, so the one-hot sum is exact)."""
    oh = jax.nn.one_hot(jnp.clip(lock, 0, n_lines - 1), n_lines, dtype=jnp.int32)
    ring1 = jnp.arange(1, lock.shape[1] + 1, dtype=jnp.int32)[None, :, None]
    return jnp.sum(oh * ring1 * (lock >= 0)[..., None].astype(jnp.int32), axis=1) - 1


def _controller_order(tables: SearchTables, spec: ChainSpec, order: str):
    """(T, N) rank -> ring: who re-searches first in the probe phase.

    "constrained": fewest-peaks-first (n_valid is locally observable, so the
    order is oblivious); "physical": bus order; "chain": the target-ordering
    chain (the LtD-conditioned variant locks in spectral target order).
    """
    t, n, _ = tables.wl.shape
    if order == "constrained":
        return jnp.argsort(tables.n_valid, axis=1).astype(jnp.int32)
    if order == "physical":
        return jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (t, n))
    if order == "chain":
        return jnp.broadcast_to(jnp.asarray(spec.chain, jnp.int32), (t, n))
    raise ValueError(f"unknown controller order {order!r}; valid: {_ORDERS}")


def _probe_phase(tables: SearchTables, order: jax.Array, state: ProtocolState,
                 research: ResearchFn, trace=None, rnd=None):
    """One lock sweep: starved rings relock red-ward of their cursor.

    Returns ``(state, trace)``.  ``trace`` is an optional
    ``repro.obs.trace.TraceBuffer`` (the flight recorder); the appends are
    Python-static branches, so ``trace=None`` compiles to the legacy jaxpr
    bit for bit.
    """
    t, n, e = tables.wl.shape
    rows = jnp.arange(t)
    tracing = trace is not None
    if tracing:
        from repro.obs.trace import EV_LOCK, EV_PROBE, trace_append

    def body(rank, st):
        lock, entry, cursor, probes = st[:4]
        ring = order[:, rank]                            # (T,) per-trial ring
        # A starved ring with an *empty* table (its sweep recorded no peak)
        # has nothing to re-search: it never spends probes, which keeps the
        # per-trial probe count independent of which other trials keep the
        # shared round loop alive.
        searching = (lock[rows, ring] < 0) & (tables.n_valid[rows, ring] > 0)
        taken = _taken_lines(lock, n)
        wl_row = tables.wl[rows, ring]                   # (T, E)
        cur = cursor[rows, ring]
        first, found = research(wl_row[:, None, :], taken, cur[:, None])
        first, found = first[:, 0], found[:, 0]
        do = searching & found
        l_new = wl_row[rows, jnp.clip(first, 0, e - 1)]
        lock = lock.at[rows, ring].set(jnp.where(do, l_new, lock[rows, ring]))
        entry = entry.at[rows, ring].set(jnp.where(do, first, entry[rows, ring]))
        cursor = cursor.at[rows, ring].set(jnp.where(do, first, cur))
        probes = probes + searching.astype(jnp.int32)
        if tracing:
            tr = trace_append(st[4], searching, rnd, ring, EV_PROBE, cur)
            tr = trace_append(tr, do, rnd, ring, EV_LOCK, first)
            return lock, entry, cursor, probes, tr
        return lock, entry, cursor, probes

    init = tuple(state) + ((trace,) if tracing else ())
    out = jax.lax.fori_loop(0, n, body, init)
    return ProtocolState(*out[:4]), (out[4] if tracing else None)


def _augment_phase(tables: SearchTables, state: ProtocolState, depth: int,
                   n_seekers: int, k_donors: int,
                   research: ResearchFn, trace=None, rnd=None):
    """Displacement chains for starved rings, up to ``depth`` hops each.

    Returns ``(state, trace)`` — see ``_probe_phase`` for the flight-
    recorder contract (``trace=None`` keeps the legacy jaxpr).

    Hop resolution order (first match wins, all red-ward of the seeker's
    cursor): a *free* visible line; among the first ``k_donors`` donor
    candidates, one that can itself relock red-ward (two coordinated moves,
    chain closed); otherwise the nearest donor surrenders its line and
    becomes the next hop's seeker.  Every donor hand-off advances the
    displaced ring's cursor past the surrendered entry, so hops within a
    round are monotone red-ward and chains cannot cycle.

    ``n_seekers`` chains run per phase (each picks the lowest-indexed
    not-yet-attempted starved ring per trial); leftover starvation is
    retried next round, so small slot counts trade rounds for per-round
    cost, not correctness.
    """
    t, n, e = tables.wl.shape
    k_don = max(1, min(k_donors, e))
    rows = jnp.arange(t)
    eiota = jnp.arange(e, dtype=jnp.int32)
    tracing = trace is not None
    if tracing:
        from repro.obs.trace import (
            EV_DISPLACE,
            EV_LOCK,
            EV_PROBE,
            EV_SURRENDER,
            trace_append,
        )

    def chain_step(_, carry):
        lock, entry, cursor, probes, s, active = carry[:6]
        taken = _taken_lines(lock, n)
        holder = _line_holder(lock, n)
        wl_s = tables.wl[rows, s]                        # (T, E)
        floor_s = cursor[rows, s]

        # 1) a free line red-ward of the seeker's cursor.
        f_free, free_ok = research(wl_s[:, None, :], taken, floor_s[:, None])
        f_free, free_ok = f_free[:, 0], free_ok[:, 0]

        # 2) donor candidates: entry e of the seeker's table is a candidate
        #    iff its line is held by another ring.  The first k_donors of
        #    them are interrogated in ONE batched re-search: a donor can
        #    close the chain iff it has a visible entry red-ward of the one
        #    it holds.
        cand = (wl_s >= 0) & (eiota[None, :] >= floor_s[:, None])
        x_e = jnp.where(cand, holder[rows[:, None], jnp.clip(wl_s, 0, n - 1)], -1)
        cand = cand & (x_e >= 0) & (x_e != s[:, None])
        e_k = jnp.sort(jnp.where(cand, eiota[None, :], e), axis=1)[:, :k_don]
        valid_k = e_k < e                                # (T, K)
        e_k_safe = jnp.clip(e_k, 0, e - 1)
        x_k = jnp.clip(x_e[rows[:, None], e_k_safe], 0, n - 1)   # (T, K)
        wl_x = tables.wl[rows[:, None], x_k]             # (T, K, E)
        floor_x = entry[rows[:, None], x_k] + 1          # strictly red-ward
        alt, has_alt = research(wl_x, taken, floor_x)    # (T, K)
        swap_ok = valid_k & has_alt

        do_free = active & free_ok
        do_swap = active & ~free_ok & swap_ok.any(axis=1)
        do_yield = active & ~free_ok & ~swap_ok.any(axis=1) & cand.any(axis=1)
        take = do_free | do_swap | do_yield

        k_swap = jnp.argmax(swap_ok, axis=1).astype(jnp.int32)
        k_sel = jnp.where(do_swap, k_swap, 0)            # yield: nearest donor
        e_don = e_k_safe[rows, k_sel]
        e_s = jnp.where(do_free, f_free, e_don)
        e_s_safe = jnp.clip(e_s, 0, e - 1)
        l_s = wl_s[rows, e_s_safe]

        # donor of the selected entry (swap or yield case)
        x_sel = x_k[rows, k_sel]
        a_sel = jnp.clip(alt[rows, k_sel], 0, e - 1)
        l_alt = tables.wl[rows, x_sel, a_sel]
        x_entry = entry[rows, x_sel]                     # read before writes

        # seeker locks its chosen line (atomic with the donor hand-off)
        lock = lock.at[rows, s].set(jnp.where(take, l_s, lock[rows, s]))
        entry = entry.at[rows, s].set(jnp.where(take, e_s, entry[rows, s]))
        cursor = cursor.at[rows, s].set(jnp.where(take, e_s, cursor[rows, s]))
        # swap: the donor relocks red-ward at its alternative entry
        lock = lock.at[rows, x_sel].set(
            jnp.where(do_swap, l_alt, lock[rows, x_sel]))
        entry = entry.at[rows, x_sel].set(
            jnp.where(do_swap, a_sel, entry[rows, x_sel]))
        cursor = cursor.at[rows, x_sel].set(
            jnp.where(do_swap, a_sel, cursor[rows, x_sel]))
        # yield: the donor surrenders and becomes the next hop's seeker,
        # cursor advanced past the surrendered entry (red-ward monotone)
        lock = lock.at[rows, x_sel].set(
            jnp.where(do_yield, -1, lock[rows, x_sel]))
        entry = entry.at[rows, x_sel].set(
            jnp.where(do_yield, -1, entry[rows, x_sel]))
        cursor = cursor.at[rows, x_sel].set(
            jnp.where(do_yield, x_entry + 1, cursor[rows, x_sel]))

        # probe accounting: 1 re-search by the seeker, plus one
        # release/re-search/restore transaction per donor interrogated
        # (up to the selected one; all k_donors when the chain is stuck).
        n_inter = jnp.sum(valid_k.astype(jnp.int32), axis=1)
        scanned = jnp.where(
            do_free, 0, jnp.where(do_swap, k_swap + 1, n_inter)
        )
        probes = probes + jnp.where(active, 1 + scanned, 0)

        s_next = jnp.where(do_yield, x_sel, s)
        if tracing:
            tr = trace_append(carry[6], active, rnd, s, EV_PROBE, floor_s)
            tr = trace_append(tr, take, rnd, s, EV_LOCK, e_s)
            tr = trace_append(tr, do_swap, rnd, x_sel, EV_DISPLACE, a_sel)
            tr = trace_append(tr, do_yield, rnd, x_sel, EV_SURRENDER, x_entry)
            return lock, entry, cursor, probes, s_next, do_yield, tr
        return lock, entry, cursor, probes, s_next, do_yield

    def seeker_slot(_, st):
        lock, entry, cursor, probes, tried = st[:5]
        # Empty-table rings can never lock: they launch no chains (and spend
        # no probes), same per-trial accounting argument as the probe phase.
        starved = (lock < 0) & ~tried & (tables.n_valid > 0)
        any_s = starved.any(axis=1)
        s0 = jnp.argmax(starved, axis=1).astype(jnp.int32)
        tried = tried.at[rows, s0].set(tried[rows, s0] | any_s)
        carry = (lock, entry, cursor, probes, s0, any_s)
        carry = carry + ((st[5],) if tracing else ())
        out = jax.lax.fori_loop(0, depth, chain_step, carry)
        return out[:4] + (tried,) + ((out[6],) if tracing else ())

    init = tuple(state) + (jnp.zeros((t, n), bool),)
    init = init + ((trace,) if tracing else ())
    out = jax.lax.fori_loop(0, min(n_seekers, n), seeker_slot, init)
    return ProtocolState(*out[:4]), (out[5] if tracing else None)


def _release_phase(state: ProtocolState, trace=None, rnd=None):
    """Starved rings restart their tuner sweep (cursor back to entry 0).

    Returns ``(state, trace)``; with the recorder on, every cursor that
    actually rewinds logs one ``release`` event (entry = the old cursor).
    """
    starved = state.lock < 0
    if trace is not None:
        from repro.obs.trace import EV_RELEASE, trace_append

        reset = starved & (state.cursor != 0)

        def body(i, tr):
            return trace_append(
                tr, reset[:, i], rnd, i, EV_RELEASE, state.cursor[:, i]
            )

        trace = jax.lax.fori_loop(0, state.lock.shape[1], body, trace)
    return state._replace(cursor=jnp.where(starved, 0, state.cursor)), trace


def _finalize(tables: SearchTables, state: ProtocolState) -> Assignment:
    e = tables.max_entries
    e_safe = jnp.clip(state.entry, 0, e - 1)
    delta = jnp.where(
        state.entry >= 0,
        jnp.take_along_axis(tables.delta, e_safe[..., None], axis=-1)[..., 0],
        jnp.inf,
    )
    wl = jnp.where(state.entry >= 0, state.lock, -1)
    return Assignment(entry=state.entry, wl=wl, delta=delta)


def _resolve_research(backend: str | None) -> ResearchFn:
    if backend is None:
        return masked_first_entry
    from repro.kernels import ops  # local import: kernels layer is optional

    def research(wl, taken, floor):
        return ops.masked_research(wl, taken, floor, backend=backend)

    return research


def default_rounds(n_ch: int) -> int:
    """Static round bound: enough for the starvation "hole" to traverse the
    bus a few times.  4N empirically drives CAFP vs the ideal LtA arbiter to
    zero on the WDM8 *and* WDM16 benchmark grids (2N leaves a ~1e-2 mid-TR
    residual at N=16); converged trials exit the while_loop early, so the
    bound is only ever paid on ideal-infeasible trials."""
    return 4 * n_ch


def run_protocol(
    tables: SearchTables,
    spec: ChainSpec,
    *,
    order: str = "constrained",
    depth: int | None = None,
    n_rounds: int | None = None,
    n_seekers: int = 4,
    k_donors: int = 4,
    backend: str | None = None,
    with_stats: bool = False,
    init_state: ProtocolState | None = None,
    with_state: bool = False,
    transactional: bool = False,
    patience: int | None = None,
    trace: int | None = None,
):
    """Run the round-driven oblivious arbitration protocol on a table batch.

    depth:    max displacement-chain hops per augmenting attempt (None = N —
              full multi-hop); 0 disables augmenting entirely.
    n_rounds: static probe/release/augment round bound (None =
              ``default_rounds`` = 4N).
    n_seekers: displacement chains launched per augment phase (starvation
              rarely exceeds a few rings; leftovers retry next round).
    k_donors: donor-lookahead width per hop (how many held lines the seeker
              interrogates before forcing the nearest donor to surrender).
    order:    controller order of the probe phase (see ``_controller_order``).
    backend:  None = core jnp; "jnp"/"interpret"/"pallas" route the masked
              re-search primitive through ``repro.kernels.ops``.  Registered
              protocol schemes forward the engine's call-time backend here
              (``SweepRequest.backend`` reaches table build, ideal scoring
              *and* this loop); the ``make_protocol(backend=)`` default only
              applies when the caller leaves the backend unset.
    init_state: resume from a live ``ProtocolState`` (warm start — the
              incremental re-arbitration path of ``core.temporal``; pass it
              through ``revalidate_state`` against the current tables first).
              None = ``cold_state`` — today's from-scratch behavior.
    with_state: additionally return the final ``ProtocolState``, resumable
              by a later call's ``init_state``.
    transactional: make-before-break commit — the whole re-arbitration is
              one transaction per trial, committed only if it locked
              strictly MORE rings than ``init_state`` held; otherwise
              (lock, entry, cursor) roll back to the initial state (probes
              stay spent: the exploratory transactions physically ran).
              Warm re-arbitration needs this: after a lane loss leaves a
              ring unlockable, augmenting yields would otherwise walk the
              starvation hole through every still-feasible lock and leave
              the bus permuted for nothing.  Rollback is per-trial and a
              pure function of that trial's own states, so probe/stat
              accounting stays batch-independent.  Keep False for cold
              starts (bit-identical legacy behavior; from an empty state
              any lock is an improvement, so rollback could only ever fire
              on the all-infeasible no-lock case).
    patience: halt a trial after this many consecutive rounds without a
              locked-count increase (None = legacy: halt only on exact
              fixed points).  Augmenting yields keep *changing* state while
              walking the starvation hole around an infeasible bus, so the
              fixed-point halt never fires and such trials pay the full
              round bound; a patience cap bounds that exploration at
              ``patience * O(chain)`` probes.  Plateau-halted trials freeze
              (later rounds restore their state and refund their probes,
              same per-trial argument as the fixed-point halt); a feasible
              augmenting sequence with full ``depth`` rarely plateaus more
              than a round or two before locking another ring, so small
              values (4-8) trade essentially no completion for a bounded
              infeasible-trial budget.  Used by ``core.temporal`` for both
              warm and cold re-arbitration (a fair probe comparison).
    trace:    flight-recorder ring capacity (events per trial).  None (the
              default) disables tracing and the compiled program is the
              legacy jaxpr bit for bit — every append is a Python-static
              branch.  An int appends a ``repro.obs.trace.TraceBuffer`` to
              the return tuple, recording every probe / lock / displace /
              surrender / release transaction plus a trial-level ``halt``
              event.  Frozen (halted) trials record nothing further — the
              recorder follows the engine's restore-and-refund semantics —
              but transactional rollbacks keep their exploration events
              (the transactions physically ran; only the commit rolled
              back).  Tracing never changes arbitration outcomes
              (asserted in ``tests/test_obs.py``).

    Returns ``assign`` and, per the flags, ``(assign, stats)``,
    ``(assign, state)`` or ``(assign, stats, state)`` — with ``trace`` set,
    the ``TraceBuffer`` is appended last.  ``assign`` is an
    ``Assignment`` ((T, N) entry/wl/delta).  The while_loop exits as soon as
    every trial is fully locked — and, since one probe/augment/release round
    is a deterministic function of (lock, entry, cursor), a trial whose
    round changed nothing is at a fixed point: it is sticky-marked *halted*,
    its later rounds refund their probes (keeping the per-trial probe count
    batch-independent), and the loop exits once every trial is complete,
    dead or halted — ideal-infeasible trials stop paying the 4N bound.
    Stats count only this call's spend: ``stats.probes`` starts from
    ``init_state.probes`` (zero it for per-resume accounting) and
    ``stats.rounds`` is 0 for a trial that resumed already-complete.
    """
    t, n, _ = tables.wl.shape
    dep = n if depth is None else int(depth)
    rounds = default_rounds(n) if n_rounds is None else int(n_rounds)
    research = _resolve_research(backend)
    order_idx = _controller_order(tables, spec, order)
    tracing = trace is not None
    if tracing:
        from repro.obs.trace import (
            EV_HALT, merge_traces, trace_append, trace_buffer,
        )

        buf0 = trace_buffer(t, int(trace))

    state0 = cold_state(t, n) if init_state is None else init_state
    # Trials resumed already-complete never enter the loop: report round 0
    # (a warm fixed point costs nothing).  Cold starts (n >= 1 starved
    # rings) leave this at -1 exactly as before.
    done0 = jnp.where(
        jnp.all(state0.lock >= 0, axis=1), jnp.int32(0), jnp.int32(-1)
    )

    def cond(carry):
        state, rnd, halted = carry[0], carry[1], carry[3]
        # A trial stays live while some starved ring could still act: a
        # starved ring whose search table is empty (n_valid == 0 — an
        # observable event: its sweep records no peak) can never lock, and a
        # trial whose every starved ring is in that state is a fixed point
        # of all three phases — exit instead of spinning out the bound.
        # ``halted`` extends the same argument to *stalled* trials (a full
        # round changed nothing), so ideal-infeasible workloads exit as soon
        # as every trial is complete, dead or provably stuck.
        live = (state.lock < 0) & (tables.n_valid > 0)
        return (rnd < rounds) & jnp.any(jnp.any(live, axis=1) & ~halted)

    def body(carry):
        state, rnd, done_round, halted, plateau, halt_round = carry[:6]
        buf = carry[6] if tracing else None
        prev, prev_buf = state, buf
        state, buf = _probe_phase(
            tables, order_idx, state, research, buf, rnd
        )
        if dep > 0:
            state, buf = _augment_phase(
                tables, state, dep, n_seekers, k_donors, research, buf, rnd
            )
        state, buf = _release_phase(state, buf, rnd)
        # Progress stall: one round is a deterministic map of (lock, entry,
        # cursor), so an unchanged live trial repeats forever — sticky-halt
        # it.  Already-halted trials are frozen: this round's state changes
        # are restored and its probes refunded (for a fixed-point halt the
        # restore is a no-op by definition; for a plateau halt it stops the
        # hole-walk where the patience ran out).  Either way the per-trial
        # spend stays independent of which *other* trials keep the shared
        # loop alive.
        changed = (
            jnp.any(state.lock != prev.lock, axis=1)
            | jnp.any(state.entry != prev.entry, axis=1)
            | jnp.any(state.cursor != prev.cursor, axis=1)
        )
        state = ProtocolState(
            lock=jnp.where(halted[:, None], prev.lock, state.lock),
            entry=jnp.where(halted[:, None], prev.entry, state.entry),
            cursor=jnp.where(halted[:, None], prev.cursor, state.cursor),
            probes=jnp.where(halted, prev.probes, state.probes),
        )
        if tracing:
            # The recorder follows restore-and-refund: a frozen trial's
            # events this round are dropped along with its state changes.
            buf = merge_traces(halted, prev_buf, buf)
        live = jnp.any((prev.lock < 0) & (tables.n_valid > 0), axis=1)
        was_halted = halted
        halted = halted | (live & ~changed)
        if patience is not None:
            improved = (
                jnp.sum((state.lock >= 0).astype(jnp.int32), axis=1)
                > jnp.sum((prev.lock >= 0).astype(jnp.int32), axis=1)
            )
            plateau = jnp.where(improved | halted, 0, plateau + 1)
            halted = halted | (live & (plateau >= int(patience)))
        halt_round = jnp.where(
            halted & ~was_halted & (halt_round < 0), rnd + 1, halt_round
        )
        complete = jnp.all(state.lock >= 0, axis=1)
        done_round = jnp.where(
            complete & (done_round < 0), rnd + 1, done_round
        )
        out = (state, rnd + 1, done_round, halted, plateau, halt_round)
        if tracing:
            buf = trace_append(
                buf, halted & ~was_halted, rnd + 1, -1, EV_HALT, -1
            )
            out = out + (buf,)
        return out

    carry0 = (state0, jnp.int32(0), done0, jnp.zeros((t,), bool),
              jnp.zeros((t,), jnp.int32), jnp.full((t,), -1, jnp.int32))
    if tracing:
        carry0 = carry0 + (buf0,)
    final = jax.lax.while_loop(cond, body, carry0)
    state, done_round, halt_round = final[0], final[2], final[5]
    buf = final[6] if tracing else None
    if transactional:
        n_lock0 = jnp.sum((state0.lock >= 0).astype(jnp.int32), axis=1)
        n_lock1 = jnp.sum((state.lock >= 0).astype(jnp.int32), axis=1)
        commit = (n_lock1 > n_lock0)[:, None]
        state = state._replace(
            lock=jnp.where(commit, state.lock, state0.lock),
            entry=jnp.where(commit, state.entry, state0.entry),
            cursor=jnp.where(commit, state.cursor, state0.cursor),
        )
        done_round = jnp.where(commit[:, 0], done_round, done0)
        # Rollback restores state only: the exploration events stand (those
        # transactions physically ran; just the commit was refused).
    assign = _finalize(tables, state)
    if not with_stats:
        if with_state:
            return (assign, state, buf) if tracing else (assign, state)
        return (assign, buf) if tracing else assign
    stats = ProtocolStats(
        probes=state.probes,
        rounds=jnp.where(done_round < 0, rounds, done_round),
        locked=jnp.sum((state.lock >= 0).astype(jnp.int32), axis=1),
        # Rounds this trial actually executed: completion round, halt round
        # (fixed point or plateau), or the full bound.  ``rounds`` keeps its
        # legacy report-the-bound-when-incomplete semantics; ``worked`` is
        # the honest latency the temporal layer accounts.
        worked=jnp.where(
            done_round >= 0, done_round,
            jnp.where(halt_round >= 0, halt_round, rounds),
        ),
    )
    out = (assign, stats, state) if with_state else (assign, stats)
    return out + (buf,) if tracing else out


# Jitted phase steps for the trace path: compiled once per (T, N, E) shape,
# so the per-round Python loop of run_protocol_trace stays fast enough for
# the hypothesis/parametrized invariant tests.
_probe_jit = jax.jit(
    lambda tables, order, state: _probe_phase(
        tables, order, state, masked_first_entry
    )[0]
)
_augment_jit = jax.jit(
    lambda tables, state, depth, n_seekers, k_donors: _augment_phase(
        tables, state, depth, n_seekers, k_donors, masked_first_entry
    )[0],
    static_argnums=(2, 3, 4),
)


def run_protocol_trace(
    tables: SearchTables,
    spec: ChainSpec,
    *,
    order: str = "constrained",
    depth: int | None = None,
    n_rounds: int | None = None,
    n_seekers: int = 4,
    k_donors: int = 4,
    init_state: ProtocolState | None = None,
    transactional: bool = False,
) -> tuple:
    """Instrumented run: per-phase state snapshots for invariant checks.

    Executes exactly ``n_rounds`` rounds (no early exit) with a Python round
    loop and returns (assignment, snapshots) where snapshots is a list of
    (round, phase_name, ProtocolState) — phases "probe", "augment",
    "release" in execution order.  Test-only; never on a hot path.
    """
    t, n, _ = tables.wl.shape
    dep = n if depth is None else int(depth)
    rounds = default_rounds(n) if n_rounds is None else int(n_rounds)
    order_idx = _controller_order(tables, spec, order)

    state0 = cold_state(t, n) if init_state is None else init_state
    state = state0
    snaps = []
    for rnd in range(rounds):
        state = _probe_jit(tables, order_idx, state)
        snaps.append((rnd, "probe", jax.tree_util.tree_map(np.asarray, state)))
        if dep > 0:
            state = _augment_jit(tables, state, dep, n_seekers, k_donors)
        snaps.append((rnd, "augment", jax.tree_util.tree_map(np.asarray, state)))
        state, _ = _release_phase(state)
        snaps.append((rnd, "release", jax.tree_util.tree_map(np.asarray, state)))
    if transactional:
        commit = (
            jnp.sum((state.lock >= 0).astype(jnp.int32), axis=1)
            > jnp.sum((state0.lock >= 0).astype(jnp.int32), axis=1)
        )[:, None]
        state = state._replace(
            lock=jnp.where(commit, state.lock, state0.lock),
            entry=jnp.where(commit, state.entry, state0.entry),
            cursor=jnp.where(commit, state.cursor, state0.cursor),
        )
        snaps.append((rounds, "commit",
                      jax.tree_util.tree_map(np.asarray, state)))
    return _finalize(tables, state), snaps
