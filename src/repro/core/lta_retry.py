"""Beyond-paper: a wavelength-oblivious Lock-to-Any implementation.

The paper implements only the LtC policy and leaves LtA algorithms as
future work (§V-E: "the algorithm implementations of the LtD and LtA
policies are left for future exploration").  We contribute
**sequential tuning with conflict retry (SEQ-R)**: the natural oblivious
LtA arbiter —

  round 0: every ring locks its nearest visible peak (Lock-to-Nearest),
           in physical order (upstream precedence is the arbiter);
  round r: every ring whose line was captured by an upstream ring (its
           lock monitor reads no power — an observable event, no
           wavelength knowledge needed) re-runs its wavelength search
           against the now-masked bus and locks its nearest remaining
           peak.  Repeat up to R rounds.

Termination/soundness: a displaced ring only moves red-ward (its previous
peak is gone for it), so the process is monotone; R = N_ch rounds suffice.
No spectral-ordering is enforced — exactly the LtA policy.  Evaluated as
CAFP against the ideal LtA arbiter (perfect matching), the same way the
paper scores its LtC algorithms.

``n_rounds`` and ``constrained_first`` are static controller knobs; the
scheme registry exposes them as parametrized variants
(``seq_retry_r{1,2,4}``, ``seq_retry_phys`` via ``api.make_seq_retry`` /
``register_scheme_family``), and ``benchmarks/fig17_retry_budget.py``
sweeps the retry-budget/CAFP trade-off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .search_table import SearchTables
from .ssm import Assignment


def sequential_retry(tables: SearchTables, n_rounds: int | None = None,
                     constrained_first: bool = True) -> Assignment:
    """Oblivious LtA arbitration.

    Lock ORDER is a controller choice; by default rings lock
    most-constrained-first (fewest search-table peaks — a locally
    observable quantity, so the arbiter stays wavelength-oblivious).
    VISIBILITY is physical: a searcher sees every line except those
    captured by locked rings physically upstream of it; a ring whose line
    is later stolen upstream observes lost power and re-searches.
    """
    T, n, E = tables.wl.shape
    rounds = n if n_rounds is None else n_rounds
    rows = jnp.arange(T)
    if constrained_first:
        order = jnp.argsort(tables.n_valid, axis=1).astype(jnp.int32)  # (T, n)
    else:
        order = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (T, n))

    def lock_pass(lock_wl):
        """One sweep in lock order; per-trial ring selection via gather."""
        new_lock = lock_wl
        for rank in range(n):
            ring = order[:, rank]                           # (T,) ring index
            # lines captured by locked rings physically upstream of `ring`
            pos_mask = jnp.arange(n)[None, :] < ring[:, None]   # (T, n)
            claimed = jnp.where(pos_mask & (new_lock >= 0), new_lock, -1)
            onehot = jax.nn.one_hot(jnp.clip(claimed, 0, n - 1), n, dtype=bool)
            taken = jnp.any(onehot & (claimed >= 0)[..., None], axis=1)
            wl_row = tables.wl[rows, ring, :]               # (T, E)
            vis = (wl_row >= 0) & ~jnp.take_along_axis(
                jnp.pad(taken, ((0, 0), (0, 1))),
                jnp.clip(wl_row, 0, n), axis=1,
            )
            first = jnp.argmax(vis, axis=1).astype(jnp.int32)
            found = vis.any(axis=1)
            k = jnp.where(found, wl_row[rows, jnp.clip(first, 0, E - 1)], -1)
            # keep an existing non-conflicting lock (stability): only move
            # if the current line is now upstream-claimed or none held
            cur = new_lock[rows, ring]
            cur_ok = (cur >= 0) & ~jnp.take_along_axis(
                jnp.pad(taken, ((0, 0), (0, 1))),
                jnp.clip(cur, 0, n)[:, None], axis=1,
            )[:, 0]
            new_lock = new_lock.at[rows, ring].set(jnp.where(cur_ok, cur, k))
        return new_lock

    def taken_mask(lock_wl, upto):
        """(T, n_lines) lines claimed by locked rings with index < upto."""
        pos = jnp.arange(n)[None, :] < upto[:, None]
        claimed = jnp.where(pos & (lock_wl >= 0), lock_wl, -1)
        onehot = jax.nn.one_hot(jnp.clip(claimed, 0, n - 1), n, dtype=bool)
        return jnp.any(onehot & (claimed >= 0)[..., None], axis=1)

    def augment_pass(lock_wl):
        """Depth-1 oblivious augmenting: a starved ring R probes upstream
        donors X one at a time (unlock X -> R re-searches; an appearing
        peak identifies X as holding a line R needs); X moves to its own
        next visible line and R takes the freed one.  Every primitive is a
        wavelength search or lock — the paper's unit instructions."""
        new_lock = lock_wl
        for R in range(n):
            starved = new_lock[:, R] < 0
            wl_R = tables.wl[:, R, :]
            for X in range(R):  # upstream donors only
                lx = new_lock[:, X]
                # does X hold a line R could use?
                holds_useful = (lx[:, None] == wl_R).any(axis=1) & (lx >= 0)
                # can X relock elsewhere? (visible to X, excluding its own)
                taken_x = taken_mask(new_lock, jnp.full((T,), X, jnp.int32))
                wl_X = tables.wl[:, X, :]
                vis_x = (
                    (wl_X >= 0)
                    & ~jnp.take_along_axis(
                        jnp.pad(taken_x, ((0, 0), (0, 1))),
                        jnp.clip(wl_X, 0, n), axis=1,
                    )
                    & (wl_X != lx[:, None])
                )
                alt_e = jnp.argmax(vis_x, axis=1)
                has_alt = vis_x.any(axis=1)
                # R must actually see the freed line (nothing else upstream
                # of R claims it)
                taken_r = taken_mask(
                    new_lock.at[rows, X].set(-1), jnp.full((T,), R, jnp.int32)
                )
                freed_visible = ~jnp.take_along_axis(
                    jnp.pad(taken_r, ((0, 0), (0, 1))),
                    jnp.clip(lx, 0, n)[:, None], axis=1,
                )[:, 0]
                do = starved & holds_useful & has_alt & freed_visible
                alt_line = wl_X[rows, jnp.clip(alt_e, 0, E - 1)]
                new_lock = new_lock.at[:, X].set(
                    jnp.where(do, alt_line, new_lock[:, X])
                )
                new_lock = new_lock.at[:, R].set(
                    jnp.where(do, lx, new_lock[:, R])
                )
                starved = starved & ~do
        return new_lock

    lock = jnp.full((T, n), -1, jnp.int32)
    for _ in range(rounds):
        lock = lock_pass(lock)
    for _ in range(2):          # augmenting + cleanup sweeps
        lock = augment_pass(lock)
        lock = lock_pass(lock)

    # resolve entries/deltas for the final locks (nearest alias of the line)
    hit = tables.wl == lock[:, :, None]
    entry = jnp.where(hit.any(-1), jnp.argmax(hit, -1).astype(jnp.int32), -1)
    e_safe = jnp.clip(entry, 0, E - 1)
    delta = jnp.where(
        entry >= 0,
        jnp.take_along_axis(
            tables.delta, e_safe[..., None], axis=-1
        )[..., 0],
        jnp.inf,
    )
    return Assignment(entry=entry, wl=jnp.where(entry >= 0, lock, -1), delta=delta)
