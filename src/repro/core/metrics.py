"""Robustness metrics: AFP and CAFP (paper §III, Eq. 6-7).

AFP  — Arbitration Failure Probability of the *ideal* wavelength-aware
       arbiter under a policy: policy-level yield.
CAFP — Conditional Arbitration Failure Probability of a wavelength-oblivious
       *algorithm*: P(algorithm fails AND ideal succeeds), with the total
       trial count as denominator for sampling stability (Eq. 6).
Total algorithmic failure = AFP + CAFP (Eq. 7).
"""
from __future__ import annotations

import jax.numpy as jnp


def afp(ideal_success: jnp.ndarray) -> jnp.ndarray:
    """Fraction of trials where ideal arbitration fails."""
    return 1.0 - jnp.mean(ideal_success.astype(jnp.float32))


def cafp(alg_success: jnp.ndarray, ideal_success: jnp.ndarray) -> jnp.ndarray:
    """P_alg|succ(fail) * P(succ), denominator = total trials (Eq. 6)."""
    return jnp.mean((~alg_success & ideal_success).astype(jnp.float32))


def total_failure(alg_success: jnp.ndarray, ideal_success: jnp.ndarray) -> jnp.ndarray:
    """AFP + CAFP = total failure probability of the algorithm (Eq. 7)."""
    return afp(ideal_success) + cafp(alg_success, ideal_success)


def min_tr_for_complete_success(per_trial_min_tr: jnp.ndarray) -> jnp.ndarray:
    """Paper's 'minimum tuning range': smallest TR mean with zero failures."""
    return jnp.max(per_trial_min_tr)
