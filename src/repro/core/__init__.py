"""Core wavelength-arbitration library (the paper's contribution).

Public API re-exports; see DESIGN.md §2 for the layer map.
"""
from .grid import (  # noqa: F401
    POLICIES,
    ArbitrationConfig,
    DWDMGrid,
    VariationModel,
    natural_order,
    permuted_order,
    wdm_config,
)
from .sampling import (  # noqa: F401
    SystemBatch,
    UnitSamples,
    draw_unit_samples,
    instantiate,
    sample_systems,
)
from .reach import reach_matrix, scaled_residual, tuning_residual  # noqa: F401
from .api import (  # noqa: F401
    SCHEMES,
    EvalResult,
    SchemeSpec,
    evaluate_policy,
    evaluate_scheme,
    make_units,
    oblivious_arbitrate,
    policy_min_tr,
    register_scheme,
    registered_schemes,
    scheme_spec,
    shmoo,
)
from .sweep import (  # noqa: F401
    sweep_grid,
    sweep_grid_reference,
    sweep_min_tr,
    sweep_policy,
    sweep_scheme,
)
from .outcomes import Outcome, classify  # noqa: F401
from .ssm import Assignment  # noqa: F401
