"""Core wavelength-arbitration library (the paper's contribution).

Public API re-exports; see DESIGN.md §2 for the layer map.
"""
from .grid import (  # noqa: F401
    POLICIES,
    ArbitrationConfig,
    DWDMGrid,
    VariationModel,
    natural_order,
    permuted_order,
    wdm_config,
)
from .variations import (  # noqa: F401
    AxisSpec,
    Variations,
    axis_names,
    axis_spec,
    register_axis,
)
from .sampling import (  # noqa: F401
    SystemBatch,
    UnitSamples,
    draw_unit_samples,
    instantiate,
    sample_systems,
)
from .reach import reach_matrix, scaled_residual, tuning_residual  # noqa: F401
from .api import (  # noqa: F401
    SCHEME_POLICY,
    SCHEMES,
    EvalResult,
    SchemeSpec,
    evaluate_policy,
    evaluate_scheme,
    make_protocol,
    make_seq_retry,
    make_units,
    oblivious_arbitrate,
    policy_min_tr,
    register_scheme,
    register_scheme_family,
    registered_schemes,
    scheme_spec,
    shmoo,
)
from .protocol import (  # noqa: F401
    ProtocolState,
    ProtocolStats,
    cold_state,
    masked_first_entry,
    revalidate_state,
    run_protocol,
    run_protocol_trace,
)
from .temporal import (  # noqa: F401
    TemporalStats,
    Timeline,
    make_timeline,
    restore_campaign,
    run_timeline,
    save_campaign,
    slice_timeline,
)
from .sweep import (  # noqa: F401
    SweepRequest,
    SweepResult,
    sweep,
    sweep_grid,
    sweep_grid_reference,
    sweep_min_tr,
    sweep_policy,
    sweep_reference,
    sweep_scheme,
)
from .outcomes import Outcome, classify  # noqa: F401
from .ssm import Assignment  # noqa: F401
