"""Batched exact bipartite matching for the Lock-to-Any ideal arbiter.

Three exact formulations, dispatched by channel count:

  * N <= ``_HALL_MAX_N``: Hall's condition over all 2^N ring subsets —
    loop-free elementwise/reduction work (existence and bottleneck).
  * N >  ``_HALL_MAX_N``: a single-pass *bottleneck sweep* — for each left
    vertex a Dijkstra-style search over alternating paths that minimizes the
    maximum edge weight, so the bottleneck threshold comes from ONE matching
    pass instead of ~log(N^2) full Kuhn runs under a binary search.
    Existence queries reuse the same pass on 0/1 weights.
  * Kuhn's augmenting-path algorithm (``max_matching``, and the binary
    search ``_bottleneck_threshold_kuhn``): the exactness oracle the fast
    paths are pinned against bit-for-bit, and the producer of an explicit
    matching when one is needed.

All paths are vectorized over a batch of trials with fixed trip counts and
no data-dependent control flow, so they map cleanly onto TPU (Kuhn existence
and the bottleneck sweep are mirrored by the Pallas kernels in
``repro.kernels.bitmask_match``).

For Kuhn, each left vertex (ring) BFSes over alternating paths:
  frontier of wavelengths -> matched rings -> their adjacency -> ...
recording ``parent`` (the ring from which each wavelength was first reached)
so the augmenting path can be walked back in <= N steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

#: Up to this many channels, matching existence and bottleneck thresholds are
#: evaluated via Hall's condition over all 2^N ring subsets — pure
#: elementwise/reduction work with no sequential augmenting loops, which is
#: far faster on CPU and vmaps cleanly inside the sweep engine.  Beyond it
#: the subset table would dominate memory and Kuhn's algorithm takes over.
_HALL_MAX_N = 10


@functools.lru_cache(maxsize=None)
def _subset_masks(n: int) -> np.ndarray:
    """(2^n, n) bool: row s = membership mask of subset s."""
    s = np.arange(1 << n, dtype=np.uint32)
    return ((s[:, None] >> np.arange(n)) & 1).astype(bool)


@functools.lru_cache(maxsize=None)
def _sorting_network(n: int) -> tuple:
    """Batcher odd-even compare-exchange pairs for a power-of-two n.

    XLA's comparator sort is far slower than a fixed min/max network on the
    small trailing lane axis of the Hall subset table, and the network is
    pure elementwise ops so it fuses and vmaps freely.
    """
    assert n & (n - 1) == 0, n
    pairs = []

    def merge(lo, m, r):
        step = r * 2
        if step < m:
            merge(lo, m, step)
            merge(lo + r, m, step)
            pairs.extend((i, i + r) for i in range(lo + r, lo + m - r, step))
        else:
            pairs.append((lo, lo + r))

    def sort(lo, m):
        if m > 1:
            h = m // 2
            sort(lo, h)
            sort(lo + h, h)
            merge(lo, m, 1)

    sort(0, n)
    return tuple(pairs)


def adjacency_bitmask(reach: jax.Array) -> jax.Array:
    """(T, N, N) bool reach[t, ring, wl] -> packed per-ring wl bitmasks.

    N <= 32 packs into a single int32 word per ring — (T, N), the layout the
    Pallas matching kernel consumes, unchanged bit-for-bit.  Wider systems
    (e.g. WDM64) pack into ``ceil(N / 32)`` little-endian uint32 words —
    (T, N, W) — consumed by the multiword Kuhn path in ``max_matching``.
    """
    n = reach.shape[-1]
    if n > 32:
        return _pack_words(reach)
    bits = (1 << jnp.arange(n, dtype=jnp.int32))[None, None, :]
    return jnp.sum(jnp.where(reach, bits, 0), axis=-1).astype(jnp.int32)


def _pack_words(bits: jax.Array) -> jax.Array:
    """(..., n) bool -> (..., W) uint32, little-endian 32-bit words."""
    n = bits.shape[-1]
    w = -(-n // 32)
    pad = w * 32 - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bool)], axis=-1
        )
    lanes = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    grouped = bits.reshape(bits.shape[:-1] + (w, 32))
    return jnp.sum(jnp.where(grouped, lanes, jnp.uint32(0)), axis=-1,
                   dtype=jnp.uint32)


def _unpack_words(words: jax.Array, n: int) -> jax.Array:
    """(..., W) uint32 -> (..., n) bool."""
    idx = jnp.arange(n) // 32
    shift = jnp.arange(n, dtype=jnp.uint32) % 32
    return ((words[..., idx] >> shift) & 1).astype(bool)


def _augment_one(adj: jax.Array, match_wl: jax.Array, match_ring: jax.Array, i: jax.Array):
    """Try to augment the matching from left vertex (ring) ``i``.

    adj:        (T, N) int32 — ring -> wavelength bitmask
    match_wl:   (T, N) int32 — ring -> matched wl index, -1 if free
    match_ring: (T, N) int32 — wl   -> matched ring index, -1 if free
    """
    T, N = adj.shape
    rows = jnp.arange(T)

    # --- BFS over alternating paths -------------------------------------
    start = adj[rows, i]                                   # (T,) frontier bitmask
    parent = jnp.where(start[:, None] >> jnp.arange(N) & 1 == 1, i, -1).astype(jnp.int32)
    matched_mask = _matched_bitmask(match_ring)            # (T,) int32

    def bfs_body(_, carry):
        frontier, visited, parent, free_wl = carry
        # Wavelengths in frontier that are free -> augmenting path found.
        free_hit = frontier & ~matched_mask
        found_now = (free_hit != 0) & (free_wl < 0)
        free_wl = jnp.where(found_now, _lowest_bit_index(free_hit), free_wl)
        # Expand via matched rings of (non-free) frontier wavelengths.
        new_frontier = jnp.zeros_like(frontier)
        new_parent = parent

        def ring_body(r, inner):
            nf, par = inner
            # is ring r matched to some wavelength in the frontier?
            wl_of_r = match_wl[rows, r]                    # (T,)
            in_frontier = (wl_of_r >= 0) & ((frontier >> wl_of_r) & 1 == 1)
            newly = jnp.where(in_frontier, adj[rows, r] & ~visited & ~nf, 0)
            par = jnp.where((newly[:, None] >> jnp.arange(N)) & 1 == 1, r, par)
            return nf | newly, par

        new_frontier, new_parent = jax.lax.fori_loop(
            0, N, ring_body, (new_frontier, new_parent)
        )
        cont = free_wl < 0
        frontier = jnp.where(cont, new_frontier & ~visited, 0)
        visited = visited | new_frontier
        parent = jnp.where((free_wl < 0)[:, None], new_parent, parent)
        return frontier, visited, parent, free_wl

    free_wl0 = jnp.full((T,), -1, jnp.int32)
    _, _, parent, free_wl = jax.lax.fori_loop(
        0, N, bfs_body, (start, start, parent, free_wl0)
    )

    # --- walk the augmenting path back, flipping matched edges ----------
    def walk_body(_, carry):
        match_wl, match_ring, k, active = carry
        k_safe = jnp.maximum(k, 0)
        r = parent[rows, k_safe]
        r_safe = jnp.maximum(r, 0)
        prev = match_wl[rows, r_safe]                      # wl r was matched to
        match_wl = match_wl.at[rows, r_safe].set(jnp.where(active, k_safe, match_wl[rows, r_safe]))
        match_ring = match_ring.at[rows, k_safe].set(jnp.where(active, r_safe, match_ring[rows, k_safe]))
        active = active & (r_safe != i) & (prev >= 0)
        return match_wl, match_ring, jnp.where(active, prev, k), active

    active0 = free_wl >= 0
    match_wl, match_ring, _, _ = jax.lax.fori_loop(
        0, N, walk_body, (match_wl, match_ring, free_wl, active0)
    )
    return match_wl, match_ring


def _matched_bitmask(match_ring: jax.Array) -> jax.Array:
    """(T, N) wl->ring matching -> (T,) bitmask of matched wavelengths."""
    N = match_ring.shape[1]
    bits = (1 << jnp.arange(N, dtype=jnp.int32))[None, :]
    return jnp.sum(jnp.where(match_ring >= 0, bits, 0), axis=1).astype(jnp.int32)


def _lowest_bit_index(x: jax.Array) -> jax.Array:
    """Index of lowest set bit (x != 0 assumed where used)."""
    lsb = x & -x
    return (31 - jax.lax.clz(lsb)).astype(jnp.int32)


def _augment_one_wide(adj: jax.Array, match_wl: jax.Array, match_ring: jax.Array, i: jax.Array):
    """Kuhn augmentation from ring ``i`` on an unpacked (T, N, N) bool
    adjacency — the N > 32 mirror of ``_augment_one``.  Frontier/visited
    masks are (T, N) bool lanes instead of int32 words; identical BFS order
    (lowest wavelength index first), so matchings agree with the single-word
    path wherever both apply."""
    T, N, _ = adj.shape
    rows = jnp.arange(T)

    start = adj[rows, i]                                   # (T, N) bool
    parent = jnp.where(start, i, -1).astype(jnp.int32)
    matched = match_ring >= 0                              # (T, N) bool

    def bfs_body(_, carry):
        frontier, visited, parent, free_wl = carry
        free_hit = frontier & ~matched
        found_now = free_hit.any(axis=1) & (free_wl < 0)
        free_wl = jnp.where(
            found_now, jnp.argmax(free_hit, axis=1).astype(jnp.int32), free_wl
        )
        new_frontier = jnp.zeros_like(frontier)
        new_parent = parent

        def ring_body(r, inner):
            nf, par = inner
            wl_of_r = match_wl[rows, r]                    # (T,)
            in_frontier = (wl_of_r >= 0) & jnp.take_along_axis(
                frontier, jnp.maximum(wl_of_r, 0)[:, None], axis=1
            )[:, 0]
            newly = adj[rows, r] & ~visited & ~nf & in_frontier[:, None]
            par = jnp.where(newly, r, par)
            return nf | newly, par

        new_frontier, new_parent = jax.lax.fori_loop(
            0, N, ring_body, (new_frontier, new_parent)
        )
        cont = free_wl < 0
        frontier = jnp.where(cont[:, None], new_frontier & ~visited, False)
        visited = visited | new_frontier
        parent = jnp.where(cont[:, None], new_parent, parent)
        return frontier, visited, parent, free_wl

    free_wl0 = jnp.full((T,), -1, jnp.int32)
    _, _, parent, free_wl = jax.lax.fori_loop(
        0, N, bfs_body, (start, start, parent, free_wl0)
    )

    def walk_body(_, carry):
        match_wl, match_ring, k, active = carry
        k_safe = jnp.maximum(k, 0)
        r = parent[rows, k_safe]
        r_safe = jnp.maximum(r, 0)
        prev = match_wl[rows, r_safe]
        match_wl = match_wl.at[rows, r_safe].set(jnp.where(active, k_safe, match_wl[rows, r_safe]))
        match_ring = match_ring.at[rows, k_safe].set(jnp.where(active, r_safe, match_ring[rows, k_safe]))
        active = active & (r_safe != i) & (prev >= 0)
        return match_wl, match_ring, jnp.where(active, prev, k), active

    active0 = free_wl >= 0
    match_wl, match_ring, _, _ = jax.lax.fori_loop(
        0, N, walk_body, (match_wl, match_ring, free_wl, active0)
    )
    return match_wl, match_ring


@jax.jit
def max_matching(adj: jax.Array):
    """Run Kuhn over all left vertices.  Returns (match_wl, match_ring).

    Accepts either a single-word (T, N) int32 adjacency (N <= 32, the
    original path, unchanged) or a multiword (T, N, W) uint32 one from
    ``adjacency_bitmask`` at N > 32, which runs on unpacked bool lanes.
    """
    if adj.ndim == 3:
        t, n, _ = adj.shape
        adj_bool = _unpack_words(adj, n)                   # square: N wls
        match_wl = jnp.full((t, n), -1, jnp.int32)
        match_ring = jnp.full((t, n), -1, jnp.int32)

        def body_wide(i, carry):
            return _augment_one_wide(adj_bool, *carry, i=i)

        return jax.lax.fori_loop(0, n, body_wide, (match_wl, match_ring))

    T, N = adj.shape
    match_wl = jnp.full((T, N), -1, jnp.int32)
    match_ring = jnp.full((T, N), -1, jnp.int32)

    def body(i, carry):
        return _augment_one(adj, *carry, i=i)

    return jax.lax.fori_loop(0, N, body, (match_wl, match_ring))


def _has_perfect_matching_hall(reach: jax.Array) -> jax.Array:
    """Hall's condition: a perfect matching exists iff every ring subset S
    reaches at least |S| laser lines.  Loop-free (the n-step accumulation
    unrolls to elementwise ops on a (T, 2^n, n) table)."""
    T, n, _ = reach.shape
    sub = jnp.asarray(_subset_masks(n))                    # (S, n)
    size = jnp.asarray(_subset_masks(n).sum(1), jnp.int32)  # (S,)
    nbr = jnp.zeros((T, sub.shape[0], n), bool)
    for i in range(n):
        nbr = jnp.where(sub[None, :, i:i + 1], nbr | reach[:, None, i, :], nbr)
    ok = nbr.sum(axis=-1) >= size[None, :]
    return ok.all(axis=1)


def has_perfect_matching(reach: jax.Array) -> jax.Array:
    """(T, N, N) bool reach -> (T,) bool perfect matching existence.

    N > ``_HALL_MAX_N`` runs the bottleneck sweep on 0/1 weights: a perfect
    matching within ``reach`` exists iff a bottleneck using only weight-0
    edges exists.  One pass, ~N x fewer sequential steps than Kuhn (whose
    BFS nests an N-trip ring expansion inside each of N levels); booleans
    are identical to ``max_matching`` (both exact).
    """
    if reach.shape[-1] <= _HALL_MAX_N:
        return _has_perfect_matching_hall(reach)
    weights = jnp.where(reach, jnp.float32(0), jnp.float32(1))
    return _bottleneck_threshold_sweep(weights) < 0.5


def _bottleneck_threshold_sweep(weights: jax.Array) -> jax.Array:
    """Single-pass bottleneck matching threshold for a (T, N, N) batch.

    Incremental formulation: left vertices (rings) are inserted one at a
    time; for each, a Dijkstra-style search over alternating paths finds the
    augmenting path minimizing the maximum edge weight along it
    (``dist[k]`` = cheapest achievable path bottleneck from vertex ``i`` to
    wavelength ``k`` given the current matching).  The global threshold is
    the running max of the per-vertex augmentation bottlenecks — exactly the
    minimum t such that {weights <= t} admits a perfect matching, because
    feasible thresholds for covering the first i vertices form an up-set and
    a maximum matching on cheaper edges always extends by one augmenting
    path.  Only comparisons and max-compositions of input values are
    performed, so the result is bit-for-bit one of the N^2 edge weights and
    identical to the Kuhn binary-search oracle.

    Fixed trip counts throughout: N vertices x (N selection steps + N
    walk-back steps) — one matching pass, vs ~ceil(log2 N^2)+1 full Kuhn
    runs for the binary search it replaces.
    """
    T, N, _ = weights.shape
    rows = jnp.arange(T)
    inf = jnp.float32(jnp.inf)

    def per_vertex(i, carry):
        match_wl, match_ring, thr = carry

        # --- Dijkstra over alternating paths, bottleneck (max) metric ----
        dist = weights[:, i, :]                        # (T, N)
        parent = jnp.full((T, N), i, jnp.int32)        # wl -> relaxing ring
        visited = jnp.zeros((T, N), bool)

        def select_relax(_, c):
            dist, parent, visited = c
            d = jnp.where(visited, inf, dist)
            k = jnp.argmin(d, axis=1).astype(jnp.int32)   # (T,) settled wl
            dk = jnp.min(d, axis=1)
            visited = visited.at[rows, k].set(True)
            r = match_ring[rows, k]                    # matched ring or -1
            r_safe = jnp.maximum(r, 0)
            cand = jnp.maximum(dk[:, None], weights[rows, r_safe, :])
            # Free wavelengths end the path: no expansion through them.
            better = (r[:, None] >= 0) & ~visited & (cand < dist)
            dist = jnp.where(better, cand, dist)
            parent = jnp.where(better, r_safe[:, None], parent)
            return dist, parent, visited

        dist, parent, _ = jax.lax.fori_loop(
            0, N, select_relax, (dist, parent, visited)
        )

        # --- cheapest free wavelength = this vertex's augmentation cost ---
        df = jnp.where(match_ring < 0, dist, inf)      # >= 1 free wl always
        k0 = jnp.argmin(df, axis=1).astype(jnp.int32)
        thr = jnp.maximum(thr, jnp.min(df, axis=1))

        # --- walk the augmenting path back, flipping matched edges -------
        def walk(_, c):
            match_wl, match_ring, k, active = c
            r = parent[rows, k]
            prev = match_wl[rows, r]                   # wl r was matched to
            match_wl = match_wl.at[rows, r].set(
                jnp.where(active, k, match_wl[rows, r])
            )
            match_ring = match_ring.at[rows, k].set(
                jnp.where(active, r, match_ring[rows, k])
            )
            active = active & (r != i)
            return match_wl, match_ring, jnp.where(active, jnp.maximum(prev, 0), k), active

        match_wl, match_ring, _, _ = jax.lax.fori_loop(
            0, N, walk, (match_wl, match_ring, k0, jnp.ones((T,), bool))
        )
        return match_wl, match_ring, thr

    match_wl0 = jnp.full((T, N), -1, jnp.int32)
    match_ring0 = jnp.full((T, N), -1, jnp.int32)
    thr0 = jnp.full((T,), -jnp.inf, jnp.float32)
    _, _, thr = jax.lax.fori_loop(0, N, per_vertex, (match_wl0, match_ring0, thr0))
    return thr


def _bottleneck_threshold_hall(weights: jax.Array) -> jax.Array:
    """Bottleneck threshold via Hall: subset S becomes satisfiable once the
    |S|-th smallest of (min over i in S of w[i, k]) is reached, and the
    bottleneck is the worst subset's requirement.  One shot, no search.

    Runs the subset DP on uint8 *ranks* instead of f32 weights (4x less
    traffic through the (T, 2^N, N) table; this path is memory-bound), with
    ranks from an all-pairs comparison count and the k-th selection from a
    fixed min/max sorting network — no XLA comparator sorts anywhere.  Rank
    -> value is monotone (ties share a rank and a value) so every comparison,
    selection and max lands on the same edge weight the f32 computation would
    pick — the result stays bit-for-bit equal to the binary-search reference.
    """
    T, n, _ = weights.shape
    sub = jnp.asarray(_subset_masks(n))                    # (S, n)
    size = jnp.asarray(_subset_masks(n).sum(1), jnp.int32)
    n_sub = sub.shape[0]
    flat = weights.reshape(T, n * n)
    # rank_e = |{e' : w_e' < w_e}|  (== searchsorted-left into sorted edges)
    ranks = jnp.sum(
        (flat[:, None, :] < flat[:, :, None]), axis=-1
    ).astype(jnp.uint8)                                    # (T, n^2), max n^2-1
    rank_grid = ranks.reshape(T, n, n)
    minr = jnp.full((T, n_sub, n), 255, jnp.uint8)
    for i in range(n):
        minr = jnp.where(
            sub[None, :, i:i + 1], jnp.minimum(minr, rank_grid[:, None, i, :]), minr
        )
    # Ascending per-subset lanes via the compare-exchange network (255-padded
    # to the next power of two; pads sink to the tail, past any real size).
    m = 1 << (n - 1).bit_length()
    lanes = [minr[..., k] for k in range(n)]
    lanes += [jnp.full(minr.shape[:-1], 255, jnp.uint8)] * (m - n)
    for i, j in _sorting_network(m):
        lanes[i], lanes[j] = (
            jnp.minimum(lanes[i], lanes[j]), jnp.maximum(lanes[i], lanes[j])
        )
    vals = jnp.stack(lanes, axis=-1)                       # (T, S, m) ascending
    idx = jnp.broadcast_to(
        jnp.clip(size - 1, 0)[None, :, None], (T, n_sub, 1)
    )
    req = jnp.take_along_axis(vals, idx, axis=-1)[..., 0]  # (T, S) uint8 ranks
    req = jnp.where(size[None, :] > 0, req, 0)
    bottleneck_rank = req.max(axis=1)                      # (T,)
    # The bottleneck is the edge weight carrying that rank (ties share it).
    return jnp.max(
        jnp.where(ranks == bottleneck_rank[:, None], flat, -jnp.inf), axis=-1
    )


def bottleneck_matching_threshold(weights: jax.Array) -> jax.Array:
    """Minimum t such that a perfect matching exists in {weights <= t}.

    weights: (T, N, N) scaled residuals (ring x wl).  Small N uses the
    loop-free Hall formulation; larger N the single-pass bottleneck sweep
    (``_bottleneck_threshold_sweep``).  The bottleneck value is always one
    of the N^2 edge weights, bit-for-bit equal to the retired Kuhn binary
    search (``_bottleneck_threshold_kuhn``, kept as the exactness oracle).
    Returns (T,) float32.
    """
    if weights.shape[-1] <= _HALL_MAX_N:
        return _bottleneck_threshold_hall(weights)
    return _bottleneck_threshold_sweep(weights)


def _bottleneck_threshold_kuhn(weights: jax.Array, n_steps: int | None = None) -> jax.Array:
    """Exactness oracle: binary search over sorted per-trial edge weights
    with a full Kuhn matching-existence query per step — the pre-sweep
    (PR 1) N > ``_HALL_MAX_N`` path, ~ceil(log2 N^2)+1 Kuhn runs."""
    T, N, _ = weights.shape
    flat = weights.reshape(T, N * N)
    cand = jnp.sort(flat, axis=1)                          # (T, N^2) ascending
    steps = n_steps if n_steps is not None else int(math.ceil(math.log2(N * N))) + 1

    lo = jnp.zeros((T,), jnp.int32)
    hi = jnp.full((T,), N * N - 1, jnp.int32)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        thr = cand[jnp.arange(T), mid]
        mw, _ = max_matching(adjacency_bitmask(weights <= thr[:, None, None]))
        ok = jnp.all(mw >= 0, axis=1)
        return jnp.where(ok, lo, mid + 1), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return cand[jnp.arange(T), hi]
