"""Declarative device-variation overrides: the ``Variations`` pytree and the
axis registry that makes new variation sources first-class sweep axes.

The paper evaluates every policy/scheme across device-variability axes
(sigma_rLV, sigma_FSR, sigma_TR, grid offset, laser local variation — §II-C,
Figs. 4-16).  Pre-redesign, those axes were seven positional/keyword scalars
copy-pasted through every evaluation signature; adding one variation source
meant editing ~6 signatures and every benchmark.  This module replaces the
kwarg zoo with two objects:

``register_axis(name, default, ...)``
    One registration makes a variation axis known everywhere at once: it is
    a valid ``Variations`` key, a valid ``SweepRequest`` axis/fixed name, and
    (via an optional ``transform`` hook) applied during ``instantiate`` —
    no signature edits anywhere.  ``thermal_drift`` below is the in-tree
    demonstration: a post-paper axis added with a single call.

``Variations(**overrides)``
    A frozen name -> value mapping registered as a jax pytree.  The key set
    is part of the treedef (jit-static), the values are leaves (traced), so
    sweeping a value never recompiles while adding/removing an override
    recompiles exactly once — the same caching contract the old per-kwarg
    API had.  ``None`` means "use the config default" and is normalized
    away at construction: ``Variations(sigma_rlv=None)`` carries no
    overrides, indistinguishable from ``Variations()`` (same treedef).

Resolution order for an axis value: explicit override in the ``Variations``
instance, else the registry default evaluated against the
``ArbitrationConfig`` (e.g. ``sigma_rlv`` falls back to ``cfg.var.sigma_rlv``,
``tr_mean`` to ``cfg.grid.tr_mean``).
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Iterable, Mapping, NamedTuple

import jax
import jax.numpy as jnp


class AxisSpec(NamedTuple):
    """Registry record for one variation/TR axis.

    ``default``   cfg -> default value used when no override is present.
    ``validate``  optional check run on *concrete* values only (sweep axis
                  coordinates, fixed scalars, plain-float overrides); traced
                  values inside jit are never validated.
    ``transform`` optional ``(sys, value, cfg) -> sys`` hook applied by
                  ``instantiate`` after the core sampling math whenever the
                  axis is overridden — how post-paper axes (thermal drift,
                  per-channel effects, ...) plug in without touching
                  ``sampling.py``.
    """

    name: str
    default: Callable[[Any], Any]
    doc: str = ""
    validate: Callable[[float], None] | None = None
    transform: Callable[[Any, Any, Any], Any] | None = None


_AXIS_REGISTRY: dict[str, AxisSpec] = {}


def register_axis(
    name: str,
    default: Callable[[Any], Any],
    *,
    doc: str = "",
    validate: Callable[[float], None] | None = None,
    transform: Callable[[Any, Any, Any], Any] | None = None,
) -> AxisSpec:
    """Register a variation axis; see the module docstring for what that buys.

    Axis names are jit-static (they live in ``Variations`` treedefs and the
    sweep engine's static argument tuples), so re-binding a name would
    silently serve stale compiled code — duplicate registration is an error.
    """
    if not isinstance(name, str) or not name.isidentifier():
        raise ValueError(f"axis name must be an identifier, got {name!r}")
    if name in _AXIS_REGISTRY:
        raise ValueError(f"variation axis {name!r} already registered")
    spec = AxisSpec(name=name, default=default, doc=doc, validate=validate,
                    transform=transform)
    _AXIS_REGISTRY[name] = spec
    return spec


def axis_names() -> tuple[str, ...]:
    """Registered axis names, in registration order (live, never stale)."""
    return tuple(_AXIS_REGISTRY)


def axis_spec(name: str) -> AxisSpec:
    try:
        return _AXIS_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown variation axis {name!r}; registered: {axis_names()}"
        ) from None


def _maybe_validate(spec: AxisSpec, value) -> None:
    if spec.validate is None or isinstance(value, jax.core.Tracer):
        return
    try:
        concrete = float(value)
    except (TypeError, ValueError):
        return  # non-scalar/abstract value; nothing to check host-side
    spec.validate(concrete)


class Variations:
    """Frozen axis-name -> override mapping; a jax pytree (see module doc)."""

    __slots__ = ("_overrides",)

    def __init__(self, **overrides):
        clean = {}
        for name in sorted(overrides):  # canonical key order -> one treedef
            value = overrides[name]
            if value is None:
                continue
            spec = axis_spec(name)
            _maybe_validate(spec, value)
            clean[name] = value
        object.__setattr__(self, "_overrides", clean)

    def __setattr__(self, name, value):
        raise AttributeError("Variations is immutable; use .replace(...)")

    # -- mapping-ish accessors ------------------------------------------
    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self._overrides)

    def get(self, name: str, default=None):
        axis_spec(name)  # typo guard
        return self._overrides.get(name, default)

    def items(self) -> tuple:
        return tuple(self._overrides.items())

    def __contains__(self, name: str) -> bool:
        return name in self._overrides

    def __len__(self) -> int:
        return len(self._overrides)

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v!r}" for k, v in self._overrides.items())
        return f"Variations({body})"

    # -- functional updates ---------------------------------------------
    def replace(self, **overrides) -> "Variations":
        """New instance with overrides added/updated (``None`` removes)."""
        merged = dict(self._overrides)
        for name, value in overrides.items():
            if value is None:
                merged.pop(name, None)
            else:
                merged[name] = value
        return Variations(**merged)

    def merge(self, other) -> "Variations":
        """Union with a mapping/``Variations``; duplicate axes are an error
        (a silent precedence rule would hide caller bugs)."""
        items = dict(other.items()) if isinstance(other, Variations) else dict(other)
        items = {k: v for k, v in items.items() if v is not None}
        dup = sorted(set(items) & set(self._overrides))
        if dup:
            raise ValueError(f"variation axes specified twice: {dup}")
        return self.replace(**items)

    # -- resolution ------------------------------------------------------
    def resolve(self, name: str, cfg):
        """Override if present, else the registry default under ``cfg``."""
        spec = axis_spec(name)
        value = self._overrides.get(name)
        return spec.default(cfg) if value is None else value


def _variations_flatten(v: Variations):
    names = tuple(v._overrides)
    return tuple(v._overrides[n] for n in names), names


def _variations_unflatten(names, children) -> Variations:
    # Bypass __init__: unflatten must round-trip tracers and jax-internal
    # sentinel objects without validation.
    out = object.__new__(Variations)
    object.__setattr__(out, "_overrides", dict(zip(names, children)))
    return out


jax.tree_util.register_pytree_node(
    Variations, _variations_flatten, _variations_unflatten
)


def as_variations(value) -> Variations:
    """Coerce ``None`` / mapping / ``Variations`` to a ``Variations``."""
    if value is None:
        return Variations()
    if isinstance(value, Variations):
        return value
    if isinstance(value, Mapping):
        return Variations(**dict(value))
    raise TypeError(
        f"expected a Variations, mapping, or None, got {type(value).__name__}: "
        f"{value!r} — pass overrides as Variations(sigma_rlv=...) (the old "
        "positional-scalar convention was removed; the sigma_*= keywords "
        "remain as deprecated shims)"
    )


#: Keyword names of the pre-``Variations`` sampling/evaluation API, kept as
#: deprecated shims (signature order matches the old ``instantiate``).
LEGACY_SIGMA_KWARGS = (
    "sigma_rlv",
    "sigma_go",
    "sigma_llv_frac",
    "sigma_fsr_frac",
    "sigma_tr_frac",
    "fsr_mean",
)


def merge_legacy_overrides(variations, legacy: Mapping[str, Any], *,
                           caller: str, stacklevel: int = 3) -> Variations:
    """Fold deprecated ``sigma_* =`` keyword overrides into a ``Variations``.

    Emits ``DeprecationWarning`` when any legacy kwarg is actually given;
    results are bit-identical to passing the same values via the pytree
    (asserted in tests/test_variations.py).  Specifying an axis both ways
    is an error.  ``stacklevel`` is the warning's attribution depth: 3
    points at the caller of a function that calls this directly
    (``instantiate``); evaluators with an intermediate frame pass 4 so the
    warning names the user's call site, not library internals.
    """
    base = as_variations(variations)
    given = {k: v for k, v in legacy.items() if v is not None}
    if not given:
        return base
    warnings.warn(
        f"{caller}: the {sorted(given)} keyword overrides are deprecated; "
        "pass variations=Variations(...) instead",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
    return base.merge(given)


def apply_axis_transforms(sys, variations: Variations, cfg):
    """Run the ``transform`` hook of every overridden axis that has one.

    Called by ``instantiate`` after the core sampling math; axes without an
    override are skipped entirely, so the default path is bit-identical to
    the pre-registry implementation.  Hooks run in axis *registration*
    order (the engine-facing axis order), not override spelling order, so
    composing non-commuting transforms is deterministic and documented.
    """
    for name, spec in _AXIS_REGISTRY.items():
        if spec.transform is not None and name in variations:
            sys = spec.transform(sys, variations.get(name), cfg)
    return sys


# --------------------------------------------------------------------------
# Built-in axes (paper §II-C, Table I).  Registration order is the
# engine-facing axis order; the first seven match the pre-registry
# ``AXIS_NAMES`` tuple exactly.
# --------------------------------------------------------------------------

def _nonneg(name: str) -> Callable[[float], None]:
    def check(v: float) -> None:
        if v < 0.0:
            raise ValueError(f"axis {name!r} must be >= 0, got {v}")
    return check


def _positive(name: str) -> Callable[[float], None]:
    def check(v: float) -> None:
        if v <= 0.0:
            raise ValueError(f"axis {name!r} must be > 0, got {v}")
    return check


def _llv_frac_check(v: float) -> None:
    if not 0.0 <= v < 0.5:
        raise ValueError(
            "axis 'sigma_llv_frac' must be in [0, 0.5) to keep the laser "
            f"grid monotone (paper §II-C), got {v}"
        )


register_axis(
    "tr_mean", lambda cfg: cfg.grid.tr_mean,
    doc="mean tuning range lambda_TR [nm] (the shmoo x-axis of Figs. 4/14-16)",
    validate=_positive("tr_mean"),
)
register_axis(
    "sigma_rlv", lambda cfg: cfg.var.sigma_rlv,
    doc="ring local resonance variation half-range [nm] (Table I)",
    validate=_nonneg("sigma_rlv"),
)
register_axis(
    "sigma_go", lambda cfg: cfg.var.sigma_go,
    doc="grid offset half-range sigma_lGV + sigma_rGV [nm] (Table I)",
    validate=_nonneg("sigma_go"),
)
register_axis(
    "sigma_llv_frac", lambda cfg: cfg.var.sigma_llv_frac,
    doc="laser local variation half-range, fraction of grid spacing",
    validate=_llv_frac_check,
)
register_axis(
    "sigma_fsr_frac", lambda cfg: cfg.var.sigma_fsr_frac,
    doc="FSR variation half-range, fraction of the FSR mean",
    validate=_nonneg("sigma_fsr_frac"),
)
register_axis(
    "sigma_tr_frac", lambda cfg: cfg.var.sigma_tr_frac,
    doc="tuning-range variation half-range, fraction of the TR mean",
    validate=_nonneg("sigma_tr_frac"),
)
register_axis(
    "fsr_mean", lambda cfg: cfg.grid.fsr,
    doc="mean free spectral range lambda_FSR [nm] (Fig. 8 design axis)",
    validate=_positive("fsr_mean"),
)
# Post-paper axis, added entirely through the registry: a uniform thermal
# red-shift of every ring resonance (substrate heating moves the whole row
# together; lasers are assumed independently stabilized).  Exists to prove
# the extension contract — registered once, immediately sweepable.
register_axis(
    "thermal_drift", lambda cfg: 0.0,
    doc="uniform thermal red-shift of every ring resonance [nm]",
    transform=lambda sys, value, cfg: sys._replace(ring=sys.ring + value),
)
# Trajectory axes for the temporal layer (``core/temporal.py``): a timeline
# step is just a ``Variations`` override re-applied per ``lax.scan`` step —
# ``thermal_drift`` carries the per-step ring offset ((N,) broadcasts over
# trials) and these two model the remaining drift sources.  Registered like
# any other axis, they are also directly sweepable as static offsets.
register_axis(
    "comb_wander", lambda cfg: 0.0,
    doc="uniform comb-source wander: shift of every laser line [nm]",
    transform=lambda sys, value, cfg: sys._replace(laser=sys.laser + value),
)
register_axis(
    "ring_aging", lambda cfg: 0.0,
    doc=("differential aging tilt across the ring row [nm]: ring i "
         "red-shifts by value * i / (N - 1)"),
    transform=lambda sys, value, cfg: sys._replace(
        ring=sys.ring + value * (
            jnp.arange(sys.ring.shape[-1], dtype=sys.ring.dtype)
            / max(1, sys.ring.shape[-1] - 1)
        )
    ),
)
