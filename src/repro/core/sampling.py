"""Monte-Carlo sampling of multi-wavelength lasers and microring rows.

The paper's experiments cross ``n_laser`` laser samples with ``n_ring``
microring-row samples (100 x 100 = 10,000 trials).  To support sweeping the
variation half-ranges (sigma_*) without re-sampling, we draw *unit* uniform
deviates in [-1, 1] once and scale them by the sigma values at
instantiation — sample-efficient exploration exactly as the paper's
uniform-distribution rationale intends (§II-C).

Overrides are carried by the ``Variations`` pytree (``repro.core.variations``):
``instantiate(cfg, units, Variations(sigma_rlv=2.24))``.  The old per-sigma
keyword arguments remain as deprecated shims with identical numerics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .grid import ArbitrationConfig, DWDMGrid, VariationModel
from .variations import Variations, apply_axis_transforms, merge_legacy_overrides


class UnitSamples(NamedTuple):
    """Unit uniform deviates in [-1, 1]; scaled by sigma at instantiation."""

    u_go: jax.Array    # (L, 1)  grid offset per laser sample
    u_llv: jax.Array   # (L, N)  laser local variation
    u_rlv: jax.Array   # (R, N)  ring local resonance variation
    u_fsr: jax.Array   # (R, N)  FSR variation
    u_tr: jax.Array    # (R, N)  tuning-range variation


class SystemBatch(NamedTuple):
    """A batch of T sampled systems, projected onto the wavelength domain.

    All wavelengths relative to lambda_center.  ``tr_unit`` is the per-ring
    tuning-range multiplier (1 + Delta_TR/TR); actual TR_i = tr_mean * tr_unit.
    """

    laser: jax.Array    # (T, N) laser wavelengths, ascending in channel index
    ring: jax.Array     # (T, N) ring resonance wavelengths (physical index i)
    fsr: jax.Array      # (T, N) per-ring FSR
    tr_unit: jax.Array  # (T, N) per-ring tuning-range multiplier

    @property
    def n_trials(self) -> int:
        return self.laser.shape[0]

    @property
    def n_ch(self) -> int:
        return self.laser.shape[1]


def draw_unit_samples(key: jax.Array, n_ch: int, n_laser: int, n_ring: int) -> UnitSamples:
    ks = jax.random.split(key, 5)
    u = lambda k, shape: jax.random.uniform(k, shape, jnp.float32, -1.0, 1.0)
    return UnitSamples(
        u_go=u(ks[0], (n_laser, 1)),
        u_llv=u(ks[1], (n_laser, n_ch)),
        u_rlv=u(ks[2], (n_ring, n_ch)),
        u_fsr=u(ks[3], (n_ring, n_ch)),
        u_tr=u(ks[4], (n_ring, n_ch)),
    )


def instantiate(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    variations: Variations | None = None,
    *,
    sigma_rlv: float | None = None,
    sigma_go: float | None = None,
    sigma_llv_frac: float | None = None,
    sigma_fsr_frac: float | None = None,
    sigma_tr_frac: float | None = None,
    fsr_mean: float | None = None,
) -> SystemBatch:
    """Apply sigma scales to unit samples and cross lasers x rings (Eq. 3-4).

    ``variations`` (a ``Variations`` pytree or plain mapping) carries the
    overrides; unset axes fall back to the config via the axis registry.
    The ``sigma_* =`` keywords are the deprecated pre-pytree shims — bit-
    identical, but they warn.  Registered extension axes (e.g.
    ``thermal_drift``) are applied through their ``transform`` hooks after
    the core sampling math; ``tr_mean`` overrides are ignored here (the
    tuning range is an evaluation-time quantity, not a sampling one).
    """
    over = merge_legacy_overrides(
        variations,
        dict(sigma_rlv=sigma_rlv, sigma_go=sigma_go,
             sigma_llv_frac=sigma_llv_frac, sigma_fsr_frac=sigma_fsr_frac,
             sigma_tr_frac=sigma_tr_frac, fsr_mean=fsr_mean),
        caller="instantiate",
    )
    grid = cfg.grid
    s_go = over.resolve("sigma_go", cfg)
    s_llv = over.resolve("sigma_llv_frac", cfg) * grid.grid_spacing
    s_rlv = over.resolve("sigma_rlv", cfg)
    s_fsr = over.resolve("sigma_fsr_frac", cfg)
    s_tr = over.resolve("sigma_tr_frac", cfg)
    fsr0 = over.resolve("fsr_mean", cfg)

    # Lasers: lambda_i = grid_i + Delta_gO + Delta_lLV,i           (Eq. 3)
    laser = (
        jnp.asarray(grid.laser_grid())[None, :]
        + s_go * units.u_go
        + s_llv * units.u_llv
    )  # (L, N)
    # Rings: lambda_i = grid(r_i) - lambda_rB + Delta_rLV,i        (Eq. 4)
    ring = jnp.asarray(grid.ring_grid(cfg.r))[None, :] + s_rlv * units.u_rlv  # (R, N)
    fsr = fsr0 * (1.0 + s_fsr * units.u_fsr)     # (R, N)
    tr_unit = 1.0 + s_tr * units.u_tr            # (R, N)

    L, R, N = laser.shape[0], ring.shape[0], laser.shape[1]
    T = L * R
    # Cross product lasers x rings -> T trials.
    laser_t = jnp.broadcast_to(laser[:, None, :], (L, R, N)).reshape(T, N)
    ring_t = jnp.broadcast_to(ring[None, :, :], (L, R, N)).reshape(T, N)
    fsr_t = jnp.broadcast_to(fsr[None, :, :], (L, R, N)).reshape(T, N)
    tr_t = jnp.broadcast_to(tr_unit[None, :, :], (L, R, N)).reshape(T, N)
    sys = SystemBatch(laser=laser_t, ring=ring_t, fsr=fsr_t, tr_unit=tr_t)
    return apply_axis_transforms(sys, over, cfg)


def sample_systems(
    key: jax.Array,
    cfg: ArbitrationConfig,
    n_laser: int = 100,
    n_ring: int = 100,
    variations: Variations | None = None,
    **sigma_overrides,
) -> SystemBatch:
    """Convenience: draw units and instantiate in one go."""
    units = draw_unit_samples(key, cfg.grid.n_ch, n_laser, n_ring)
    return instantiate(cfg, units, variations, **sigma_overrides)
