"""Pure-Python per-trial reference oracle for the arbitration system.

Deliberately written as straightforward scalar code, independent of the
vectorized JAX implementation, so the two can cross-validate each other in
tests (including hypothesis property tests).  Semantics follow the paper
(§II, §V) and are documented inline.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

PHI = None  # relation-not-found sentinel


@dataclass
class Trial:
    laser: np.ndarray   # (N,) ascending laser lines [nm, relative]
    ring: np.ndarray    # (N,) ring resonances by physical index
    fsr: np.ndarray     # (N,)
    tr: np.ndarray      # (N,) actual per-ring tuning ranges


def residual(trial: Trial, i: int, k: int) -> float:
    """Minimum red-shift of ring i to reach laser line k."""
    return float((trial.laser[k] - trial.ring[i]) % trial.fsr[i])


def reach(trial: Trial, i: int, k: int) -> bool:
    return residual(trial, i, k) <= trial.tr[i]


# ----------------------------------------------------------- ideal arbiters
def ltd_ok(trial: Trial, s: Sequence[int]) -> bool:
    return all(reach(trial, i, s[i]) for i in range(len(s)))


def ltc_ok(trial: Trial, s: Sequence[int]) -> bool:
    n = len(s)
    return any(
        all(reach(trial, i, (s[i] + c) % n) for i in range(n)) for c in range(n)
    )


def lta_ok(trial: Trial) -> bool:
    """Perfect matching existence — Kuhn's algorithm, recursive."""
    n = len(trial.laser)
    adj = [[k for k in range(n) if reach(trial, i, k)] for i in range(n)]
    match_ring: List[Optional[int]] = [None] * n  # wl -> ring

    def try_augment(i: int, seen: List[bool]) -> bool:
        for k in adj[i]:
            if not seen[k]:
                seen[k] = True
                if match_ring[k] is None or try_augment(match_ring[k], seen):
                    match_ring[k] = i
                    return True
        return False

    return all(try_augment(i, [False] * n) for i in range(n))


def min_tr(trial: Trial, policy: str, s: Sequence[int], tr_unit: np.ndarray) -> float:
    """Minimum mean TR for success; tr_unit = per-ring (1 + Delta_TR)."""
    n = len(s)
    scaled = np.array(
        [[residual(trial, i, k) / tr_unit[i] for k in range(n)] for i in range(n)]
    )
    if policy == "ltd":
        return float(max(scaled[i, s[i]] for i in range(n)))
    if policy == "ltc":
        return float(
            min(
                max(scaled[i, (s[i] + c) % n] for i in range(n))
                for c in range(n)
            )
        )
    if policy == "lta":
        # Bottleneck assignment by brute force (tests use small N).
        assert n <= 8, "reference LtA bottleneck is brute-force"
        return float(
            min(
                max(scaled[i, p[i]] for i in range(n))
                for p in itertools.permutations(range(n))
            )
        )
    raise ValueError(policy)


# ----------------------------------------------------------- search tables
def search_table(
    trial: Trial, i: int, visible: Optional[Sequence[bool]] = None
) -> List[Tuple[float, int]]:
    """Ascending (delta, line) peaks for ring i's wavelength sweep."""
    out = []
    for k in range(len(trial.laser)):
        if visible is not None and not visible[k]:
            continue
        base = (trial.laser[k] - trial.ring[i]) % trial.fsr[i]
        d = float(base)
        while d <= trial.tr[i]:
            out.append((d, k))
            d += float(trial.fsr[i])
    out.sort()
    return out


# ---------------------------------------------------- relation search (RS)
def unit_relation_search(
    trial: Trial, agg: int, vic: int, entry: int
) -> Optional[int]:
    """Aggressor (upstream) locks ST(agg)[entry]; victim diffs its table."""
    st_a = search_table(trial, agg)
    st_v = search_table(trial, vic)
    if not (0 <= entry < len(st_a)):
        return PHI
    line = st_a[entry][1]
    masked = [idx for idx, (_, k) in enumerate(st_v) if k == line]
    if not masked:
        return PHI
    return masked[0] - entry


def relation_search_pair(
    trial: Trial, agg: int, vic: int, n_ch: int, variation_tolerant: bool
) -> Optional[int]:
    st_a = search_table(trial, agg)
    ri_last = unit_relation_search(trial, agg, vic, len(st_a) - 1)
    ri_first = unit_relation_search(trial, agg, vic, 0)
    if ri_last is not PHI and ri_first is not PHI:
        ri = ri_last if (ri_last - ri_first) % n_ch == 0 else PHI
    else:
        ri = ri_last if ri_last is not PHI else ri_first
    if ri is PHI and variation_tolerant and len(st_a) >= 2:
        ri = unit_relation_search(trial, agg, vic, 1)
    return ri


def relation_search(
    trial: Trial, s: Sequence[int], variation_tolerant: bool = False
) -> List[Optional[int]]:
    """Chain-oriented relation indices, one per chain link (pos -> pos+1)."""
    n = len(s)
    chain = list(np.argsort(s))
    out: List[Optional[int]] = []
    for pos in range(n):
        a, b = chain[pos], chain[(pos + 1) % n]
        agg, vic = min(a, b), max(a, b)
        ri = relation_search_pair(trial, agg, vic, n, variation_tolerant)
        if ri is not PHI and agg != a:   # measured against chain direction
            ri = -ri
        out.append(ri)
    return out


# ------------------------------------------------ single-step matching (SSM)
def single_step_matching(
    trial: Trial, s: Sequence[int], ri: List[Optional[int]]
) -> List[Optional[Tuple[float, int]]]:
    """Returns per-physical-ring (delta, line) lock target or None.

    Builds sub-chains between RI=phi cuts; head takes its first entry, tail
    its last, intermediates follow the LAT diagonal (paper Fig. 13).
    """
    n = len(s)
    chain = list(np.argsort(s))
    tables = [search_table(trial, i) for i in range(n)]
    cuts = [pos for pos in range(n) if ri[pos] is PHI]
    assign_pos: List[Optional[int]] = [None] * n  # entry index per chain pos

    if not cuts:
        # Single cyclic LAT, diagonal from chain position 0 (Fig. 13(a)).
        segments = [list(range(n))]
        real_cut = [False]
    else:
        segments, real_cut = [], []
        for ci, cpos in enumerate(cuts):
            start = (cpos + 1) % n
            end = cuts[(ci + 1) % len(cuts)]
            seg = []
            p = start
            while True:
                seg.append(p)
                if p == end:
                    break
                p = (p + 1) % n
            segments.append(seg)
            real_cut.append(True)

    # LAT rows are modular: a line reappears N rows apart via the adjacent
    # FSR, so diagonals advance mod N (smallest in-table representative =
    # bluest alias).
    for seg, has_tail in zip(segments, real_cut):
        acc = 0
        diag = {}
        for u, pos in enumerate(seg):
            if u == 0:
                e = 0                      # head -> first entry (if anchored)
            else:
                prev = seg[u - 1]
                acc += ri[prev]            # RI along the chain link prev->pos
                e = u + acc
            diag[pos] = e
        if not has_tail:
            # Zero-phi single cycle (Fig. 13(a)): no anchor; scan cyclic
            # offsets and take the first whose diagonal fits every table.
            for rho0 in range(n):
                cand = {pos: (e + rho0) % n for pos, e in diag.items()}
                if all(cand[pos] < len(tables[chain[pos]]) for pos in seg):
                    diag = cand
                    break
            else:
                diag = {pos: e % n for pos, e in diag.items()}
        else:
            diag = {pos: e % n for pos, e in diag.items()}
        for pos, e in diag.items():
            if has_tail and pos == seg[-1]:
                e = len(tables[chain[pos]]) - 1   # tail -> last entry
            assign_pos[pos] = e

    result: List[Optional[Tuple[float, int]]] = [None] * n
    for pos in range(n):
        ring_i = chain[pos]
        e = assign_pos[pos]
        if e is None or not (0 <= e < len(tables[ring_i])):
            result[ring_i] = None
        else:
            result[ring_i] = tables[ring_i][e]
    return result


# ------------------------------------------------------- sequential tuning
def sequential_tuning(
    trial: Trial, s: Sequence[int]
) -> List[Optional[Tuple[float, int]]]:
    n = len(s)
    chain = list(np.argsort(s))
    locked: List[Optional[Tuple[float, int]]] = [None] * n
    for pos in range(n):
        ring_i = chain[pos]
        taken_upstream = {
            locked[u][1] for u in range(ring_i) if locked[u] is not None
        }
        visible = [k not in taken_upstream for k in range(n)]
        st = search_table(trial, ring_i, visible=visible)
        locked[ring_i] = st[0] if st else None
    return locked


# ----------------------------------------------------------- classification
def classify(
    locks: List[Optional[Tuple[float, int]]], s: Sequence[int], policy: str = "ltc"
) -> str:
    n = len(s)
    if any(l is None for l in locks):
        return "zero_lock"
    lines = [l[1] for l in locks]
    if len(set(lines)) != n:
        return "dup_lock"
    if policy == "ltd":
        ok = all(lines[i] == s[i] for i in range(n))
    elif policy == "ltc":
        shifts = {(lines[i] - s[i]) % n for i in range(n)}
        ok = len(shifts) == 1
    else:
        ok = True
    return "success" if ok else "order_err"
