"""Reachability in the wavelength domain (Eq. 5).

A ring's thermally-tuned resonance sweeps red-ward by delta in [0, TR_i] from
every comb line lambda_ring,i + j*FSR_i.  Laser line k is reachable iff the
red-shift residual  (lambda_laser,k - lambda_ring,i) mod FSR_i  <= TR_i, and
that residual is exactly the minimum tuning distance delta_{i,k}.
"""
from __future__ import annotations

import jax.numpy as jnp

from .sampling import SystemBatch


def tuning_residual(sys: SystemBatch) -> jnp.ndarray:
    """(T, N, N) residual[t, i, k] = min red-shift of ring i to laser k [nm]."""
    d = sys.laser[:, None, :] - sys.ring[:, :, None]          # (T, ring, laser)
    return jnp.mod(d, sys.fsr[:, :, None])


def scaled_residual(sys: SystemBatch) -> jnp.ndarray:
    """Residual divided by the per-ring TR multiplier.

    success at mean tuning range t  <=>  scaled_residual <= t, so per-trial
    minimum tuning ranges are direct max/min-reductions of this tensor.
    """
    return tuning_residual(sys) / sys.tr_unit[:, :, None]


def reach_matrix(sys: SystemBatch, tr_mean: float) -> jnp.ndarray:
    """(T, N, N) bool: ring i can be tuned onto laser k at the given TR mean."""
    return scaled_residual(sys) <= jnp.float32(tr_mean)
