"""Single-Step Matching (paper §V-C, Fig. 12-13).

Builds the Lock Allocation Table implicitly: within a sub-chain (rings
between two RI=phi cuts, in target-ordering chain order), aligning search
tables by relation indices makes entry ``e`` of chain position p sit at LAT
row ``e + off_p`` with off_{p+1} = off_p - RI_p.  The diagonal assignment
"head takes its first entry, every following ring takes the next row" then
reduces to the closed form

    e_p = (p - h) + sum_{q=h..p-1} RI_q        (h = sub-chain head position)

with the paper's overrides: sub-chain heads take their first entry and
sub-chain tails their last (Fig. 13(b)(c)).  With no phi at all the cycle is
cut at the wrap link and the diagonal starts at chain position 0 (Fig. 13(a)).

The phi pattern differs per trial, so segmentation is data-dependent; we
resolve it with a doubled scan over chain positions (2N fixed steps) —
vectorized over trials, no data-dependent shapes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .relation import RI_PHI, ChainSpec
from .search_table import SearchTables


class Assignment(NamedTuple):
    """Per-physical-ring lock outcome of an oblivious arbitration."""

    entry: jax.Array   # (T, N) chosen search-table entry index, -1 if none
    wl: jax.Array      # (T, N) laser line id of the chosen entry, -1 if none
    delta: jax.Array   # (T, N) tuning distance, +inf if none


def single_step_matching(
    tables: SearchTables, ri_chain: jax.Array, spec: ChainSpec
) -> Assignment:
    """ri_chain: (T, N) chain-oriented relation indices (RI_PHI = cut)."""
    T, n = ri_chain.shape
    chain = jnp.asarray(spec.chain)                       # (N,) pos -> ring
    cut = ri_chain == RI_PHI                              # (T, N) link p->p+1 broken
    any_cut = cut.any(axis=1)                             # (T,)
    # Head at position p iff the incoming link (p-1 -> p) is broken; with no
    # phi anywhere, cut the cycle at the wrap link => artificial head at 0.
    prev_cut = jnp.roll(cut, 1, axis=1)
    is_head = jnp.where(any_cut[:, None], prev_cut, jnp.arange(n)[None, :] == 0)

    ri_safe = jnp.where(cut, 0, ri_chain)

    # Doubled scan: positions 0..2N-1; state (u, acc) = (distance from head,
    # accumulated RI since head).  Second lap fixes wrapped sub-chains.
    def body(step, carry):
        u, acc, e = carry
        p = step % n
        head = is_head[:, p]
        pm1 = (p - 1) % n
        u = jnp.where(head, 0, u + 1)
        acc = jnp.where(head, 0, acc + ri_safe[:, pm1])
        e = e.at[:, p].set(u + acc)
        return u, acc, e

    u0 = jnp.zeros((T,), jnp.int32)
    e0 = jnp.zeros((T, n), jnp.int32)
    _, _, e_diag = jax.lax.fori_loop(0, 2 * n, body, (u0, u0, e0))

    # LAT rows are modular: a laser line reappears N rows apart through the
    # adjacent FSR (shared resonance periodicity, §V-B), so "the next row" is
    # taken mod N with the smallest in-table representative (bluest alias,
    # minimal tuning power).
    nv_chain = tables.n_valid[:, chain]                   # (T, N) by position

    # Sub-chains anchored at a real phi cut: head -> first entry (e_diag = 0
    # by construction, the §V-C adjacency argument), diagonal mod N inside.
    e_anchored = e_diag % n

    # No phi anywhere (Fig. 13(a)): the cycle imposes no anchor; the diagonal
    # matching scans cyclic offsets rho0 and takes the first that fits every
    # search table (an offset exists iff the ideal LtC assignment does).
    rho = jnp.arange(n, dtype=jnp.int32)
    e_cand = (e_diag[:, None, :] + rho[None, :, None]) % n   # (T, rho, pos)
    feas = jnp.all(e_cand < nv_chain[:, None, :], axis=-1)   # (T, rho)
    rho0 = jnp.argmax(feas, axis=1)                          # first feasible
    e_free = jnp.take_along_axis(e_cand, rho0[:, None, None], axis=1)[:, 0, :]

    e_pos = jnp.where(any_cut[:, None], e_anchored, e_free)

    # Tail override: ring at position p with a real outgoing cut takes its
    # LAST entry (paper Fig. 13(b)(c)).
    e_pos = jnp.where(cut, nv_chain - 1, e_pos)

    valid = (e_pos >= 0) & (e_pos < nv_chain)
    e_pos = jnp.where(valid, e_pos, -1)

    # Scatter back from chain position to physical ring index.
    entry = jnp.full((T, n), -1, jnp.int32).at[:, chain].set(e_pos)
    rows = jnp.arange(T)[:, None]
    e_safe = jnp.clip(entry, 0, tables.max_entries - 1)
    ring_idx = jnp.arange(n)[None, :]
    wl = jnp.where(entry >= 0, tables.wl[rows, ring_idx, e_safe], -1)
    delta = jnp.where(entry >= 0, tables.delta[rows, ring_idx, e_safe], jnp.inf)
    return Assignment(entry=entry, wl=wl, delta=delta)
