"""Batched sweep engine: whole sigma x TR grids in a single jitted call.

The paper's headline results (Figs. 4-8, 14-16) are shmoo grids: every point
is one ``evaluate_policy`` / ``evaluate_scheme`` / ``policy_min_tr`` call at
a different (sigma_*, TR) combination.  Filling those grids with nested
Python loops costs one host->device dispatch per point and dominates
wall-time long before the arithmetic does.  This module evaluates the entire
grid device-resident:

  * named axes (``tr_mean``, ``sigma_rlv``, ``sigma_go``, ``sigma_llv_frac``,
    ``sigma_fsr_frac``, ``sigma_tr_frac``, ``fsr_mean``) are crossed into a
    flat (P, K) point list on the host;
  * the un-jitted evaluation body is ``vmap``-ped over points within a
    chunk, and ``lax.map`` iterates the chunks — so peak memory is bounded
    by ``chunk_size`` times the per-point T x N x N x J table footprint while
    the whole grid remains ONE jit compilation and ONE dispatch;
  * results come back as grid-shaped arrays (leading dims = axis lengths,
    in the order the ``axes`` mapping lists them).

Usage::

    from repro.core import make_units, sweep_policy, sweep_scheme, sweep_min_tr
    from repro.configs.wdm import WDM8_G200

    cfg = WDM8_G200
    units = make_units(cfg, seed=4, n_laser=100, n_ring=100)

    # Fig. 4: AFP over a sigma_rLV x TR shmoo, one dispatch.
    afp = sweep_policy(cfg, units, "ltc",
                       {"sigma_rlv": rlvs, "tr_mean": trs})   # (len(rlvs), len(trs))

    # Fig. 16: CAFP grid with fixed harsh variations.
    res = sweep_scheme(cfg, units, "vtrs_ssm",
                       {"sigma_rlv": rlvs, "tr_mean": trs},
                       fixed={"sigma_fsr_frac": 0.05, "sigma_tr_frac": 0.20})
    cafp = res.cafp                                           # grid-shaped

    # Fig. 5/7/8: minimum tuning range along any named axis.
    mt = sweep_min_tr(cfg, units, "lta", {"fsr_mean": fsrs})  # (len(fsrs),)

    # Device-parallel grids: shard the chunk axis over a 1-D mesh.  Works
    # with real TPUs and with placeholder CPU devices (dryrun.py's
    # --xla_force_host_platform_device_count); results are bit-identical
    # to the unsharded engine and invariant to the mesh size.
    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh()           # ("sweep",) over all visible devices
    afp = sweep_policy(cfg, units, "ltc",
                       {"sigma_rlv": rlvs, "tr_mean": trs}, mesh=mesh)

``backend`` threads through to the kernel wrappers in ``repro.kernels.ops``
(``"jnp"``, ``"interpret"``, ``"pallas"``); the default ``None`` uses the
pure-jnp core path.  ``sweep_grid_reference`` keeps the pre-engine per-point
loop as the golden oracle — the engine is bit-for-bit equal to it (asserted
in tests/test_sweep.py), and it validates requests identically so it rejects
exactly what the engine rejects.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .api import (
    EvalResult,
    evaluate_policy,
    evaluate_policy_impl,
    evaluate_scheme,
    evaluate_scheme_impl,
    policy_min_tr,
    policy_min_tr_impl,
    policy_trial_min_tr_impl,
)
from .grid import ArbitrationConfig
from .matching import _HALL_MAX_N
from .sampling import UnitSamples
from .search_table import max_entries_for

#: Axis/fixed names accepted by the engine (keyword names of the eval impls;
#: ``tr_mean`` is positional there but a named axis here).
AXIS_NAMES = (
    "tr_mean",
    "sigma_rlv",
    "sigma_go",
    "sigma_llv_frac",
    "sigma_fsr_frac",
    "sigma_tr_frac",
    "fsr_mean",
)

#: Per-chunk device memory budget for auto chunk sizing [bytes].
_CHUNK_BUDGET = 256 * 1024 * 1024


def _shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """shard_map across jax versions (jax.shard_map landed in 0.6)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep
    )


def _check_names(names, *, metric: str) -> None:
    for name in names:
        if name not in AXIS_NAMES:
            raise ValueError(f"unknown sweep axis {name!r}; valid: {AXIS_NAMES}")
    if metric == "min_tr" and "tr_mean" in names:
        raise ValueError("min_tr sweeps solve for TR; 'tr_mean' cannot be an axis")


def _validate_request(names, fixed, *, metric: str, policy, scheme) -> None:
    """Shared request validation: the engine and the reference loop must
    accept/reject identically (the oracle is only an oracle on the domain
    the engine serves)."""
    if (policy is None) == (scheme is None):
        raise ValueError("exactly one of policy/scheme required")
    if metric not in ("eval", "min_tr"):
        raise ValueError(f"unknown metric {metric!r}")
    if metric == "min_tr" and policy is None:
        raise ValueError("min_tr sweeps are policy sweeps")
    _check_names(names, metric=metric)
    _check_names(fixed, metric=metric)
    overlap = set(names) & set(fixed)
    if overlap:
        raise ValueError(f"axes and fixed overlap: {sorted(overlap)}")


def _grid_points(axes: Mapping[str, np.ndarray]):
    """Cross the named axes into a flat (P, K) float32 point array."""
    if not axes:
        raise ValueError("at least one sweep axis required")
    names = tuple(axes)
    values = [np.asarray(v, np.float32).reshape(-1) for v in axes.values()]
    shape = tuple(len(v) for v in values)
    mesh = np.meshgrid(*values, indexing="ij")
    points = np.stack([m.reshape(-1) for m in mesh], axis=-1)  # (P, K)
    return names, points, shape


def _auto_chunk(cfg: ArbitrationConfig, units: UnitSamples, n_points: int,
                scheme: str | None) -> int:
    """Largest chunk whose per-point working set fits the memory budget."""
    n = cfg.grid.n_ch
    trials = units.u_rlv.shape[0] * units.u_go.shape[0]
    if scheme is not None:
        # dominant: the (T, N, N, J) candidate-peak tensor of the table build
        # plus the (T, N, 3N) sorted tables; ~3 live f32 copies through sort.
        j = 2 * cfg.max_fsr_alias + 1
        per_point = trials * n * (n * j + max_entries_for(n)) * 4 * 3
    else:
        # dominant: the (T, 2^N, N) Hall subset table (small N) or the
        # (T, N, N) residual tensor; a few live f32 copies either way.
        width = max(n, (1 << n) if n <= _HALL_MAX_N else 0)
        per_point = trials * n * width * 4 * 3
    return int(np.clip(_CHUNK_BUDGET // max(per_point, 1), 1, n_points))


@partial(
    jax.jit,
    static_argnames=("cfg", "policy", "scheme", "metric", "names",
                     "fixed_names", "chunk", "backend", "mesh"),
)
def _sweep_flat(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    points,            # (P, K) traced
    fixed_values,      # (F,) traced
    *,
    policy: str | None,
    scheme: str | None,
    metric: str,
    names: tuple,
    fixed_names: tuple,
    chunk: int,
    backend: str | None,
    mesh=None,
):
    """Chunked vmap over flat grid points; one compilation for the grid.

    With ``mesh`` (a 1-D ``jax.sharding.Mesh``), the chunk axis is split
    over the mesh devices with ``shard_map`` — each device runs the same
    per-chunk program on its slice of the chunk list, so results are
    bit-identical to the unsharded engine and invariant to the mesh size
    (the chunking contract extended to devices).
    """

    def eval_point(units, fixed_values, vals):
        kw = {fn: fixed_values[i] for i, fn in enumerate(fixed_names)}
        kw.update({name: vals[i] for i, name in enumerate(names)})
        if metric == "min_tr":
            return policy_min_tr_impl(cfg, units, policy, backend=backend, **kw)
        if metric == "trial_min_tr":
            return policy_trial_min_tr_impl(cfg, units, policy, backend=backend, **kw)
        tr_mean = kw.pop("tr_mean", cfg.grid.tr_mean)
        if policy is not None:
            return evaluate_policy_impl(
                cfg, units, policy, tr_mean, backend=backend, **kw
            )
        return evaluate_scheme_impl(
            cfg, units, scheme, tr_mean, backend=backend, **kw
        )

    def run_chunks(units, fixed_values, chunks):  # (C, chunk, K) -> C-leading tree
        return jax.lax.map(
            jax.vmap(partial(eval_point, units, fixed_values)), chunks
        )

    p = points.shape[0]
    n_chunks = -(-p // chunk)
    if mesh is not None:
        n_dev = mesh.devices.size
        n_chunks = -(-n_chunks // n_dev) * n_dev   # whole chunks per device
    pad = n_chunks * chunk - p
    # Padded points repeat the last row: numerically benign, results dropped.
    padded = jnp.concatenate([points, jnp.tile(points[-1:], (pad, 1))]) if pad else points
    chunks = padded.reshape(n_chunks, chunk, -1)
    if mesh is None:
        out = run_chunks(units, fixed_values, chunks)
    else:
        P = jax.sharding.PartitionSpec
        axis = mesh.axis_names[0]
        out = _shard_map(
            run_chunks, mesh=mesh,
            in_specs=(P(), P(), P(axis)), out_specs=P(axis),
            check_rep=False,
        )(units, fixed_values, chunks)
    return jax.tree_util.tree_map(
        lambda a: a.reshape((n_chunks * chunk,) + a.shape[2:])[:p], out
    )


@jax.jit
def _afp_from_trial_min_tr(trial_min_tr, tr_values):
    """(..., T) per-trial min TR x (L,) TR axis -> (..., L) AFP grid.

    Bit-exact vs evaluating each TR point: success bools are identical
    (ideal success at t == trial_min_tr <= t for every policy) and a mean
    of 0/1 float32 values is order-independent (integer sums < 2^24).
    """
    ok = trial_min_tr[..., None, :] <= tr_values[:, None]
    return 1.0 - jnp.mean(ok.astype(jnp.float32), axis=-1)


def sweep_grid(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    axes: Mapping[str, np.ndarray],
    *,
    policy: str | None = None,
    scheme: str | None = None,
    metric: str = "eval",
    fixed: Mapping[str, float] | None = None,
    chunk_size: int | None = None,
    backend: str | None = None,
    tr_fast: bool = True,
    mesh=None,
):
    """Evaluate a full named-axis grid in one jitted call.

    axes:   ordered mapping axis name -> 1-D values; output leading dims
            follow this order.
    metric: "eval" (AFP for a policy / EvalResult for a scheme) or
            "min_tr" (policy only; minimum mean TR for complete success).
    fixed:  scalar overrides applied at every point (traced, so changing
            them does not recompile).
    tr_fast: policy-eval sweeps with a ``tr_mean`` axis collapse that axis
            to a free threshold comparison against one per-trial min-TR
            evaluation per remaining point (bit-exact; see
            ``_afp_from_trial_min_tr``).  Disable to force the direct path.
    mesh:   optional 1-D ``jax.sharding.Mesh`` (e.g. from
            ``repro.launch.mesh.make_sweep_mesh``); the chunk axis is split
            over its devices with ``shard_map``.  A pure performance knob:
            results are bit-identical to the unsharded engine and invariant
            to the mesh size.
    Returns grid-shaped array(s): EvalResult of grids for a scheme,
    a single grid otherwise.
    """
    fixed = dict(fixed or {})
    names, points, shape = _grid_points(axes)
    _validate_request(names, fixed, metric=metric, policy=policy, scheme=scheme)
    if mesh is not None and len(mesh.axis_names) != 1:
        raise ValueError(
            f"sweep meshes are 1-D (the chunk axis); got axes {mesh.axis_names}"
        )

    if policy is not None and metric == "eval" and tr_fast and "tr_mean" in names:
        # TR fast path: one per-trial min-TR evaluation per non-TR point,
        # then the whole TR axis is a broadcast threshold comparison.
        metric = "trial_min_tr"
        tr_idx = names.index("tr_mean")
        tr_values = jnp.asarray(np.asarray(axes["tr_mean"], np.float32).reshape(-1))
        names = tuple(n for n in names if n != "tr_mean")
        shape = shape[:tr_idx] + shape[tr_idx + 1:]
        if names:
            points = _grid_points({n: axes[n] for n in names})[1]
        else:
            points = np.zeros((1, 0), np.float32)  # single all-defaults point
    else:
        tr_idx = None

    chunk = chunk_size or _auto_chunk(cfg, units, points.shape[0], scheme)
    fixed_names = tuple(fixed)
    fixed_values = jnp.asarray([float(fixed[k]) for k in fixed_names], jnp.float32)
    out = _sweep_flat(
        cfg, units, jnp.asarray(points), fixed_values,
        policy=policy, scheme=scheme, metric=metric, names=names,
        fixed_names=fixed_names, chunk=chunk, backend=backend, mesh=mesh,
    )
    if tr_idx is not None:
        afp = _afp_from_trial_min_tr(out.reshape(shape + out.shape[1:]), tr_values)
        return jnp.moveaxis(afp, -1, tr_idx)
    return jax.tree_util.tree_map(
        lambda a: a.reshape(shape + a.shape[1:]), out
    )


def sweep_policy(cfg, units, policy, axes, **kw):
    """Grid of AFP values for an ideal policy.  See ``sweep_grid``."""
    return sweep_grid(cfg, units, axes, policy=policy, **kw)


def sweep_scheme(cfg, units, scheme, axes, **kw) -> EvalResult:
    """EvalResult whose fields are grids, for an oblivious scheme."""
    return sweep_grid(cfg, units, axes, scheme=scheme, **kw)


def sweep_min_tr(cfg, units, policy, axes, **kw):
    """Grid of minimum mean tuning ranges for an ideal policy."""
    return sweep_grid(cfg, units, axes, policy=policy, metric="min_tr", **kw)


def sweep_grid_reference(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    axes: Mapping[str, np.ndarray],
    *,
    policy: str | None = None,
    scheme: str | None = None,
    metric: str = "eval",
    fixed: Mapping[str, float] | None = None,
    backend: str | None = None,
):
    """Pre-engine per-point Python loop: one jitted call per grid point.

    The golden oracle for ``sweep_grid`` (bit-for-bit equal on CPU); also a
    readable spec of what the engine computes.  Validates requests with the
    same ``_validate_request`` as the engine, so it rejects exactly what the
    engine rejects.  Never use on a hot path.
    """
    fixed = dict(fixed or {})
    names, points, shape = _grid_points(axes)
    _validate_request(names, fixed, metric=metric, policy=policy, scheme=scheme)
    outs = []
    for vals in points:
        kw = dict(fixed, backend=backend)
        kw.update({name: float(v) for name, v in zip(names, vals)})
        if metric == "min_tr":
            outs.append(policy_min_tr(cfg, units, policy, **kw))
        else:
            tr_mean = kw.pop("tr_mean", cfg.grid.tr_mean)
            if policy is not None:
                outs.append(evaluate_policy(cfg, units, policy, tr_mean, **kw))
            else:
                outs.append(evaluate_scheme(cfg, units, scheme, tr_mean, **kw))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    return jax.tree_util.tree_map(
        lambda a: a.reshape(shape + a.shape[1:]), stacked
    )
