"""Declarative batched sweep engine: whole variation grids in one jitted call.

The paper's headline results (Figs. 4-8, 14-16) are shmoo grids: every point
is one policy/scheme evaluation at a different combination of variation-axis
values.  The frontend is a single declarative request object::

    from repro.core import SweepRequest, make_units, sweep
    from repro.configs.wdm import WDM8_G200

    cfg = WDM8_G200
    units = make_units(cfg, seed=4, n_laser=100, n_ring=100)

    # Fig. 4: AFP over a sigma_rLV x TR shmoo, one dispatch.
    res = sweep(SweepRequest(cfg=cfg, units=units, policy="ltc",
                             axes={"sigma_rlv": rlvs, "tr_mean": trs}))
    res.data                 # (len(rlvs), len(trs)) AFP grid
    res.axis_names           # ("sigma_rlv", "tr_mean")
    res.axis("tr_mean")      # the coordinate values, carried with the result

    # Fig. 16: CAFP grid with fixed harsh variations (traced: changing them
    # never recompiles).
    res = sweep(SweepRequest(
        cfg=cfg, units=units, scheme="vtrs_ssm",
        axes={"sigma_rlv": rlvs, "tr_mean": trs},
        fixed={"sigma_fsr_frac": 0.05, "sigma_tr_frac": 0.20}))
    res.data.cafp            # EvalResult of grid-shaped fields

    # Fig. 5/7/8: minimum tuning range along any registered axis.
    res = sweep(SweepRequest(cfg=cfg, units=units, policy="lta",
                             metric="min_tr", axes={"fsr_mean": fsrs}))

Valid axis/fixed names are whatever the ``Variations`` axis registry knows
(``repro.core.variations.axis_names()``) — an axis registered with
``register_axis`` is immediately sweepable here, with no engine edits.
``sweep_policy`` / ``sweep_scheme`` / ``sweep_min_tr`` / ``sweep_grid`` are
thin wrappers that build a request and return the bare grid(s).

Engine mechanics (unchanged by the declarative frontend):

  * named axes are crossed into a flat (P, K) point list on the host;
  * the un-jitted evaluation body is ``vmap``-ped over points within a
    chunk, and ``lax.map`` iterates the chunks — so peak memory is bounded
    by ``chunk_size`` times the per-point footprint (for scheme sweeps the
    streaming T x N x E table build; see ``scheme_point_bytes``) while the
    whole grid remains ONE jit compilation and ONE dispatch;
  * results come back as grid-shaped arrays (leading dims = axis lengths,
    in the order the ``axes`` mapping lists them);
  * with ``mesh`` (1-D, e.g. from ``repro.launch.mesh.make_sweep_mesh``)
    the chunk axis is split over devices with ``shard_map`` — bit-identical
    to the unsharded engine and invariant to the mesh size;
  * ``backend`` threads through to the kernel wrappers in
    ``repro.kernels.ops`` (``"jnp"``, ``"interpret"``, ``"pallas"``); the
    default ``None`` uses the pure-jnp core path.

``sweep_reference`` keeps the pre-engine per-point loop as the golden
oracle — the engine is bit-for-bit equal to it (asserted in
tests/test_sweep.py), and both consume the same validated ``SweepRequest``
so they reject exactly the same inputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .api import (
    EvalResult,
    evaluate_policy,
    evaluate_policy_impl,
    evaluate_scheme,
    evaluate_scheme_impl,
    policy_min_tr,
    policy_min_tr_impl,
    policy_trial_min_tr_impl,
)
from .grid import ArbitrationConfig
from .matching import _HALL_MAX_N
from .sampling import UnitSamples
from .search_table import max_entries_for, merge_plan
from .variations import Variations, axis_names, axis_spec, _maybe_validate

#: Per-chunk device memory budget for auto chunk sizing [bytes].
_CHUNK_BUDGET = 256 * 1024 * 1024


def __getattr__(name: str):
    # Back-compat: the pre-registry engine exposed its axis names as a
    # module-level tuple frozen at import time.  Serve it live instead so
    # axes registered later are visible through the old spelling too.
    if name == "AXIS_NAMES":
        return axis_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """shard_map across jax versions (jax.shard_map landed in 0.6)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep
    )


def chunked_map(item_fn, xs, *, chunk, mesh=None, broadcast=(), tag=None):
    """Map ``item_fn`` over ``xs``'s leading axis in vmapped chunks.

    The engine's memory-bounding primitive, extracted so other batch axes
    (the fabric layer's link axis) reuse the exact same machinery:
    ``lax.map`` iterates chunks of size ``chunk`` and ``vmap`` runs the
    items within a chunk, so peak memory is ``chunk`` times the per-item
    footprint while the whole map stays one traced program.  ``broadcast``
    pytrees are passed unchunked as leading arguments:
    ``item_fn(*broadcast, item)``.

    The tail chunk is padded by repeating the last item (numerically
    benign; padded results are dropped).  With ``mesh`` (1-D) the chunk
    axis is split over devices with ``shard_map`` — the chunk count is
    rounded up to a device multiple so every device runs whole chunks,
    which keeps results bit-identical to the unsharded path and invariant
    to the mesh size.  Composable: with ``mesh=None`` this is vmap-safe,
    so an outer ``chunked_map`` (grid points) may contain an inner one
    (links per point).
    """
    tree = jax.tree_util
    p = tree.tree_leaves(xs)[0].shape[0]
    n_chunks = -(-p // chunk)
    if mesh is not None:
        n_dev = mesh.devices.size
        n_chunks = -(-n_chunks // n_dev) * n_dev   # whole chunks per device
    pad = n_chunks * chunk - p
    if tag is not None:
        # Best-effort plan telemetry: this body runs at *trace* time, so
        # the note fires once per compilation, not per executed chunk.
        from repro.obs.phase import note

        note(f"chunked_map.{tag}", items=int(p), chunk=int(chunk),
             n_chunks=int(n_chunks), pad=int(pad))
    if pad:
        xs = tree.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.tile(a[-1:], (pad,) + (1,) * (a.ndim - 1))]
            ),
            xs,
        )
    chunks = tree.tree_map(
        lambda a: a.reshape((n_chunks, chunk) + a.shape[1:]), xs
    )

    def run(*args):
        *br, ch = args
        return jax.lax.map(jax.vmap(partial(item_fn, *br)), ch)

    if mesh is None:
        out = run(*broadcast, chunks)
    else:
        P = jax.sharding.PartitionSpec
        axis = mesh.axis_names[0]
        out = _shard_map(
            run, mesh=mesh,
            in_specs=(P(),) * len(broadcast) + (P(axis),),
            out_specs=P(axis), check_rep=False,
        )(*broadcast, chunks)
    return tree.tree_map(
        lambda a: a.reshape((n_chunks * chunk,) + a.shape[2:])[:p], out
    )


def _check_names(names, *, metric: str) -> None:
    valid = axis_names()
    for name in names:
        if name not in valid:
            raise ValueError(f"unknown sweep axis {name!r}; valid: {valid}")
    if metric == "min_tr" and "tr_mean" in names:
        raise ValueError("min_tr sweeps solve for TR; 'tr_mean' cannot be an axis")


def _validate_request(names, fixed, *, metric: str, policy, scheme) -> None:
    """Shared request validation: the engine and the reference loop consume
    the same validated ``SweepRequest``, so they accept/reject identically
    (the oracle is only an oracle on the domain the engine serves)."""
    if (policy is None) == (scheme is None):
        raise ValueError("exactly one of policy/scheme required")
    if metric not in ("eval", "min_tr"):
        raise ValueError(f"unknown metric {metric!r}")
    if metric == "min_tr" and policy is None:
        raise ValueError("min_tr sweeps are policy sweeps")
    _check_names(names, metric=metric)
    _check_names(fixed, metric=metric)
    overlap = set(names) & set(fixed)
    if overlap:
        raise ValueError(f"axes and fixed overlap: {sorted(overlap)}")


@dataclasses.dataclass(frozen=True, eq=False)
class SweepRequest:
    """A complete, validated description of one grid evaluation.

    axes:   ordered mapping axis name -> 1-D coordinate values; the result's
            leading dims follow this order.  Names come from the
            ``Variations`` axis registry.
    policy/scheme: exactly one; the evaluation target.
    metric: "eval" (AFP for a policy / EvalResult for a scheme) or
            "min_tr" (policy only; minimum mean TR for complete success).
    fixed:  scalar overrides applied at every point (a mapping or a
            ``Variations``; traced, so changing values never recompiles).
    chunk_size: points per vmap chunk (None = auto from the memory budget;
            since the streaming top-E table build the per-point scheme
            footprint is ~6x smaller, so scheme sweeps auto-size
            correspondingly larger chunks — fewer ``lax.map`` iterations).
    backend: kernel backend threaded to ``repro.kernels.ops`` (None = jnp
            core path).
    tr_fast: policy-eval sweeps with a ``tr_mean`` axis collapse that axis
            to a free threshold comparison against one per-trial min-TR
            evaluation per remaining point (bit-exact; see
            ``_afp_from_trial_min_tr``).  Disable to force the direct path.
    mesh:   optional 1-D ``jax.sharding.Mesh``; the chunk axis is split
            over its devices with ``shard_map``.  A pure performance knob.
    timeline: optional ``repro.core.temporal.Timeline``.  Each grid point
            then runs the full temporal scan (incremental re-arbitration
            with ``run_timeline`` defaults) instead of a one-shot
            evaluation, and the result grids are trial-mean
            ``TemporalStats`` fields with a trailing step axis.  Requires a
            ``protocol_*`` scheme and ``metric="eval"``; warm/hysteresis
            knobs live on ``run_timeline`` itself.
    fabric: optional ``repro.fabric.FabricSpec``.  Each grid point then
            brings up the whole fabric (per-link scheme arbitration + the
            network-level wavelength-assignment constraints) and the result
            grids are ``FabricStats`` fields.  Requires a scheme,
            ``metric="eval"`` and ``units`` from
            ``repro.fabric.make_fabric_units`` matching the spec.  The link
            axis is chunked *inside* each grid point against the same
            memory budget.

    Composition precedence: with BOTH ``fabric`` and ``timeline`` set, the
    fabric wins the dispatch and the timeline must be a fabric-scoped
    ``repro.fabric.FabricTimeline`` matching the spec's link count and the
    config's channel count — each grid point then runs the full chaos scan
    (``run_fabric_timeline`` defaults: warm, transactional) and the result
    grids are link-mean ``FabricChaosStats`` fields with a trailing step
    axis.  A per-transceiver ``Timeline`` has no link addressing, and a
    ``FabricTimeline`` without ``fabric=`` has no topology — both
    combinations are rejected at construction.  Any scheme is accepted
    (bring-up uses the scheme's arbiter; re-lock always runs the protocol
    engine), unlike transceiver timelines which need ``protocol_*``.

    Validation happens at construction, so an invalid request never reaches
    the engine (or the reference loop).
    """

    cfg: ArbitrationConfig
    units: UnitSamples
    axes: Mapping[str, np.ndarray]
    policy: str | None = None
    scheme: str | None = None
    metric: str = "eval"
    fixed: Mapping[str, float] | Variations | None = None
    chunk_size: int | None = None
    backend: str | None = None
    tr_fast: bool = True
    mesh: Any = None
    timeline: Any = None
    fabric: Any = None

    def __post_init__(self):
        axes = {
            str(k): np.asarray(v, np.float32).reshape(-1)
            for k, v in dict(self.axes).items()
        }
        fixed = self.fixed
        if isinstance(fixed, Variations):
            fixed = dict(fixed.items())
        fixed = {str(k): v for k, v in dict(fixed or {}).items()}
        object.__setattr__(self, "axes", axes)
        object.__setattr__(self, "fixed", fixed)
        if self.fabric is not None:
            # Fabric-specific diagnostics win over the generic metric/policy
            # checks: a fabric request that also trips e.g. the min_tr rule
            # should say what is wrong with the *fabric* usage.
            if self.scheme is None:
                raise ValueError(
                    "fabric sweeps arbitrate every link with an oblivious "
                    "scheme; pass scheme=..., not policy=..."
                )
            if self.metric != "eval":
                raise ValueError("fabric sweeps require metric='eval'")
            if self.timeline is not None:
                from repro.fabric.chaos import FabricTimeline

                if not isinstance(self.timeline, FabricTimeline):
                    raise ValueError(
                        "fabric sweeps compose with a fabric-scoped "
                        "FabricTimeline (repro.fabric.make_fabric_timeline); "
                        "a per-transceiver Timeline has no link addressing "
                        f"at fabric scale (got {type(self.timeline).__name__})"
                    )
                if self.timeline.n_links != self.fabric.n_links:
                    raise ValueError(
                        f"timeline spans {self.timeline.n_links} links but "
                        f"the fabric spec describes {self.fabric.n_links}"
                    )
                if self.timeline.n_ch != len(self.cfg.s):
                    raise ValueError(
                        f"timeline has {self.timeline.n_ch} channels but "
                        f"cfg has {len(self.cfg.s)}"
                    )
            from repro.fabric.sampling import FabricUnits

            if not isinstance(self.units, FabricUnits):
                raise ValueError(
                    "fabric sweeps take FabricUnits from "
                    "repro.fabric.make_fabric_units, not UnitSamples"
                )
            if self.units.n_links != self.fabric.n_links:
                raise ValueError(
                    f"units carry {self.units.n_links} links but the spec "
                    f"describes {self.fabric.n_links}"
                )
        _validate_request(
            tuple(axes), tuple(fixed),
            metric=self.metric, policy=self.policy, scheme=self.scheme,
        )
        if not axes:
            raise ValueError("at least one sweep axis required")
        for name, values in axes.items():
            spec = axis_spec(name)
            for v in values:
                _maybe_validate(spec, v)
        for name, v in fixed.items():
            _maybe_validate(axis_spec(name), v)
        if self.mesh is not None and len(self.mesh.axis_names) != 1:
            raise ValueError(
                f"sweep meshes are 1-D (the chunk axis); got axes "
                f"{self.mesh.axis_names}"
            )
        if self.timeline is not None and self.fabric is None:
            from repro.fabric.chaos import FabricTimeline

            if isinstance(self.timeline, FabricTimeline):
                raise ValueError(
                    "a FabricTimeline carries per-link faults but no "
                    "topology; pass the matching fabric=FabricSpec(...) "
                    "alongside it"
                )
            if self.scheme is None or not self.scheme.startswith("protocol_"):
                raise ValueError(
                    "timeline sweeps run incremental re-arbitration and "
                    f"need a protocol_* scheme; got scheme={self.scheme!r}"
                )
            if self.metric != "eval":
                raise ValueError("timeline sweeps require metric='eval'")
            n_ch = int(self.timeline.n_ch)
            if n_ch != len(self.cfg.s):
                raise ValueError(
                    f"timeline has {n_ch} channels but cfg has {len(self.cfg.s)}"
                )

    def replace(self, **kw) -> "SweepRequest":
        return dataclasses.replace(self, **kw)


class SweepResult(NamedTuple):
    """Grid(s) plus the axis metadata they were evaluated over.

    ``data`` is the grid array (policy/min_tr requests) or an ``EvalResult``
    whose fields are grids (scheme requests); leading dims follow
    ``axis_names``, with ``coords[i]`` holding axis i's coordinate values.
    A NamedTuple, hence a pytree: ``jax.block_until_ready`` etc. work.
    """

    data: Any
    axis_names: tuple
    coords: tuple

    def axis(self, name: str) -> np.ndarray:
        """Coordinate values of the named axis."""
        try:
            return self.coords[self.axis_names.index(name)]
        except ValueError:
            raise ValueError(
                f"result has no axis {name!r}; axes: {self.axis_names}"
            ) from None


def _grid_points(axes: Mapping[str, np.ndarray]):
    """Cross the named axes into a flat (P, K) float32 point array."""
    names = tuple(axes)
    values = [np.asarray(v, np.float32).reshape(-1) for v in axes.values()]
    shape = tuple(len(v) for v in values)
    mesh = np.meshgrid(*values, indexing="ij")
    points = np.stack([m.reshape(-1) for m in mesh], axis=-1)  # (P, K)
    return names, points, shape


def scheme_point_bytes(cfg: ArbitrationConfig, n_trials: int) -> int:
    """Per-grid-point working-set estimate [bytes] for a *scheme* sweep —
    the quantity ``_auto_chunk`` budgets against.  Exposed for capacity
    audits (e.g. the WDM32 table-footprint test).

    Dominant: the persistent (T, N, E) search tables (f32 delta + i32 wl)
    plus the bounded transient of the streaming top-E merge — the tiling
    and its scratch come from the same ``merge_plan`` the builder uses, so
    the accounting cannot drift from the implementation.  The dense
    (T, N, N*J) candidate tensor of the retired full-sort build no longer
    exists: at N=32, J=17 this is ~6x smaller, which is what lets
    ``chunk_size=None`` auto-size scheme chunks ~6x larger (fewer
    ``lax.map`` iterations per grid) and a paper-scale (100x100-trial)
    WDM32 scheme point fit the 256 MB chunk budget.
    """
    return merge_plan(
        n_trials, cfg.grid.n_ch, max_alias=cfg.max_fsr_alias
    ).total_bytes


def policy_point_bytes(cfg: ArbitrationConfig, n_trials: int) -> int:
    """Per-grid-point working-set estimate [bytes] for a *policy* sweep.

    Policy sweeps never build search tables (the streaming-merge budget is
    scheme-path only); the dominant term is the (T, 2^N, N) Hall subset
    table (small N) or the (T, N, N) residual tensor of the bottleneck
    sweep — a few live f32 copies either way.
    """
    n = cfg.grid.n_ch
    width = max(n, (1 << n) if n <= _HALL_MAX_N else 0)
    return n_trials * n * width * 4 * 3


def _auto_chunk(cfg: ArbitrationConfig, units: UnitSamples, n_points: int,
                scheme: str | None) -> int:
    """Largest chunk whose per-point working set fits the memory budget."""
    trials = units.u_rlv.shape[0] * units.u_go.shape[0]
    per_point = (scheme_point_bytes(cfg, trials) if scheme is not None
                 else policy_point_bytes(cfg, trials))
    return int(np.clip(_CHUNK_BUDGET // max(per_point, 1), 1, n_points))


@partial(
    jax.jit,
    static_argnames=("cfg", "policy", "scheme", "metric", "names",
                     "fixed_names", "chunk", "backend", "mesh", "fabric",
                     "link_chunk"),
)
def _sweep_flat(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    points,            # (P, K) traced
    fixed_values,      # (F,) traced
    *,
    policy: str | None,
    scheme: str | None,
    metric: str,
    names: tuple,
    fixed_names: tuple,
    chunk: int,
    backend: str | None,
    mesh=None,
    timeline=None,     # Timeline pytree (traced) for temporal sweeps
    fabric=None,       # FabricSpec (static) for fabric sweeps
    link_chunk: int = 0,
):
    """Chunked vmap over flat grid points; one compilation for the grid.

    All chunking/sharding mechanics live in ``chunked_map``: the grid
    points are its mapped axis, and ``units``/``fixed_values``/``timeline``
    broadcast to every point.  With ``mesh`` the chunk axis is split over
    devices — bit-identical to the unsharded engine and invariant to the
    mesh size (the chunking contract extended to devices).
    """

    def eval_point(units, fixed_values, tl, vals):
        over = {fn: fixed_values[i] for i, fn in enumerate(fixed_names)}
        over.update({name: vals[i] for i, name in enumerate(names)})
        var = Variations(**over)
        if fabric is not None:
            if tl is not None:
                from repro.fabric.chaos import (
                    run_fabric_timeline_impl,
                    summarize_chaos,
                )

                _, cs = run_fabric_timeline_impl(
                    cfg, units, fabric, tl, var,
                    scheme=scheme, backend=backend, link_chunk=link_chunk,
                )
                # link-mean per step: grids stay axis-shaped + (S,) trailing
                return summarize_chaos(cs)
            from repro.fabric.bringup import fabric_stats_impl

            return fabric_stats_impl(
                cfg, units, fabric, var,
                scheme=scheme, backend=backend, link_chunk=link_chunk,
            )
        if tl is not None:
            from .temporal import run_timeline_impl

            _, tstats = run_timeline_impl(
                cfg, units, tl, var, scheme=scheme, backend=backend
            )
            # trial-mean per step: grids stay axis-shaped + (S,) trailing
            return jax.tree_util.tree_map(
                lambda a: jnp.mean(a.astype(jnp.float32), axis=-1), tstats
            )
        if metric == "min_tr":
            return policy_min_tr_impl(cfg, units, policy, var, backend=backend)
        if metric == "trial_min_tr":
            return policy_trial_min_tr_impl(cfg, units, policy, var, backend=backend)
        if policy is not None:
            return evaluate_policy_impl(
                cfg, units, policy, variations=var, backend=backend
            )
        return evaluate_scheme_impl(
            cfg, units, scheme, variations=var, backend=backend
        )

    return chunked_map(
        eval_point, points, chunk=chunk, mesh=mesh,
        broadcast=(units, fixed_values, timeline), tag="sweep_points",
    )


@jax.jit
def _afp_from_trial_min_tr(trial_min_tr, tr_values):
    """(..., T) per-trial min TR x (L,) TR axis -> (..., L) AFP grid.

    Bit-exact vs evaluating each TR point: success bools are identical
    (ideal success at t == trial_min_tr <= t for every policy) and a mean
    of 0/1 float32 values is order-independent (integer sums < 2^24).
    """
    ok = trial_min_tr[..., None, :] <= tr_values[:, None]
    return 1.0 - jnp.mean(ok.astype(jnp.float32), axis=-1)


def sweep(request: SweepRequest) -> SweepResult:
    """Evaluate a ``SweepRequest`` in one jitted call.

    The single entry point of the engine; ``sweep_policy`` /
    ``sweep_scheme`` / ``sweep_min_tr`` / ``sweep_grid`` are wrappers over
    it.  Returns a ``SweepResult`` carrying the grid(s) and the axis
    metadata (names + coordinate values).
    """
    cfg, units = request.cfg, request.units
    policy, scheme, metric = request.policy, request.scheme, request.metric
    names, points, shape = _grid_points(request.axes)
    coords = tuple(request.axes[n] for n in names)

    if (policy is not None and metric == "eval" and request.tr_fast
            and "tr_mean" in names):
        # TR fast path: one per-trial min-TR evaluation per non-TR point,
        # then the whole TR axis is a broadcast threshold comparison.
        metric = "trial_min_tr"
        tr_idx = names.index("tr_mean")
        tr_values = jnp.asarray(request.axes["tr_mean"])
        sub_names = tuple(n for n in names if n != "tr_mean")
        shape = shape[:tr_idx] + shape[tr_idx + 1:]
        if sub_names:
            points = _grid_points({n: request.axes[n] for n in sub_names})[1]
        else:
            points = np.zeros((1, 0), np.float32)  # single all-defaults point
        run_names = sub_names
    else:
        tr_idx = None
        run_names = names

    if request.fabric is not None:
        # Budget the *link* axis first (one fabric point is a 2*link_chunk-
        # trial scheme evaluation), then fit grid points over it.
        from repro.fabric.bringup import auto_link_chunk

        link_chunk = auto_link_chunk(cfg, request.fabric.n_links)
        per_point = scheme_point_bytes(cfg, 2 * link_chunk)
        chunk = request.chunk_size or int(
            np.clip(_CHUNK_BUDGET // max(per_point, 1), 1, points.shape[0])
        )
    else:
        link_chunk = 0
        chunk = request.chunk_size or _auto_chunk(
            cfg, units, points.shape[0], scheme
        )
    fixed_names = tuple(request.fixed)
    fixed_values = jnp.asarray(
        [float(request.fixed[k]) for k in fixed_names], jnp.float32
    )
    points_arr = jnp.asarray(points)
    statics = dict(
        policy=policy, scheme=scheme, metric=metric, names=run_names,
        fixed_names=fixed_names, chunk=chunk, backend=request.backend,
        mesh=request.mesh, fabric=request.fabric, link_chunk=link_chunk,
    )
    from repro.obs.phase import current_recorder, measured_call

    rec = current_recorder()
    if rec is None:
        out = _sweep_flat(
            cfg, units, points_arr, fixed_values,
            timeline=request.timeline, **statics,
        )
    else:
        # Telemetry path: record the chunk plan, then dispatch through
        # ``measured_call`` — a plain call unless the recorder opted into
        # the AOT compile/execute split (memory watermarks vs the budget).
        trials = units.u_rlv.shape[0] * units.u_go.shape[0]
        per_point = (scheme_point_bytes(cfg, 2 * link_chunk)
                     if request.fabric is not None
                     else scheme_point_bytes(cfg, trials) if scheme is not None
                     else policy_point_bytes(cfg, trials))
        rec.note(
            "sweep.plan", points=int(points_arr.shape[0]), chunk=int(chunk),
            n_chunks=-(-int(points_arr.shape[0]) // int(chunk)),
            link_chunk=int(link_chunk), per_point_bytes=int(per_point),
            budget=_CHUNK_BUDGET, metric=metric,
            target=scheme if scheme is not None else policy,
        )
        if request.timeline is not None:
            kw = {**statics, "timeline": request.timeline}
            dyn_kw = {"timeline": request.timeline}
        else:  # leave the default: None confuses the AOT pytree signature
            kw, dyn_kw = statics, {}
        out = measured_call(
            "sweep", _sweep_flat,
            (cfg, units, points_arr, fixed_values), kw,
            dynamic_args=(units, points_arr, fixed_values),
            dynamic_kwargs=dyn_kw,
            budget=_CHUNK_BUDGET,
        )
    if tr_idx is not None:
        afp = _afp_from_trial_min_tr(out.reshape(shape + out.shape[1:]), tr_values)
        data = jnp.moveaxis(afp, -1, tr_idx)
    else:
        data = jax.tree_util.tree_map(
            lambda a: a.reshape(shape + a.shape[1:]), out
        )
    return SweepResult(data=data, axis_names=names, coords=coords)


def sweep_grid(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    axes: Mapping[str, np.ndarray],
    *,
    policy: str | None = None,
    scheme: str | None = None,
    metric: str = "eval",
    fixed: Mapping[str, float] | None = None,
    chunk_size: int | None = None,
    backend: str | None = None,
    tr_fast: bool = True,
    mesh=None,
):
    """Bare-grid wrapper over ``sweep``: builds the ``SweepRequest`` and
    returns ``SweepResult.data`` only (EvalResult of grids for a scheme, a
    single grid otherwise)."""
    return sweep(SweepRequest(
        cfg=cfg, units=units, axes=axes, policy=policy, scheme=scheme,
        metric=metric, fixed=fixed, chunk_size=chunk_size, backend=backend,
        tr_fast=tr_fast, mesh=mesh,
    )).data


def sweep_policy(cfg, units, policy, axes, **kw):
    """Grid of AFP values for an ideal policy.  See ``SweepRequest``."""
    return sweep_grid(cfg, units, axes, policy=policy, **kw)


def sweep_scheme(cfg, units, scheme, axes, **kw) -> EvalResult:
    """EvalResult whose fields are grids, for an oblivious scheme."""
    return sweep_grid(cfg, units, axes, scheme=scheme, **kw)


def sweep_min_tr(cfg, units, policy, axes, **kw):
    """Grid of minimum mean tuning ranges for an ideal policy."""
    return sweep_grid(cfg, units, axes, policy=policy, metric="min_tr", **kw)


def sweep_reference(request: SweepRequest) -> SweepResult:
    """Pre-engine per-point Python loop: one jitted call per grid point.

    The golden oracle for ``sweep`` (bit-for-bit equal on CPU); also a
    readable spec of what the engine computes.  Consumes the same validated
    ``SweepRequest`` as the engine, so it rejects exactly what the engine
    rejects.  Never use on a hot path.
    """
    cfg, units = request.cfg, request.units
    policy, scheme = request.policy, request.scheme
    if request.timeline is not None:
        raise NotImplementedError(
            "sweep_reference has no temporal path; run_timeline is itself "
            "the per-point primitive a timeline sweep maps — compare "
            "against direct run_timeline calls instead"
        )
    if request.fabric is not None:
        raise NotImplementedError(
            "sweep_reference has no fabric path; the per-link oracle is a "
            "vmapped core instantiate + one flat oblivious_arbitrate "
            "(asserted bit-identical in tests/test_fabric.py)"
        )
    names, points, shape = _grid_points(request.axes)
    outs = []
    for vals in points:
        over = dict(request.fixed)
        over.update({name: float(v) for name, v in zip(names, vals)})
        var = Variations(**over)
        if request.metric == "min_tr":
            outs.append(policy_min_tr(cfg, units, policy, var,
                                      backend=request.backend))
        elif policy is not None:
            outs.append(evaluate_policy(cfg, units, policy, variations=var,
                                        backend=request.backend))
        else:
            outs.append(evaluate_scheme(cfg, units, scheme, variations=var,
                                        backend=request.backend))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    data = jax.tree_util.tree_map(
        lambda a: a.reshape(shape + a.shape[1:]), stacked
    )
    return SweepResult(
        data=data, axis_names=names,
        coords=tuple(request.axes[n] for n in names),
    )


def sweep_grid_reference(
    cfg: ArbitrationConfig,
    units: UnitSamples,
    axes: Mapping[str, np.ndarray],
    *,
    policy: str | None = None,
    scheme: str | None = None,
    metric: str = "eval",
    fixed: Mapping[str, float] | None = None,
    backend: str | None = None,
):
    """Bare-grid wrapper over ``sweep_reference`` (see there)."""
    return sweep_reference(SweepRequest(
        cfg=cfg, units=units, axes=axes, policy=policy, scheme=scheme,
        metric=metric, fixed=fixed, backend=backend,
    )).data
