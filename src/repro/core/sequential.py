"""Sequential Lock-to-Nearest tuning — the paper's baseline (§V-D).

Rings tune one at a time in target-ordering chain order; each locks onto the
first (nearest, smallest red-shift) peak visible in its wavelength search.
Visibility honors light precedence: a locked ring captures its line only for
rings physically *downstream* of it.  Under permuted orderings a ring that
tunes later but sits upstream can therefore steal a line already held
downstream — the dup-lock failure mode of Fig. 15; under natural ordering the
characteristic failure is tone skipping (zero-lock).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .relation import ChainSpec
from .search_table import SearchTables
from .ssm import Assignment


def sequential_tuning(tables: SearchTables, spec: ChainSpec) -> Assignment:
    T, n, E = tables.wl.shape
    rows = jnp.arange(T)
    entry = jnp.full((T, n), -1, jnp.int32)
    cap_wl = jnp.full((T, n), -1, jnp.int32)   # per-physical-ring captured line

    for pos in range(n):                        # static chain order
        ring = int(spec.chain[pos])
        # Lines captured by locked rings physically upstream of `ring`.
        up = cap_wl[:, :ring]                                   # (T, ring)
        taken = jnp.zeros((T, n), bool)
        if ring > 0:
            onehot = jax.nn.one_hot(jnp.clip(up, 0, n - 1), n, dtype=bool)
            taken = jnp.any(onehot & (up >= 0)[..., None], axis=1)
        wl_row = tables.wl[:, ring, :]                          # (T, E)
        vis = (wl_row >= 0) & ~jnp.take_along_axis(
            jnp.pad(taken, ((0, 0), (0, 1))), jnp.clip(wl_row, 0, n), axis=1
        )
        # Tables are delta-ascending: first visible entry = nearest peak.
        first = jnp.argmax(vis, axis=1).astype(jnp.int32)
        found = vis.any(axis=1)
        e = jnp.where(found, first, -1)
        k = jnp.where(found, wl_row[rows, jnp.clip(first, 0, E - 1)], -1)
        entry = entry.at[:, ring].set(e)
        cap_wl = cap_wl.at[:, ring].set(k)

    e_safe = jnp.clip(entry, 0, E - 1)
    delta = jnp.where(
        entry >= 0,
        tables.delta[rows[:, None], jnp.arange(n)[None, :], e_safe],
        jnp.inf,
    )
    return Assignment(entry=entry, wl=cap_wl, delta=delta)
