"""DWDM grid, device-variation model and arbitration configuration.

Implements the wavelength-domain model of Choi & Stojanović, §II-C (Fig. 2,
Table I).  All wavelengths are *relative* to the grid center ``lambda_center``
(the paper notes only relative distances matter); this keeps fp32 exact enough
for TPU execution (values span ±~60 nm, spacing resolution ~1e-3 nm).

Units: nm everywhere.  ``sigma_*`` are half-ranges of uniform distributions
(paper footnote 4: linear, not RSS, sums).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

Policy = str  # "ltd" | "ltc" | "lta"
POLICIES: Tuple[Policy, ...] = ("ltd", "ltc", "lta")


def natural_order(n_ch: int) -> np.ndarray:
    """Natural spectral ordering (0, 1, 2, ..., N-1)."""
    return np.arange(n_ch, dtype=np.int32)


def permuted_order(n_ch: int) -> np.ndarray:
    """Paper's 'Permuted' ordering (0, N/2, 1, N/2+1, ...) — Table II."""
    half = n_ch // 2
    out = np.empty(n_ch, dtype=np.int32)
    out[0::2] = np.arange(half, dtype=np.int32)
    out[1::2] = np.arange(half, dtype=np.int32) + half
    return out


@dataclasses.dataclass(frozen=True)
class DWDMGrid:
    """Pre-fabrication design intent (Eq. 1-2 of the paper)."""

    n_ch: int = 8                 # number of DWDM channels
    grid_spacing: float = 1.12    # lambda_gS [nm]  (200 GHz in O-band)
    ring_bias: float = 4.48       # lambda_rB [nm]  blue-side fabrication bias
    fsr_mean: float | None = None  # lambda_FSR mean; default N_ch * grid_spacing
    tr_mean: float = 8.96         # lambda_TR mean [nm] (swept in experiments)

    @property
    def fsr(self) -> float:
        return self.n_ch * self.grid_spacing if self.fsr_mean is None else self.fsr_mean

    def laser_grid(self) -> np.ndarray:
        """Pre-fab laser wavelengths, relative to lambda_center (Eq. 1)."""
        i = np.arange(self.n_ch, dtype=np.float32)
        return (i - (self.n_ch - 1) / 2.0) * np.float32(self.grid_spacing)

    def ring_grid(self, r: np.ndarray) -> np.ndarray:
        """Pre-fab ring resonances, relative to lambda_center (Eq. 2)."""
        r = np.asarray(r, dtype=np.float32)
        return -np.float32(self.ring_bias) + (r - (self.n_ch - 1) / 2.0) * np.float32(
            self.grid_spacing
        )


@dataclasses.dataclass(frozen=True)
class VariationModel:
    """Half-ranges of uniform device variations (Table I)."""

    sigma_go: float = 15.0        # grid offset  = sigma_lGV + sigma_rGV [nm]
    sigma_llv_frac: float = 0.25  # laser local variation, fraction of grid_spacing
    sigma_rlv: float = 2.24       # ring local resonance variation [nm]
    sigma_fsr_frac: float = 0.01  # FSR variation, fraction of FSR mean
    sigma_tr_frac: float = 0.10   # tuning-range variation, fraction of TR mean

    def replace(self, **kw) -> "VariationModel":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ArbitrationConfig:
    """A complete system-under-test specification.

    ``r`` — pre-fabrication spectral ordering (r_i), per physical ring i.
    ``s`` — post-arbitration target spectral ordering (s_i).  The paper's
    experiments assume s == r (Table II); we keep them separate for
    generality ("channel reconfiguration" is out of scope, as in the paper).
    """

    grid: DWDMGrid = dataclasses.field(default_factory=DWDMGrid)
    var: VariationModel = dataclasses.field(default_factory=VariationModel)
    r_order: Tuple[int, ...] = None  # type: ignore[assignment]
    s_order: Tuple[int, ...] = None  # type: ignore[assignment]
    max_fsr_alias: int = 8        # |j| bound when enumerating FSR-periodic resonances

    def __post_init__(self):
        n = self.grid.n_ch
        if self.r_order is None:
            object.__setattr__(self, "r_order", tuple(natural_order(n).tolist()))
        if self.s_order is None:
            object.__setattr__(self, "s_order", tuple(self.r_order))
        assert sorted(self.r_order) == list(range(n)), "r must be a permutation"
        assert sorted(self.s_order) == list(range(n)), "s must be a permutation"
        # Laser lines must stay monotone in index for order semantics (paper
        # sweeps sigma_lLV to 45% < 50% of spacing, preserving monotonicity).
        assert self.var.sigma_llv_frac < 0.5, "laser local variation must keep grid monotone"

    @property
    def r(self) -> np.ndarray:
        return np.asarray(self.r_order, dtype=np.int32)

    @property
    def s(self) -> np.ndarray:
        return np.asarray(self.s_order, dtype=np.int32)

    @property
    def chain(self) -> np.ndarray:
        """Tuning/relation chain pi: pi[t] = physical ring with target order t."""
        return np.argsort(self.s).astype(np.int32)

    def with_orders(self, kind: str) -> "ArbitrationConfig":
        """kind in {'natural', 'permuted'} applied to both r and s (N/N, P/P)."""
        order = {"natural": natural_order, "permuted": permuted_order}[kind](self.grid.n_ch)
        t = tuple(order.tolist())
        return dataclasses.replace(self, r_order=t, s_order=t)


# Named DWDM configurations used across the paper (Fig. 5): wdm8/16 x g200/400.
def wdm_config(n_ch: int = 8, ghz: int = 200, **kw) -> ArbitrationConfig:
    spacing = 1.12 * (ghz / 200.0)  # 200 GHz = 1.12 nm in O-band (paper §II-C)
    grid = DWDMGrid(n_ch=n_ch, grid_spacing=spacing, ring_bias=4.0 * spacing)
    return ArbitrationConfig(grid=grid, **kw)
