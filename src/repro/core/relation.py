"""Wavelength-oblivious Relation Search (paper §V-B, Fig. 10-11).

The record phase runs N relation searches on consecutive pairs of the target
spectral ordering s.  For the pair at chain position t:

    a_t = pi[t], b_t = pi[(t+1) % N]        (pi = argsort(s))

the physically-upstream ring min(a, b) is the *aggressor* (light precedence,
§V-B) and the other the *victim*.  A unit search locks the aggressor onto one
entry ``e`` of its table, capturing that laser line for every ring downstream;
the victim re-runs its wavelength search and observes the first masked entry
``m`` of its own table.  The unit relation index is RI = m - e.

RS combines Lock-to-Last and Lock-to-First unit searches (footnote 8):
  * both valid and congruent mod N  -> valid RI
  * exactly one valid              -> that RI
  * otherwise                       -> RI = phi  (encoded as RI_PHI)

VT-RS retries with Lock-to-Second when RS yields phi (Fig. 11(c)(d)).

Everything is vectorized over trials; the pair list and roles are static
(derived from s), matching hardware where the sequence is compiled into the
arbiter FSM.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .search_table import SearchTables

RI_PHI = np.int32(-(10**6))  # sentinel: relation not found


class ChainSpec(NamedTuple):
    """Static per-pair metadata derived from the target ordering s."""

    aggressor: np.ndarray  # (N,) physical ring index of pair aggressor
    victim: np.ndarray     # (N,) physical ring index of pair victim
    forward: np.ndarray    # (N,) bool: aggressor is the chain-earlier element
    chain: np.ndarray      # (N,) pi[t] = ring at chain position t


def chain_spec(s: np.ndarray) -> ChainSpec:
    s = np.asarray(s)
    n = s.shape[0]
    pi = np.argsort(s).astype(np.int32)
    first = pi                                  # chain position t
    second = pi[(np.arange(n) + 1) % n]         # chain position t+1
    aggressor = np.minimum(first, second)
    victim = np.maximum(first, second)
    forward = aggressor == first                # RI measured along the chain?
    return ChainSpec(aggressor=aggressor, victim=victim, forward=forward, chain=pi)


def _unit_relation_search(
    tables: SearchTables, agg, vic, entry: jax.Array
) -> jax.Array:
    """Aggressor injections for one or many pairs at once.

    agg, vic: scalar ring indices (one pair) or (P,) static index arrays;
    entry: matching (T,) or (T, P) aggressor entry index (or -1).
    Returns RI = masked_victim_index - entry, or RI_PHI, same shape as entry.
    """
    agg = np.asarray(agg)
    pair_axis = agg.ndim == 1
    rows = jnp.arange(tables.delta.shape[0])
    rows = rows[:, None] if pair_axis else rows
    e_ok = (entry >= 0) & (entry < tables.n_valid[:, agg])
    e_safe = jnp.clip(entry, 0, tables.max_entries - 1)
    line = tables.wl[rows, agg, e_safe]                   # captured laser line
    vic_wl = tables.wl[:, vic, :]                         # (T[, P], E)
    hit = (vic_wl == line[..., None]) & (vic_wl >= 0)
    masked = jnp.where(hit.any(axis=-1), jnp.argmax(hit, axis=-1), -1)
    ri = masked.astype(jnp.int32) - entry.astype(jnp.int32)
    return jnp.where(e_ok & (masked >= 0), ri, RI_PHI)


def _combine(ri_a: jax.Array, ri_b: jax.Array, n_ch: int) -> jax.Array:
    """Footnote-8 combination of two unit searches."""
    a_ok, b_ok = ri_a != RI_PHI, ri_b != RI_PHI
    congruent = (ri_a - ri_b) % n_ch == 0
    both = a_ok & b_ok
    out = jnp.where(both & congruent, ri_a, RI_PHI)
    out = jnp.where(a_ok & ~b_ok, ri_a, out)
    out = jnp.where(b_ok & ~a_ok, ri_b, out)
    return out


def relation_search(
    tables: SearchTables, spec: ChainSpec, *, variation_tolerant: bool = False
) -> jax.Array:
    """Full record phase.  Returns (T, N) chain-oriented relation indices.

    Output ri[t, pos]: ST(pi[pos])[e] and ST(pi[pos+1])[e + ri] refer to the
    same laser line; RI_PHI where no relation was found.

    All N pair searches run at once over a pair axis (the pair list and roles
    are static, so the gathers compile to fixed-index slices): one trace of
    ``_unit_relation_search`` instead of N, which keeps jaxpr size O(1) in N
    and lets the whole record phase sit under an outer ``vmap`` (the sweep
    engine maps it over sigma/TR grid points).
    """
    n = spec.chain.shape[0]
    T = tables.delta.shape[0]
    agg, vic = spec.aggressor, spec.victim               # (N,) static
    nv = tables.n_valid[:, agg]                          # (T, N) per-pair
    last = nv - 1
    first = jnp.zeros((T, n), jnp.int32)
    ri = _combine(
        _unit_relation_search(tables, agg, vic, last),
        _unit_relation_search(tables, agg, vic, first),
        n,
    )
    if variation_tolerant:
        second = jnp.minimum(jnp.ones((T, n), jnp.int32), last)
        ri_vt = _unit_relation_search(tables, agg, vic, second)
        ri = jnp.where(ri == RI_PHI, ri_vt, ri)
    # Orient along the chain: RI was measured aggressor->victim.
    forward = jnp.asarray(spec.forward)[None, :]
    return jnp.where(forward | (ri == RI_PHI), ri, -ri)  # (T, N)


def relation_search_loop(
    tables: SearchTables, spec: ChainSpec, *, variation_tolerant: bool = False
) -> jax.Array:
    """Reference per-position loop (the pre-vectorization implementation).

    Kept as the golden oracle for ``relation_search``: one unit search per
    chain position, traced N times.  Semantically identical; only used by
    tests and never on the hot path.
    """
    n = spec.chain.shape[0]
    T = tables.delta.shape[0]
    out = []
    for pos in range(n):
        agg, vic = int(spec.aggressor[pos]), int(spec.victim[pos])
        nv = tables.n_valid[:, agg]
        last = nv - 1
        first = jnp.zeros((T,), jnp.int32)
        ri = _combine(
            _unit_relation_search(tables, agg, vic, last),
            _unit_relation_search(tables, agg, vic, first),
            n,
        )
        if variation_tolerant:
            second = jnp.minimum(jnp.ones((T,), jnp.int32), last)
            ri_vt = _unit_relation_search(tables, agg, vic, second)
            ri = jnp.where(ri == RI_PHI, ri_vt, ri)
        ri_chain = ri if spec.forward[pos] else jnp.where(ri == RI_PHI, RI_PHI, -ri)
        out.append(ri_chain)
    return jnp.stack(out, axis=1)  # (T, N)
