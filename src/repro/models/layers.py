"""Model layers: norms, rotary embedding, chunked (flash-style) attention,
dense/MoE FFNs, and the Mamba-2 SSD mixer.  Pure functional JAX; parameters
are plain dict pytrees.  Compute in bf16, reductions/softmax in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.ctx import constrain, current_axes

from .config import ModelConfig

COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# ----------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


# ----------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., L, H, hd); positions: (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., L, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------- chunked attention
def _largest_divisor(n: int, at_most: int) -> int:
    for c in range(at_most, 0, -1):
        if n % c == 0:
            return c
    return n


def flash_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                    q_offset=0, causal_skip: bool = False):
    if causal and causal_skip and q.shape[1] == k.shape[1] and q_offset == 0:
        return flash_attention_causal_pairs(
            q, k, v, chunk=min(q_chunk, kv_chunk)
        )
    return _flash_attention_dense(
        q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
        q_offset=q_offset,
    )


def _flash_attention_dense(q, k, v, *, causal: bool, q_chunk: int,
                           kv_chunk: int, q_offset=0):
    """Memory-bounded attention: lax.scan over KV chunks with online softmax,
    outer scan over Q chunks.  Never materializes (Lq, Lkv) scores beyond a
    (q_chunk, kv_chunk) tile — the pure-XLA analogue of FlashAttention,
    shaped for TPU (tile dims are multiples of 128).

    q: (B, Lq, H, hd); k/v: (B, Lkv, KVH, hd).  GQA via head grouping.
    q_offset: absolute position of q[0] (for causal masking in prefill with
    cache or chunked decode).  Returns (B, Lq, H, hd).
    """
    B, Lq, H, hd = q.shape
    _, Lkv, KVH, _ = k.shape
    group = H // KVH
    scale = hd ** -0.5

    q_chunk = _largest_divisor(Lq, min(q_chunk, Lq))
    kv_chunk = _largest_divisor(Lkv, min(kv_chunk, Lkv))
    nq, nkv = Lq // q_chunk, Lkv // kv_chunk

    # (B, nq, qc, KVH, group, hd)
    qr = constrain(
        q.reshape(B, nq, q_chunk, KVH, group, hd),
        ("batch", None, None, None, None, None),
    )
    kr = constrain(
        k.reshape(B, nkv, kv_chunk, KVH, hd), ("batch", None, None, None, None)
    )
    vr = constrain(
        v.reshape(B, nkv, kv_chunk, KVH, hd), ("batch", None, None, None, None)
    )

    def q_step(_, qi):
        qb, qidx = qi                                   # (B, qc, KVH, g, hd)
        q_pos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb, kidx = ki
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb, preferred_element_type=jnp.float32
            ) * scale                                   # (B, KVH, g, qc, kc)
            if causal:
                k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        # constrain the online-softmax carries: pins every tensor in the KV
        # scan (scores included) to batch-sharded layout.
        m0 = constrain(
            jnp.full((B, KVH, group, q_chunk), NEG_INF, jnp.float32),
            ("batch", None, None, None),
        )
        l0 = jnp.zeros_like(m0)
        a0 = constrain(
            jnp.zeros((B, KVH, group, q_chunk, hd), jnp.float32),
            ("batch", None, None, None, None),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             jnp.arange(nkv)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4)       # (B, qc, KVH, g, hd)

    _, outs = jax.lax.scan(
        q_step, None, (qr.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq))
    )
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Lq, H, hd)
    return out.astype(q.dtype)


def flash_attention_causal_pairs(q, k, v, *, chunk: int):
    """Causal flash attention over the static lower-triangle tile list.

    The nested q x kv chunk scan computes every (i, j) tile and masks half
    of them away; here the scan runs over the n(n+1)/2 needed pairs only —
    same online-softmax semantics, half the attention FLOPs and score
    traffic (§Perf).  Requires Lq == Lkv and chunk-aligned lengths.
    """
    B, L, H, hd = q.shape
    KVH = k.shape[2]
    group = H // KVH
    scale = hd ** -0.5
    chunk = _largest_divisor(L, chunk)
    n = L // chunk

    qr = constrain(
        q.reshape(B, n, chunk, KVH, group, hd).transpose(1, 0, 2, 3, 4, 5),
        (None, "batch", None, None, None, None),
    )
    kr = constrain(
        k.reshape(B, n, chunk, KVH, hd).transpose(1, 0, 2, 3, 4),
        (None, "batch", None, None, None),
    )
    vr = constrain(
        v.reshape(B, n, chunk, KVH, hd).transpose(1, 0, 2, 3, 4),
        (None, "batch", None, None, None),
    )

    pairs = [(i, j) for i in range(n) for j in range(i + 1)]
    pi = jnp.array([p[0] for p in pairs], jnp.int32)
    pj = jnp.array([p[1] for p in pairs], jnp.int32)
    pfirst = jnp.array([p[1] == 0 for p in pairs])
    rel = jnp.arange(chunk)

    def step(carry, xs):
        m, l, acc, out = carry
        i, j, first = xs
        qb = jax.lax.dynamic_index_in_dim(qr, i, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kr, j, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vr, j, 0, keepdims=False)
        m = jnp.where(first, NEG_INF, m)
        l = jnp.where(first, 0.0, l)
        acc = jnp.where(first, 0.0, acc)

        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qb, kb, preferred_element_type=jnp.float32
        ) * scale
        mask = (i * chunk + rel)[:, None] >= (j * chunk + rel)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc = acc * corr[..., None] + pv
        # normalize and write; the final (i, i) pair's write wins.
        o = (acc / jnp.maximum(l[..., None], 1e-30)).transpose(0, 3, 1, 2, 4)
        out = jax.lax.dynamic_update_index_in_dim(
            out, o.astype(out.dtype), i, 0
        )
        return (m_new, l, acc, out), None

    m0 = jnp.full((B, KVH, group, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, group, chunk), jnp.float32)
    a0 = jnp.zeros((B, KVH, group, chunk, hd), jnp.float32)
    out0 = constrain(
        jnp.zeros((n, B, chunk, KVH, group, hd), q.dtype),
        (None, "batch", None, None, None, None),
    )
    (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, a0, out0), (pi, pj, pfirst))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, L, H, hd)


def decode_attention(q, k_cache, v_cache, kv_len):
    """Single-token attention against a (possibly longer, padded) cache.

    q: (B, 1, H, hd); caches: (B, Lmax, KVH, hd); kv_len: valid prefix length.
    """
    B, _, H, hd = q.shape
    _, Lmax, KVH, _ = k_cache.shape
    group = H // KVH
    qr = q.reshape(B, KVH, group, hd)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qr, k_cache, preferred_element_type=jnp.float32
    ) * (hd ** -0.5)
    mask = jnp.arange(Lmax)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ----------------------------------------------------------------- FFNs
def dense_ffn(x, p, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.act == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    elif cfg.act == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        raise ValueError(cfg.act)
    return h @ p["w_down"]


class MoEStats(NamedTuple):
    aux_loss: jax.Array
    dropped_frac: jax.Array


def moe_ffn(x, p, cfg: ModelConfig):
    """Top-k token-choice MoE with capacity-bounded sort-free dispatch.

    x: (B, L, d).  Experts live on the `model` mesh axis (leading E dim of
    the expert weights); dispatch/return are scatter/gathers that GSPMD
    partitions (baseline; see EXPERIMENTS §Perf for the shard_map a2a
    variant).  Deterministic shapes: per-expert buffers of capacity C.
    """
    B, L, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * L
    cap = max(8, int(cfg.capacity_factor * T * k / E))
    cap = min(cap, T)
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each routed copy inside its expert buffer
    flat_e = idx.reshape(-1)                             # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    tok = jnp.repeat(jnp.arange(T), k)

    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, cap - 1)].add(
        jnp.where(keep[:, None], xf[tok], 0)
    )
    buf = constrain(buf, ("model", None, None))   # experts live on `model`

    # expert computation (E-sharded einsums)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", buf, p["w_up"]
        )
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"])))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    routed = out_buf[flat_e, jnp.where(keep, pos, cap - 1)]          # (T*k, d)
    routed = constrain(jnp.where(keep[:, None], routed, 0), ("batch", None))
    w = (gate.reshape(-1) * keep).astype(routed.dtype)
    y = jax.ops.segment_sum(routed * w[:, None], tok, num_segments=T)

    if cfg.n_shared_experts:
        sh = jax.nn.silu(xf @ p["shared_gate"]) * (xf @ p["shared_up"])
        y = y + sh @ p["shared_down"]

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)
    ce = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(me * ce)
    dropped = 1.0 - keep.mean()
    return y.reshape(B, L, d), MoEStats(aux, dropped)


def moe_ffn_a2a(x, p, cfg: ModelConfig):
    """Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

    §Perf-optimized path: instead of letting GSPMD all-gather the
    (E, cap, d) expert buffers (the gather baseline's failure mode), tokens
    are exchanged directly between expert shards with two all-to-alls —
    wire bytes ~ capacity_factor * T * k * d per direction, the GShard
    dispatch layout (dst rank, local expert, capacity) so no indices travel.

    Falls back to the gather implementation when no mesh context is active
    or E does not divide the model axis.
    """
    axes = current_axes()
    E, k = cfg.n_experts, cfg.top_k
    if axes is None or axes.get("model") is None or cfg.act != "swiglu":
        return moe_ffn(x, p, cfg)
    mesh, model_ax = axes["mesh"], axes["model"]
    dp = axes["batch"]
    M = mesh.shape[model_ax]
    B, L, d = x.shape
    T = B * L
    n_tok_shards = M
    for a in dp:
        n_tok_shards *= mesh.shape[a]
    if E % M != 0 or T % n_tok_shards != 0:
        return moe_ffn(x, p, cfg)
    E_loc = E // M

    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    T_loc = T // n_tok_shards
    cap = max(4, int(cfg.capacity_factor * T_loc * k / E))

    tok_spec = P((*dp, model_ax))
    ew_spec = P(model_ax, None, None)

    def local_moe(xf_l, idx_l, gate_l, wg, wu, wd):
        t_l = xf_l.shape[0]
        flat_e = idx_l.reshape(-1)                       # (t_l*k,)
        dst = flat_e // E_loc
        e_loc = flat_e % E_loc
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], axis=1
        )[:, 0]
        keep = pos < cap
        pos_c = jnp.where(keep, pos, cap - 1)
        tok = jnp.repeat(jnp.arange(t_l), k)

        send = jnp.zeros((M, E_loc, cap, d), xf_l.dtype)
        send = send.at[dst, e_loc, pos_c].add(
            jnp.where(keep[:, None], xf_l[tok], 0)
        )
        recv = jax.lax.all_to_all(
            send, model_ax, split_axis=0, concat_axis=0, tiled=False
        )                                                # (M_src, E_loc, cap, d)
        xbuf = recv.transpose(1, 0, 2, 3).reshape(E_loc, M * cap, d)
        if cfg.act == "swiglu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xbuf, wg)) * jnp.einsum(
                "ecd,edf->ecf", xbuf, wu
            )
        else:
            h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xbuf, wu)))
        obuf = jnp.einsum("ecf,efd->ecd", h, wd)
        oback = obuf.reshape(E_loc, M, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(
            oback, model_ax, split_axis=0, concat_axis=0, tiled=False
        )                                                # (M_dst, E_loc, cap, d)
        routed = ret[dst, e_loc, pos_c]
        routed = jnp.where(keep[:, None], routed, 0)
        w = gate_l.reshape(-1) * keep.astype(gate_l.dtype)
        return jax.ops.segment_sum(routed * w[:, None], tok, num_segments=t_l)

    try:
        smap = jax.shard_map  # jax >= 0.6
    except AttributeError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as smap
    y = smap(
        local_moe,
        mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, ew_spec, ew_spec, ew_spec),
        out_specs=tok_spec,
        check_vma=False,
    )(xf, idx, gate.astype(x.dtype), p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:
        sh = jax.nn.silu(xf @ p["shared_gate"]) * (xf @ p["shared_up"])
        y = y + sh @ p["shared_down"]

    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, L, d), MoEStats(aux, jnp.zeros(()))


# ------------------------------------------------------------- Mamba-2 SSD
def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk: int, initial_state=None):
    """Mamba-2 state-space-duality scan (arXiv:2405.21060, simplified SSD).

    xh: (B, L, H, P) inputs per head; dt: (B, L, H) positive step sizes;
    A: (H,) negative decay rates;  Bm/Cm: (B, L, G, S) input/output maps
    (G groups broadcast over heads).  Returns (y, final_state) with
    y: (B, L, H, P), state: (B, H, P, S).

    Within a chunk the quadratic (attention-dual) form is used; across
    chunks a linear state is carried — O(L * chunk) memory and the exact
    same semantics as the sequential scan.
    """
    B, L, H, P = xh.shape
    G, S = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0
    nc = L // chunk
    rep = H // G

    xc = xh.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    Bc = jnp.repeat(Bm.reshape(B, nc, chunk, G, S), rep, axis=3)  # (B,nc,c,H,S)
    Cc = jnp.repeat(Cm.reshape(B, nc, chunk, G, S), rep, axis=3)

    dA = dtc * A[None, None, None, :]                   # (B,nc,c,H) negative
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum

    def chunk_step(state, ci):
        xb, dtb, Bb, Cb, dAb, cumb = ci
        # --- intra-chunk (quadratic dual): causal kernel L[s,t]
        seg = cumb[:, :, None, :] - cumb[:, None, :, :]   # (B, s, t, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask BEFORE exp: valid (t<=s) entries are <=0, masked -> -inf -> 0,
        # keeping both the value and its gradient finite.
        kern = jnp.exp(jnp.where(tri[None, :, :, None], seg, -1e30))
        qk = jnp.einsum("bshn,bthn->bsth", Cb, Bb, preferred_element_type=jnp.float32)
        att = qk * kern
        y_intra = jnp.einsum(
            "bsth,bthp,bth->bshp", att, xb.astype(jnp.float32), dtb
        )
        # --- inter-chunk: contribution of carried state
        decay_in = jnp.exp(cumb)                         # (B, c, H)
        y_inter = jnp.einsum(
            "bshn,bhpn,bsh->bshp", Cb, state, decay_in
        )
        # --- state update
        total = cumb[:, -1, :]                           # (B, H)
        decay_out = jnp.exp(total[:, None, :] - cumb)    # (B, c, H)
        state_in = jnp.einsum(
            "bthn,bthp,bth,bth->bhpn", Bb, xb.astype(jnp.float32), dtb, decay_out
        )
        state = state * jnp.exp(total)[:, :, None, None] + state_in
        return state, (y_intra + y_inter).astype(xh.dtype)

    state0 = (
        jnp.zeros((B, H, P, S), jnp.float32)
        if initial_state is None
        else initial_state
    )
    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3, 4),
        Cc.transpose(1, 0, 2, 3, 4),
        dA.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    state, ys = jax.lax.scan(chunk_step, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, H, P)
    return y, state


def mamba_mixer(x, p, cfg: ModelConfig, *, state=None, return_state=False):
    """Mamba-2 block (in_proj -> conv1d -> SSD -> gated out_proj).

    x: (B, L, d_model).  When ``state`` is provided (decode), L may be 1 and
    (conv_state, ssm_state) are updated incrementally.
    """
    B, L, _ = x.shape
    H, P, S, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, 1
    d_in = cfg.d_inner
    conv_dim = d_in + 2 * G * S

    zxbcdt = constrain(x @ p["in_proj"], ("batch", None, "model"))
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)

    # causal depthwise conv over the sequence
    w = p["conv_w"]                                      # (K, conv_dim)
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, K - 1, conv_dim), xbc.dtype)
        xb_pad = jnp.concatenate([pad, xbc], axis=1)
        new_conv_state = xb_pad[:, -(K - 1):, :] if return_state else None
    else:
        xb_pad = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv_state = xb_pad[:, -(K - 1):, :]
    conv = sum(
        xb_pad[:, i : i + L, :] * w[i][None, None, :] for i in range(K)
    ) + p["conv_b"]
    conv = jax.nn.silu(conv)

    xh = constrain(
        conv[..., :d_in].reshape(B, L, H, P), ("batch", None, "model", None)
    )
    Bm = conv[..., d_in : d_in + G * S].reshape(B, L, G, S)
    Cm = conv[..., d_in + G * S :].reshape(B, L, G, S)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (H,) negative

    init_state = state["ssm"] if state is not None else None
    chunk = _largest_divisor(L, min(cfg.ssm_chunk, L))
    y, fin = ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk, initial_state=init_state)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, L, d_in) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state or state is not None:
        return out, {"conv": new_conv_state, "ssm": fin}
    return out, None
