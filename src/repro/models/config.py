"""Unified model configuration for the assigned architecture pool.

One ModelConfig describes every family: dense GQA transformers, MoE,
Mamba-2 (SSD), hybrid interleaves, and modality-frontend backbones.  Layers
are grouped into *super-blocks* (the repeating ``pattern``) so heterogeneous
stacks (Jamba's 1-attn:7-mamba, MoE-every-2) scan cleanly with stacked
parameters: n_layers == len(pattern) * n_super.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer inside the repeating super-block pattern."""

    mixer: str = "attn"      # "attn" | "mamba"
    ffn: str = "dense"       # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)

    # attention features
    qk_norm: bool = False
    rope_theta: float = 1e4

    # FFN
    act: str = "swiglu"          # "swiglu" | "squared_relu"

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "gather"     # "gather" (pjit scatter/gather baseline) |
    #   "a2a" (shard_map all-to-all dispatch, §Perf optimized path)

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # modality frontend stub: extra precomputed embeddings prepended
    frontend: str | None = None   # None | "vit" | "audio"
    frontend_len: int = 0         # patches/frames provided by input_specs()

    # numerics / memory
    param_dtype: str = "float32"
    moment_dtype: str = "float32"
    accum_dtype: str = "float32"
    remat: str = "full"           # "full" | "dots" | "none"
    seq_shard_carry: bool = False  # Megatron-style sequence parallelism for
    #   the residual stream between blocks: the layer-scan carry (saved for
    #   backward) is sharded over `model` along the sequence axis.  Required
    #   to fit >=30B archs at 4k tokens/device; ablated in §Perf.
    scan_levels: int = 1          # 2 = sqrt-remat: two-level layer scan
    #   saving only ~2*sqrt(n_super) residual carries for backward instead
    #   of n_super (§Perf, deep-stack memory lever).

    # attention chunking (flash-style scan)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    causal_skip: bool = False    # §Perf: skip fully-masked causal tiles via
    #   a static lower-triangle (q,kv)-pair scan — halves attention
    #   compute + score traffic at equal semantics.

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return all(b.mixer != "attn" for b in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Serves 500k-token contexts without O(L^2) prefill state blowup:
        SSM/hybrid families (constant or dominated-by-SSM state)."""
        return any(b.mixer == "mamba" for b in self.pattern)

    def param_count(self) -> int:
        """Total parameters (exact, matches init_params)."""
        from . import model  # local import to avoid cycle

        return model.count_params(self)

    def active_param_count(self) -> int:
        from . import model

        return model.count_params(self, active_only=True)


def dense_pattern() -> Tuple[BlockSpec, ...]:
    return (BlockSpec(mixer="attn", ffn="dense"),)


def moe_pattern(every: int = 1) -> Tuple[BlockSpec, ...]:
    """MoE every `every` layers (dense otherwise)."""
    if every == 1:
        return (BlockSpec(mixer="attn", ffn="moe"),)
    return tuple(
        BlockSpec(mixer="attn", ffn="moe" if (i % every == every - 1) else "dense")
        for i in range(every)
    )


def mamba_pattern() -> Tuple[BlockSpec, ...]:
    return (BlockSpec(mixer="mamba", ffn="none"),)


def jamba_pattern() -> Tuple[BlockSpec, ...]:
    """Jamba super-block: 8 layers, attention at index 4 (1:7 ratio), MoE on
    every other layer (odd indices) — arXiv:2403.19887."""
    return tuple(
        BlockSpec(
            mixer="attn" if i == 4 else "mamba",
            ffn="moe" if i % 2 == 1 else "dense",
        )
        for i in range(8)
    )
