"""Unified LM: parameter init, training forward (scan over super-blocks with
configurable remat), chunked cross-entropy, and the serving path
(prefill + single-token decode with KV / SSM caches).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import constrain

from . import layers
from .config import BlockSpec, ModelConfig

Params = Dict[str, Any]
COMPUTE = jnp.bfloat16


# --------------------------------------------------------------------- init
def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _block_shapes(cfg: ModelConfig, spec: BlockSpec) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.head_dim
    shapes: Dict[str, Tuple[int, ...]] = {"norm1": (d,)}
    if spec.mixer == "attn":
        shapes.update(
            wq=(d, cfg.n_heads * hd),
            wk=(d, cfg.n_kv_heads * hd),
            wv=(d, cfg.n_kv_heads * hd),
            wo=(cfg.n_heads * hd, d),
        )
        if cfg.qk_norm:
            shapes.update(q_norm=(hd,), k_norm=(hd,))
    else:  # mamba
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        shapes.update(
            in_proj=(d, 2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads),
            conv_w=(cfg.ssm_conv, conv_dim),
            conv_b=(conv_dim,),
            dt_bias=(cfg.ssm_heads,),
            A_log=(cfg.ssm_heads,),
            D=(cfg.ssm_heads,),
            out_proj=(cfg.d_inner, d),
        )
    if spec.ffn == "dense":
        shapes["norm2"] = (d,)
        if cfg.act == "swiglu":
            shapes.update(w_gate=(d, cfg.d_ff), w_up=(d, cfg.d_ff), w_down=(cfg.d_ff, d))
        else:
            shapes.update(w_up=(d, cfg.d_ff), w_down=(cfg.d_ff, d))
    elif spec.ffn == "moe":
        shapes["norm2"] = (d,)
        E, f = cfg.n_experts, cfg.d_ff
        shapes.update(router=(d, E))
        if cfg.act == "swiglu":
            shapes.update(w_gate=(E, d, f), w_up=(E, d, f), w_down=(E, f, d))
        else:
            shapes.update(w_up=(E, d, f), w_down=(E, f, d))
        if cfg.n_shared_experts:
            sf = f * cfg.n_shared_experts
            shapes.update(
                shared_gate=(d, sf), shared_up=(d, sf), shared_down=(sf, d)
            )
    return shapes


def param_shapes(cfg: ModelConfig) -> Params:
    """Abstract parameter tree: leaves are (shape, dtype) ShapeDtypeStructs."""
    dt = jnp.dtype(cfg.param_dtype)
    tree: Params = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), dt),
        "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dt),
        "blocks": [],
    }
    for spec in cfg.pattern:
        blk = {
            k: jax.ShapeDtypeStruct((cfg.n_super,) + shp, dt)
            for k, shp in _block_shapes(cfg, spec).items()
        }
        tree["blocks"].append(blk)
    return tree


def init_params(key, cfg: ModelConfig) -> Params:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    keys = jax.random.split(key, len(flat))

    def init_leaf(path, sds, k):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if "norm" in str(name):
            return jnp.ones(sds.shape, sds.dtype)
        if str(name) == "A_log":
            # A in [1, 16) as in Mamba-2 reference init
            u = jax.random.uniform(k, sds.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(sds.dtype)
        if str(name) in ("conv_b", "dt_bias"):
            return jnp.zeros(sds.shape, sds.dtype)
        if str(name) == "D":
            return jnp.ones(sds.shape, sds.dtype)
        return _dense(k, sds.shape, sds.dtype)

    leaves = [init_leaf(p, s, k) for (p, s), k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count; active_only counts top-k of MoE experts."""
    total = 0
    for path, sds in jax.tree_util.tree_flatten_with_path(param_shapes(cfg))[0]:
        n = int(np.prod(sds.shape))
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if active_only and name in ("w_gate", "w_up", "w_down") and len(sds.shape) == 4:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


# ------------------------------------------------------------------ blocks
def _mixer(h, p, spec: BlockSpec, cfg: ModelConfig, positions):
    if spec.mixer == "attn":
        B, L, d = h.shape
        hdims = ("batch", None, "model", None)
        q = constrain((h @ p["wq"]).reshape(B, L, cfg.n_heads, cfg.head_dim), hdims)
        k = constrain((h @ p["wk"]).reshape(B, L, cfg.n_kv_heads, cfg.head_dim), hdims)
        v = constrain((h @ p["wv"]).reshape(B, L, cfg.n_kv_heads, cfg.head_dim), hdims)
        if cfg.qk_norm:
            q = layers.rms_norm(q, p["q_norm"])
            k = layers.rms_norm(k, p["k_norm"])
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        o = layers.flash_attention(
            q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            causal_skip=cfg.causal_skip,
        )
        return o.reshape(B, L, cfg.n_heads * cfg.head_dim) @ p["wo"], None
    out, _ = layers.mamba_mixer(h, p, cfg)
    return out, None


def _ffn(h, p, spec: BlockSpec, cfg: ModelConfig):
    if spec.ffn == "dense":
        return layers.dense_ffn(h, p, cfg), jnp.zeros((), jnp.float32)
    impl = layers.moe_ffn_a2a if cfg.moe_impl == "a2a" else layers.moe_ffn
    y, stats = impl(h, p, cfg)
    return y, stats.aux_loss


def _cast_tree(p, dtype=COMPUTE):
    """Cast float params to the compute dtype at point-of-use (master copies
    stay in cfg.param_dtype)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a, p
    )


def _super_block(h, blk_params, cfg: ModelConfig, positions):
    aux = jnp.zeros((), jnp.float32)
    blk_params = _cast_tree(blk_params)
    seq_ax = "model" if cfg.seq_shard_carry else None
    hdims = ("batch", seq_ax, None)
    h = constrain(h, hdims)
    for j, spec in enumerate(cfg.pattern):
        p = blk_params[j]
        mix, _ = _mixer(layers.rms_norm(h, p["norm1"]), p, spec, cfg, positions)
        h = constrain(h + mix, hdims)
        if spec.ffn != "none":
            f, a = _ffn(layers.rms_norm(h, p["norm2"]), p, spec, cfg)
            h = constrain(h + f, hdims)
            aux = aux + a
    return h, aux


_REMAT_POLICIES = {
    "full": None,  # save nothing
    "dots": "dots_with_no_batch_dims_saveable",
    "none": "everything_saveable",
}


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = _REMAT_POLICIES[cfg.remat]
    if policy is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=getattr(jax.checkpoint_policies, policy))


# ----------------------------------------------------------------- forward
def _sqrt_factor(n: int) -> int:
    """Divisor of n closest to sqrt(n) (outer scan length)."""
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - n ** 0.5) < abs(best - n ** 0.5):
            best = d
    return best


def backbone(params: Params, cfg: ModelConfig, h, positions):
    """Run the block stack on embeddings h (B, L, d) -> (hidden, aux_loss)."""
    body = _maybe_remat(
        lambda carry, xs: _super_block(carry, xs, cfg, positions), cfg
    )
    if cfg.scan_levels == 2 and cfg.n_super > 3:
        # sqrt-remat: the outer scan saves only group-boundary carries; the
        # checkpointed group body recomputes its inner carries in backward.
        outer = _sqrt_factor(cfg.n_super)
        inner = cfg.n_super // outer
        grouped = jax.tree.map(
            lambda a: a.reshape((outer, inner) + a.shape[1:]), params["blocks"]
        )

        @jax.checkpoint
        def group_body(carry, xs_group):
            h2, aux2 = jax.lax.scan(body, carry, xs_group)
            return h2, jnp.sum(aux2)

        h, aux = jax.lax.scan(group_body, h, grouped)
    else:
        h, aux = jax.lax.scan(body, h, params["blocks"])
    return layers.rms_norm(h, params["final_norm"]), jnp.sum(aux)


def embed_inputs(params: Params, cfg: ModelConfig, tokens, extra_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE)
    if cfg.frontend_len:
        assert extra_embeds is not None, f"{cfg.name} needs frontend embeddings"
        h = jnp.concatenate([extra_embeds.astype(COMPUTE), h], axis=1)
    return constrain(h, ("batch", None, None))


def chunked_ce_loss(h, lm_head, labels, mask, chunk: int = 1024):
    """Cross-entropy without materializing (T, vocab) logits: scan over
    sequence chunks, fp32 log-softmax, remat'd so backward recomputes."""
    B, L, d = h.shape
    chunk = layers._largest_divisor(L, min(chunk, L))
    nc = L // chunk
    hc = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, xs):
        hh, yy, mm = xs
        logits = constrain(
            (hh @ lm_head).astype(jnp.float32), ("batch", None, "model")
        )
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yy[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return (carry[0] + nll.sum(), carry[1] + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, yc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params: Params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    """batch: dict(tokens (B,L) int32, labels (B,L) int32, extra_embeds?).

    Frontend positions (if any) carry no loss.
    """
    tokens = batch["tokens"]
    h = embed_inputs(params, cfg, tokens, batch.get("extra_embeds"))
    B, L, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(L)[None, :], (B, L))
    hidden, aux = backbone(params, cfg, h, positions)
    labels = batch["labels"]
    mask = jnp.ones_like(labels, jnp.float32)
    if cfg.frontend_len:  # prepend ignore for frontend positions
        pad_lab = jnp.zeros((B, cfg.frontend_len), labels.dtype)
        labels = jnp.concatenate([pad_lab, labels], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.frontend_len), jnp.float32), mask], axis=1
        )
    ce = chunked_ce_loss(hidden, params["lm_head"].astype(COMPUTE), labels, mask)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ------------------------------------------------------------------- serve
class DecodeState(NamedTuple):
    """Per-pattern-position caches, each stacked over n_super blocks."""

    caches: Tuple[Any, ...]   # attn: dict(k, v); mamba: dict(conv, ssm)
    pos: jax.Array            # current length (scalar int32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> DecodeState:
    caches = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            shp = (cfg.n_super, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            caches.append(
                {"k": jnp.zeros(shp, COMPUTE), "v": jnp.zeros(shp, COMPUTE)}
            )
        else:
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            caches.append(
                {
                    "conv": jnp.zeros(
                        (cfg.n_super, batch, cfg.ssm_conv - 1, conv_dim), COMPUTE
                    ),
                    "ssm": jnp.zeros(
                        (cfg.n_super, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                        jnp.float32,
                    ),
                }
            )
    return DecodeState(caches=tuple(caches), pos=jnp.zeros((), jnp.int32))


def _mixer_decode(h, p, spec, cfg, cache, pos):
    """One-token mixer with cache update.  h: (B, 1, d)."""
    B = h.shape[0]
    if spec.mixer == "attn":
        q = (h @ p["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = layers.rms_norm(q, p["q_norm"])
            k = layers.rms_norm(k, p["k_norm"])
        posb = jnp.full((B, 1), pos, jnp.int32)
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        o = layers.decode_attention(q, kc, vc, pos + 1)
        out = o.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
        return out, {"k": kc, "v": vc}
    out, new_state = layers.mamba_mixer(h, p, cfg, state=cache)
    return out, new_state


def decode_step(params: Params, cfg: ModelConfig, state: DecodeState, tokens):
    """tokens: (B, 1) -> (logits (B, vocab), new state)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE)
    pos = state.pos

    def body(carry, xs):
        h = carry
        blk_params, caches = xs
        blk_params = _cast_tree(blk_params)
        new_caches = []
        for j, spec in enumerate(cfg.pattern):
            p = blk_params[j]
            mix, nc = _mixer_decode(
                layers.rms_norm(h, p["norm1"]), p, spec, cfg, caches[j], pos
            )
            h = h + mix
            new_caches.append(nc)
            if spec.ffn != "none":
                f, _ = _ffn(layers.rms_norm(h, p["norm2"]), p, spec, cfg)
                h = h + f
        return h, tuple(new_caches)

    h, new_caches = jax.lax.scan(body, h, (params["blocks"], state.caches))
    h = layers.rms_norm(h, params["final_norm"])
    logits = (h[:, 0, :] @ params["lm_head"].astype(COMPUTE)).astype(jnp.float32)
    return logits, DecodeState(caches=new_caches, pos=pos + 1)


def prefill(params: Params, cfg: ModelConfig, tokens, max_len: int,
            extra_embeds=None):
    """Batched prompt ingestion: returns (last-token logits, DecodeState)."""
    h = embed_inputs(params, cfg, tokens, extra_embeds)
    B, L, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(L)[None, :], (B, L))

    def body(carry, blk_params):
        h = carry
        blk_params = _cast_tree(blk_params)
        caches = []
        for j, spec in enumerate(cfg.pattern):
            p = blk_params[j]
            hn = layers.rms_norm(h, p["norm1"])
            if spec.mixer == "attn":
                q = (hn @ p["wq"]).reshape(B, L, cfg.n_heads, cfg.head_dim)
                k = (hn @ p["wk"]).reshape(B, L, cfg.n_kv_heads, cfg.head_dim)
                v = (hn @ p["wv"]).reshape(B, L, cfg.n_kv_heads, cfg.head_dim)
                if cfg.qk_norm:
                    q = layers.rms_norm(q, p["q_norm"])
                    k = layers.rms_norm(k, p["k_norm"])
                q = layers.apply_rope(q, positions, cfg.rope_theta)
                k = layers.apply_rope(k, positions, cfg.rope_theta)
                o = layers.flash_attention(
                    q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
                )
                h = h + o.reshape(B, L, cfg.n_heads * cfg.head_dim) @ p["wo"]
                pad = max_len - L
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(COMPUTE)
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(COMPUTE)
                caches.append({"k": kc, "v": vc})
            else:
                mix, st = layers.mamba_mixer(hn, p, cfg, return_state=True)
                h = h + mix
                caches.append(
                    {"conv": st["conv"].astype(COMPUTE), "ssm": st["ssm"]}
                )
            if spec.ffn != "none":
                f, _ = _ffn(layers.rms_norm(h, p["norm2"]), p, spec, cfg)
                h = h + f
        return h, tuple(caches)

    h, caches = jax.lax.scan(body, h, params["blocks"])
    h = layers.rms_norm(h, params["final_norm"])
    logits = (h[:, -1, :] @ params["lm_head"].astype(COMPUTE)).astype(jnp.float32)
    return logits, DecodeState(caches=caches, pos=jnp.asarray(L, jnp.int32))
