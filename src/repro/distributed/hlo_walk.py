"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` does not multiply while-loop bodies by their
trip counts, so scan-based models (layers, microbatches, attention chunks)
are undercounted by orders of magnitude.  This walker parses the post-SPMD
scheduled HLO, builds the computation call graph (while / fusion / call /
conditional), extracts static trip counts, and accumulates:

  * dot/conv FLOPs (exact shapes via per-computation symbol tables —
    scheduled HLO prints operands without types)
  * HBM traffic at materialization granularity (op outputs + operands in
    non-fused computations — post-fusion boundaries)
  * per-collective-type wire bytes (ring model)

All values are per-device (the module is the SPMD-partitioned per-device
program).  Loop bounds: jax scans bake the length into the loop condition
as an s32[] constant (possibly behind a wrapped-compare fusion), so the
trip count is the max s32 scalar constant in the condition computation.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str):
    """Procedural parse: '%name = TYPE opcode(args...), attrs'.

    TYPE may be a tuple '(...)' with nested brackets and /*index=N*/ comments,
    so regexes over a fixed charset fail; walk balanced parens instead.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        out_type = rest[: i + 1]
        rest = rest[i + 1 :].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_type = rest[:sp]
        rest = rest[sp + 1 :].lstrip()
    par = rest.find("(")
    if par <= 0:
        return None
    opcode = rest[:par]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    args = rest[par + 1 :]
    return name, out_type, opcode, args
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_SCALAR_S32_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_HEADER_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*(\(?[^,()]*(?:\([^()]*\))?[^,()]*)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_type: str
    args: str      # raw text after the opening paren (operands + attrs)
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    types: Dict[str, str]          # symbol -> type string
    s32_consts: List[int]


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.startswith("HloModule"):
            continue
        if cur is None:
            if stripped.endswith("{") and "->" in stripped and "(" in stripped:
                head = stripped.split("(", 1)
                is_entry = head[0].startswith("ENTRY")
                name = head[0].replace("ENTRY", "").strip().lstrip("%")
                cur = Computation(name=name, ops=[], types={}, s32_consts=[])
                if is_entry:
                    entry = name
                # parameter types from the signature segment (up to '->')
                sig = stripped[len(head[0]):].rsplit("->", 1)[0]
                for pname, ptype in _HEADER_PARAM_RE.findall(sig):
                    cur.types[pname] = ptype
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name_, out_type, opcode, args = parsed
            op = Op(
                name=name_, opcode=opcode, out_type=out_type, args=args,
                line=stripped,
            )
            cur.ops.append(op)
            cur.types[op.name] = op.out_type
        mc = _SCALAR_S32_CONST_RE.search(stripped)
        if mc:
            cur.s32_consts.append(int(mc.group(1)))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _operand_names(args: str) -> List[str]:
    """Operand symbol names: %tokens before the closing paren of the call."""
    depth = 1
    end = len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", args[:end])


def _trip_count(cond: Computation) -> int:
    """jax scan bound = s32 scalar constant in the condition computation."""
    if cond.s32_consts:
        return max(max(cond.s32_consts), 1)
    return 1


def _dot_flops(op: Op, types: Dict[str, str]) -> float:
    out_shapes = _shapes_in(op.out_type)
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    opers = _operand_names(op.args)
    if not opers:
        return 0.0
    lhs_type = types.get(opers[0], "")
    lhs_shapes = _shapes_in(lhs_type)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, types: Dict[str, str]) -> float:
    out_shapes = _shapes_in(op.out_type)
    opers = _operand_names(op.args)
    if not out_shapes or len(opers) < 2:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    k_shapes = _shapes_in(types.get(opers[1], ""))
    if not k_shapes:
        return 0.0
    k_elems = 1
    for d in k_shapes[0][1][:-1]:
        k_elems *= d
    return 2.0 * out_elems * k_elems


_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return default


def _wire_bytes(op: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g * out_bytes
    if op == "all-gather":
        return (g - 1) / g * out_bytes
    if op == "reduce-scatter":
        return (g - 1) * out_bytes
    if op == "all-to-all":
        return (g - 1) / g * out_bytes
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    per_collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    per_collective_ops: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)
    debug_items: list = dataclasses.field(default_factory=list)

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.pop("debug_items", None)
        return d


def analyze(text: str, n_devices: int, debug: bool = False) -> HloCost:
    comps, entry = parse_computations(text)
    cost = HloCost()

    _SKIP_BYTES = {
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "after-all", "partition-id", "while", "conditional",
    }

    def _op_bytes(comp: Computation, op: Op) -> float:
        """Effective HBM traffic of one materialized op.

        dynamic-slice reads only the slice; dynamic-update-slice writes only
        the update region (in-place).  Fusions whose parameters are consumed
        exclusively by dynamic-slices (stacked-parameter indexing inside
        scans) count the sliced bytes, not the full stacked operand; a
        dynamic-update-slice ROOT counts the update, not the whole buffer.
        """
        if op.opcode == "dynamic-slice":
            return 2.0 * _shape_bytes(op.out_type)
        opers = _operand_names(op.args)
        if op.opcode == "dynamic-update-slice":
            upd = _shape_bytes(comp.types.get(opers[1], "")) if len(opers) > 1 else 0
            return 2.0 * upd
        if op.opcode == "copy" and opers:
            # donation-artifact copies of unmodified parameters (CPU backend
            # cannot alias donated buffers); free on the TPU target.
            defs = {o.name: o for o in comp.ops}
            src = opers[0]
            for _ in range(8):  # peel bitcast/gte/copy chains
                if src.startswith("param") or src.startswith("arg_"):
                    return 0.0
                d = defs.get(src)
                if d is None or d.opcode == "parameter":
                    return 0.0
                if d.opcode in ("bitcast", "get-tuple-element", "copy"):
                    srcs = _operand_names(d.args)
                    if not srcs:
                        break
                    src = srcs[0]
                else:
                    break
        out_b = _shape_bytes(op.out_type)
        if op.opcode == "fusion":
            called = None
            for cname in _CALLS_RE.findall(op.line):
                called = comps.get(cname)
                break
            if called is not None:
                return _fusion_bytes(called, comp, opers, out_b)
        ib = sum(_shape_bytes(comp.types.get(o, "")) for o in opers)
        return out_b + ib

    # dtype converts are free on the bf16-native TPU target (XLA CPU inserts
    # bf16<->f32 emulation chains); bitcasts/copies/reshapes keep aliasing.
    _TRANSPARENT = {"convert", "bitcast", "copy", "reshape"}

    def _fusion_bytes(called: Computation, caller: Computation, opers, out_b):
        """Effective traffic of a fusion: reads of params (slice-aware,
        looking through transparent convert chains), writes of produced
        tensors (update-region-aware for in-place dynamic-update-slice)."""
        by_idx = {}
        defs = {o.name: o for o in called.ops}
        for o in called.ops:
            if o.opcode == "parameter":
                mi = re.match(r"\s*(\d+)", o.args)
                if mi:
                    by_idx[int(mi.group(1))] = o.name

        def slim_read(pname) -> Optional[float]:
            """Bytes actually read from pname if all transitive uses are
            slices or in-place-update destinations; None => full read."""
            total, frontier, seen = 0.0, [pname], {pname}
            while frontier:
                nm = frontier.pop()
                for u in called.ops:
                    uo = _operand_names(u.args)
                    if nm not in uo:
                        continue
                    if u.opcode in _TRANSPARENT:
                        if u.name not in seen:
                            seen.add(u.name)
                            frontier.append(u.name)
                    elif u.opcode == "dynamic-slice":
                        total += _shape_bytes(u.out_type)
                    elif u.opcode == "dynamic-update-slice" and uo[0] == nm:
                        pass  # aliased destination
                    else:
                        return None
            return total

        read = 0.0
        for i, oname in enumerate(opers):
            full = _shape_bytes(caller.types.get(oname, ""))
            pname = by_idx.get(i)
            if pname is None:
                read += full
                continue
            slim = slim_read(pname)
            read += full if slim is None else min(slim, full)

        # writes: every DUS writes its update region; the root (or each
        # non-DUS-backed tuple element, peeled through converts) adds its
        # full output.
        write = 0.0
        dus_backed = set()
        for u in called.ops:
            if u.opcode == "dynamic-update-slice":
                uo = _operand_names(u.args)
                upd = _shape_bytes(called.types.get(uo[1], "")) if len(uo) > 1 else 0
                write += upd
                dus_backed.add(u.name)

        def peel(name):
            op = defs.get(name)
            while op is not None and op.opcode in _TRANSPARENT:
                o = _operand_names(op.args)
                if not o:
                    break
                op = defs.get(o[0])
            return op

        root = called.ops[-1] if called.ops else None
        if root is None:
            write += out_b
        elif root.opcode == "tuple":
            for o in _operand_names(root.args):
                p = peel(o)
                if p is None or p.opcode != "dynamic-update-slice":
                    write += _shape_bytes(called.types.get(o, ""))
        else:
            p = peel(root.name)
            if p is None or p.opcode != "dynamic-update-slice":
                write += out_b
        return read + write

    def visit(name: str, mult: float, count_bytes: bool):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            base = None
            for c in COLLECTIVE_OPS:
                if op.opcode == c or op.opcode.startswith(c + "-start"):
                    base = c
                    break
            if base:
                ob = _shape_bytes(op.out_type)
                g = _group_size(op.line, n_devices)
                wb = _wire_bytes(base, ob, g) * mult
                cost.per_collective_bytes[base] = (
                    cost.per_collective_bytes.get(base, 0.0) + wb
                )
                cost.per_collective_ops[base] = (
                    cost.per_collective_ops.get(base, 0.0) + mult
                )
                cost.collective_wire_bytes += wb
            if op.opcode == "dot":
                cost.flops += _dot_flops(op, comp.types) * mult
            elif op.opcode == "convolution":
                cost.flops += _conv_flops(op, comp.types) * mult
            if count_bytes and op.opcode not in _SKIP_BYTES:
                b = _op_bytes(comp, op)
                cost.bytes += b * mult
                if debug and b * mult > 1e8:
                    cost.debug_items.append(
                        (b * mult, mult, comp.name[:48], op.opcode, op.out_type[:64])
                    )

            if op.opcode == "while":
                m = _WHILE_RE.search(op.line)
                if m:
                    cond_name, body_name = m.groups()
                    trips = (
                        _trip_count(comps[cond_name]) if cond_name in comps else 1
                    )
                    cost.while_trips[body_name] = trips
                    visit(body_name, mult * trips, count_bytes)
            elif op.opcode in ("fusion", "call", "custom-call", "conditional"):
                for cname in _CALLS_RE.findall(op.line):
                    # descend for flops only: fused interiors don't touch HBM
                    visit(cname, mult, False)

    if entry:
        visit(entry, 1.0, True)
    return cost
