"""Sharding rules: parameter, optimizer, batch and cache PartitionSpecs.

Scheme (DESIGN.md §6): TP over ``model`` for heads / ffn-hidden / experts /
vocab; FSDP over ``data`` on the complementary dimension of every large
matrix; DP gradient reduction over data (+pod) comes from pjit's handling of
the sharded-parameter <- replicated-compute contraction.  The leading
``n_super`` scan axis of stacked block params is never sharded.

Rules are *name- and shape-driven* so every architecture family (dense, MoE,
SSD, hybrid) resolves through one table.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.model import DecodeState, param_shapes


def _fsdp_ok(dim: int, mesh: Mesh) -> str | None:
    """Shard a dimension over `data` only when it divides evenly."""
    return "data" if dim % mesh.shape["data"] == 0 else None


def param_spec(name: str, shape, cfg: ModelConfig, mesh: Mesh, *, stacked: bool,
               flat_fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf (shape excludes the scan axis).

    flat_fsdp: pure FSDP over the flattened (data, model) axes, no tensor
    parallelism — the right scheme for small models where TP all-reduces
    dominate (§Perf, internlm2 iteration)."""
    model_n = mesh.shape["model"]

    if flat_fsdp:
        axes = ("data", "model")
        n_all = mesh.shape["data"] * mesh.shape["model"]
        spec_l = [None] * len(shape)
        # shard the largest divisible dim over the flattened axes
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % n_all == 0:
                spec_l[i] = axes
                break
        else:
            for i in order:  # fall back to data-only
                if shape[i] % mesh.shape["data"] == 0:
                    spec_l[i] = "data"
                    break
        if stacked:
            spec_l = [None] + spec_l
        return P(*spec_l)

    def fsdp(dim):
        return _fsdp_ok(dim, mesh)

    if name in ("embed",):                       # (vocab, d)
        spec = ("model" if shape[0] % model_n == 0 else None, fsdp(shape[1]))
    elif name == "lm_head":                      # (d, vocab)
        spec = (fsdp(shape[0]), "model" if shape[1] % model_n == 0 else None)
    elif name in ("wq", "wk", "wv"):             # (d, H*hd)
        tp = "model" if shape[1] % model_n == 0 else None
        spec = (fsdp(shape[0]), tp)
    elif name == "wo":                           # (H*hd, d)
        tp = "model" if shape[0] % model_n == 0 else None
        spec = (tp, fsdp(shape[1]))
    elif name in ("w_gate", "w_up"):
        if len(shape) == 3:                      # MoE (E, d, ff)
            spec = ("model" if shape[0] % model_n == 0 else None, fsdp(shape[1]), None)
        else:                                    # dense (d, ff)
            spec = (fsdp(shape[0]), "model" if shape[1] % model_n == 0 else None)
    elif name == "w_down":
        if len(shape) == 3:                      # MoE (E, ff, d)
            spec = ("model" if shape[0] % model_n == 0 else None, None, fsdp(shape[2]))
        else:                                    # dense (ff, d)
            spec = ("model" if shape[0] % model_n == 0 else None, fsdp(shape[1]))
    elif name in ("shared_gate", "shared_up"):   # (d, sf)
        spec = (fsdp(shape[0]), "model" if shape[1] % model_n == 0 else None)
    elif name == "shared_down":                  # (sf, d)
        spec = ("model" if shape[0] % model_n == 0 else None, fsdp(shape[1]))
    elif name == "router":                       # (d, E) small
        spec = (None, None)
    elif name == "in_proj":                      # (d, 2*d_in + 2GS + H)
        tp = "model" if shape[1] % model_n == 0 else None
        spec = (fsdp(shape[0]), tp)
    elif name == "out_proj":                     # (d_in, d)
        tp = "model" if shape[0] % model_n == 0 else None
        spec = (tp, fsdp(shape[1]))
    elif name == "conv_w":                       # (K, conv_dim)
        spec = (None, "model" if shape[1] % model_n == 0 else None)
    elif name == "conv_b":
        spec = ("model" if shape[0] % model_n == 0 else None,)
    else:                                        # norms, A_log, dt_bias, D, ...
        spec = (None,) * len(shape)
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, flat_fsdp: bool = False):
    """Sharding pytree matching model.param_shapes(cfg)."""
    shapes = param_shapes(cfg)

    def walk(path, sds):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        shape = sds.shape[1:] if stacked else sds.shape
        return NamedSharding(
            mesh,
            param_spec(name, shape, cfg, mesh, stacked=stacked,
                       flat_fsdp=flat_fsdp),
        )

    return jax.tree_util.tree_map_with_path(walk, shapes)


def opt_shardings(param_sh, step_sharding):
    """Optimizer state shardings: moments follow their parameters."""
    from repro.optim.adamw import OptState

    return OptState(step=step_sharding, mu=param_sh, nu=param_sh)


def batch_spec(mesh: Mesh) -> P:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return P(dp, None)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, with_frontend: bool,
                    batch: int | None = None, dp=None):
    if dp is None:
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if batch is not None:
        n_dp = int(np.prod([mesh.shape[a] for a in dp]))
        if batch % n_dp != 0:
            dp = None  # tiny global batch (long-context decode): replicate
    out = {
        "tokens": NamedSharding(mesh, P(dp, None)),
        "labels": NamedSharding(mesh, P(dp, None)),
    }
    if with_frontend:
        out["extra_embeds"] = NamedSharding(mesh, P(dp, None, None))
    return out


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh, batch: int) -> DecodeState:
    """KV caches: batch over data(+pod) when divisible, kv-heads over model
    when divisible; otherwise the sequence axis takes the model sharding
    (long-context decode at batch 1)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    model_n = mesh.shape["model"]
    b_ax = dp if batch % n_dp == 0 else None

    caches = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            kv_ax = "model" if cfg.n_kv_heads % model_n == 0 else None
            seq_ax = None if kv_ax else "model"
            sh = NamedSharding(mesh, P(None, b_ax, seq_ax, kv_ax, None))
            caches.append({"k": sh, "v": sh})
        else:
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            conv_ax = "model" if conv_dim % model_n == 0 else None
            head_ax = "model" if cfg.ssm_heads % model_n == 0 else None
            caches.append(
                {
                    "conv": NamedSharding(mesh, P(None, b_ax, None, conv_ax)),
                    "ssm": NamedSharding(mesh, P(None, b_ax, head_ax, None, None)),
                }
            )
    return DecodeState(
        caches=tuple(caches),
        pos=NamedSharding(mesh, P()),
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
