"""Roofline-term extraction from compiled XLA artifacts (DESIGN.md §7).

Sources:
  * ``compiled.cost_analysis()``  -> per-device HLO FLOPs and bytes accessed
  * ``compiled.as_text()``        -> post-SPMD HLO; collective ops parsed by
    regex with ring-model wire-byte formulas per op type.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Sum bytes of all array shapes in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(op: str, out_bytes: int, g: int) -> float:
    """Per-device bytes on the wire, ring algorithms."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g * out_bytes
    if op == "all-gather":
        return (g - 1) / g * out_bytes
    if op == "reduce-scatter":
        return (g - 1) * out_bytes          # input = g * output
    if op == "all-to-all":
        return (g - 1) / g * out_bytes
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


@dataclasses.dataclass
class CollectiveStats:
    per_type_ops: Dict[str, int]
    per_type_bytes: Dict[str, float]    # per-device wire bytes
    total_wire_bytes: float


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    ops: Dict[str, int] = {}
    byts: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        head, _, rest = ls.partition(" = ")
        m = re.match(r"[\w().\[\],\s]*?(\w[\w\-.]*)\(", rest)
        if not m:
            continue
        opname = m.group(1)
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or opname.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        out_b = _shape_bytes(rest.split(" ", 1)[0])
        if base == "all-to-all" and out_b == 0:
            out_b = _shape_bytes(rest)
        g = _group_size(ls, n_devices)
        ops[base] = ops.get(base, 0) + 1
        byts[base] = byts.get(base, 0.0) + _wire_bytes(base, out_b, g)
    return CollectiveStats(
        per_type_ops=ops,
        per_type_bytes=byts,
        total_wire_bytes=sum(byts.values()),
    )


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_flops_ratio: float   # MODEL_FLOPS / (HLO flops * chips)
    step_time_lower_bound_s: float
    roofline_fraction: float    # useful-compute time / max(term) — the score

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(flops: float, byts: float, wire_bytes: float, n_devices: int,
             model_flops: float) -> Roofline:
    """All inputs per-device (post-SPMD program) except model_flops (global)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_hlo = flops * n_devices
    bound = max(terms.values())
    useful_s = (model_flops / n_devices) / PEAK_FLOPS
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        wire_bytes_per_device=wire_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_hlo) if total_hlo else 0.0,
        step_time_lower_bound_s=bound,
        roofline_fraction=(useful_s / bound) if bound else 0.0,
    )


def model_flops_estimate(cfg, cell, n_tokens: int | None = None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for training;
    2*N*D for inference (fwd only)."""
    from repro.models.model import count_params

    n_active = count_params(cfg, active_only=True)
    if cell.kind == "train":
        toks = cell.global_batch * cell.seq_len
        return 6.0 * n_active * toks
    if cell.kind == "prefill":
        toks = cell.global_batch * cell.seq_len
        return 2.0 * n_active * toks
    toks = cell.global_batch  # one token per sequence
    return 2.0 * n_active * toks
