"""Activation-sharding context: lets model code place sharding constraints
without threading mesh objects through every layer.

The step builders (or dryrun) activate axes with ``activation_axes``; model
code calls ``constrain(x, dims)`` where dims is a tuple naming each axis of
x as one of: "batch" (data-parallel axes), "model", None.  Outside any mesh
context (CPU unit tests) constraints are identity.

Dims whose size does not divide the named mesh axis degrade to None
automatically, so one call site serves every architecture.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _axes():
    return getattr(_state, "axes", None)


def current_axes():
    """Public view of the active activation-sharding context (or None):
    dict(mesh=..., batch=tuple_of_axis_names, model=name_or_None)."""
    return _axes()


@contextlib.contextmanager
def activation_axes(mesh, dp: Sequence[str] = ("data",), model: str = "model"):
    """Enable constraints during tracing.  dp may include 'pod'."""
    prev = _axes()
    _state.axes = {
        "mesh": mesh,
        "batch": tuple(a for a in dp if a in mesh.axis_names),
        "model": model if model in mesh.axis_names else None,
    }
    try:
        yield
    finally:
        _state.axes = prev


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def constrain(x, dims: Sequence[Optional[str]]):
    """dims: per-dimension "batch" | "model" | None."""
    axes = _axes()
    if axes is None or x is None:
        return x
    mesh = axes["mesh"]
    spec = []
    for size, d in zip(x.shape, dims):
        name = axes.get(d) if d else None
        if name and size % _axis_size(mesh, name) == 0:
            spec.append(name)
        else:
            spec.append(None)
    try:
        sh = jax.sharding.NamedSharding(mesh, P(*spec))
        return jax.lax.with_sharding_constraint(x, sh)
    except Exception:
        return x
