"""Distributed train / serve step builders.

``train_step``: gradient accumulation over microbatches (lax.scan, remat'd
model inside), fused AdamW update — the unit the dry-run lowers for
``train_*`` cells.  ``prefill_step`` / ``decode_step``: the serving units
for ``prefill_*`` and ``decode_*`` / ``long_*`` cells.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, n_microbatch: int):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    The global batch is split into n_microbatch slices along batch dim 0;
    grads accumulate in cfg.accum_dtype.  Collectives: per-microbatch FSDP
    all-gathers + one reduce per accumulation (GSPMD inserts them from the
    parameter shardings).
    """

    def train_step(params, opt_state, batch):
        adt = jnp.dtype(cfg.accum_dtype)

        def micro(batch_slice):
            def loss(p):
                return M.loss_fn(p, cfg, batch_slice)

            (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
            return l, aux, grads

        if n_microbatch == 1:
            l, aux, grads = micro(batch)
            metrics = {"loss": l, **aux}
        else:
            B = batch["tokens"].shape[0]
            assert B % n_microbatch == 0, (B, n_microbatch)
            mb = B // n_microbatch
            sliced = jax.tree.map(
                lambda x: x.reshape((n_microbatch, mb) + x.shape[1:]), batch
            )

            def body(carry, bslice):
                acc, lsum = carry
                l, aux, grads = micro(bslice)
                acc = jax.tree.map(lambda a, g: a + g.astype(adt), acc, grads)
                return (acc, lsum + l), aux

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params
            )
            (acc, lsum), auxs = jax.lax.scan(body, (acc0, jnp.zeros(())), sliced)
            grads = jax.tree.map(lambda a: a / n_microbatch, acc)
            metrics = {"loss": lsum / n_microbatch}
            metrics.update({k: jnp.mean(v) for k, v in auxs.items()})

        new_params, new_opt, stats = adamw.apply(opt_cfg, params, grads, opt_state)
        metrics.update(stats)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch: Dict[str, Any]):
        return M.prefill(
            params,
            cfg,
            batch["tokens"],
            max_len,
            extra_embeds=batch.get("extra_embeds"),
        )

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, state: M.DecodeState, tokens):
        return M.decode_step(params, cfg, state, tokens)

    return decode_step
