"""Roofline summary over the dry-run artifacts (experiments/dryrun/*.json):
the per-(arch x shape x mesh) three-term table of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(full: bool = False):
    rows = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / "*.json"))):
        r = json.load(open(f))
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            rows.append((name, {"status": r["status"]}))
            continue
        rf = r["roofline"]
        rows.append(
            (
                name,
                {
                    "compute_s": round(rf["compute_s"], 5),
                    "memory_s": round(rf["memory_s"], 5),
                    "collective_s": round(rf["collective_s"], 5),
                    "dominant": rf["dominant"],
                    "roofline_fraction": round(rf["roofline_fraction"], 5),
                    "useful_flops_ratio": round(rf["useful_flops_ratio"], 4),
                },
            )
        )
    if not rows:
        rows.append(("roofline/missing", {"hint": "run python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun"}))
    return rows
