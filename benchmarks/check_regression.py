"""BENCH trajectory gate: diff two ``BENCH_*.json`` files and exit non-zero
on per-figure wall-time regressions beyond a threshold.

Intended as the CI step behind ROADMAP's "BENCH trajectory tracking":

  python -m benchmarks.run --json BENCH_new.json
  python -m benchmarks.check_regression BENCH_sweep.json BENCH_new.json

Comparison happens at two granularities, both against the same threshold
(default 20%):

  * per figure: ``module_wall_ms`` (each record of a module carries the
    module's wall-time; the max is used);
  * per record: every steady-state ``derived.*_ms`` field a record carries
    in both files — ``engine_ms`` keyed by the plain record name (so old
    baselines keep comparing), per-phase fields (``table_ms`` /
    ``arbitrate_ms`` / ``score_ms``) keyed ``name:field``.  Compile time is
    excluded everywhere, so these are the stable trajectory signals.

Figures/records/fields present in only one file are reported but never fail
the gate (benchmarks — and phase breakdowns — come and go; old baselines
without the breakdown stay usable); a ``full`` flag mismatch is a hard error
(exit 2) since fast and paper-scale runs are not comparable.

Noisy-container hardening: generate candidates with
``python -m benchmarks.run --runs 3 --json ...`` so both sides of the diff
carry *median* timings, and/or widen the gate via the
``BENCH_GATE_THRESHOLD`` environment variable (the ``--threshold`` default)
— PR 3 measured 23/51 records of identical code drifting >20% between
single runs on a 2-core container, so a single-run 20% gate is only
meaningful on a quiet machine.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

#: Default maximum allowed slowdown (new/old - 1) before the gate fails;
#: overridable via the BENCH_GATE_THRESHOLD environment variable.
DEFAULT_THRESHOLD = 0.20


def _default_threshold() -> float:
    raw = os.environ.get("BENCH_GATE_THRESHOLD")
    if raw is None:
        return DEFAULT_THRESHOLD
    try:
        value = float(raw)
    except ValueError:
        raise SystemExit(
            f"BENCH_GATE_THRESHOLD must be a float, got {raw!r}"
        ) from None
    if value <= 0:
        raise SystemExit(
            f"BENCH_GATE_THRESHOLD must be positive, got {raw!r}"
        )
    return value


def _timed_out(rec: dict) -> bool:
    """Marker record written by ``benchmarks.run --timeout`` for a module
    that blew its wall budget — carries no real timings."""
    return bool(rec.get("derived", {}).get("timeout"))


def _figure_walls(payload: dict) -> Dict[str, float]:
    walls: Dict[str, float] = {}
    for rec in payload.get("records", []):
        if _timed_out(rec):
            continue  # treated as missing: one-sided note, never a failure
        walls[rec["figure"]] = max(
            walls.get(rec["figure"], 0.0), float(rec.get("module_wall_ms", 0.0))
        )
    return walls


def _record_times(payload: dict) -> Dict[str, float]:
    """Per-record steady timings: every ``derived.*_ms`` field.

    ``engine_ms`` keys by the plain record name (back-compat with baselines
    written before the per-phase breakdown existed); any other ``*_ms``
    field keys ``f"{name}:{field}"``.  Fields missing on either side of a
    diff become one-sided notes in ``compare`` — never failures."""
    times: Dict[str, float] = {}
    for rec in payload.get("records", []):
        if _timed_out(rec):
            continue
        for field, value in rec.get("derived", {}).items():
            if not field.endswith("_ms") or value is None:
                continue
            key = rec["name"] if field == "engine_ms" else f"{rec['name']}:{field}"
            times[key] = float(value)
    return times


def compare(old: dict, new: dict, threshold: float = DEFAULT_THRESHOLD
            ) -> Tuple[List[dict], List[str]]:
    """Returns (regressions, notes).  A regression dict has ``kind``
    ("figure" | "record"), ``name``, ``old_ms``, ``new_ms``, ``ratio``.
    Regressions come sorted by magnitude (worst ratio first), so the
    failure message leads with the record that actually moved.  Records
    are free to carry fields this gate does not know (the obs layer adds
    top-level ``manifest``/``phases`` and ``derived.phase``): only
    ``figure``/``name``/``module_wall_ms``/``derived.*_ms`` are read, so
    old baselines without them — and new candidates with them — diff
    cleanly in both directions (asserted in ``--self-test``)."""
    regressions: List[dict] = []
    notes: List[str] = []
    for kind, old_map, new_map in (
        ("figure", _figure_walls(old), _figure_walls(new)),
        ("record", _record_times(old), _record_times(new)),
    ):
        for name in sorted(set(old_map) | set(new_map)):
            if name not in old_map or name not in new_map:
                side = "new" if name in new_map else "old"
                notes.append(f"{kind} {name!r} only in {side} file (ignored)")
                continue
            o, n = old_map[name], new_map[name]
            if o <= 0.0:
                notes.append(f"{kind} {name!r} has non-positive old time (ignored)")
                continue
            ratio = n / o
            if ratio > 1.0 + threshold:
                regressions.append(
                    {"kind": kind, "name": name, "old_ms": o, "new_ms": n,
                     "ratio": round(ratio, 3)}
                )
    regressions.sort(key=lambda r: r["ratio"], reverse=True)
    return regressions, notes


def self_test() -> int:
    """Dependency-free sanity check of the gate itself (the CI smoke step:
    ``python benchmarks/check_regression.py --self-test``).

    Exercises the compare() contract on synthetic payloads: within-threshold
    changes pass, beyond-threshold figure and record slowdowns fail, and
    added/removed figures never fail.  Returns 0 on success, 1 with a
    diagnostic on any contract violation.
    """
    def payload(**figure_times):
        records = []
        for fig, times in figure_times.items():
            wall, engine = times[0], times[1]
            derived = {} if engine is None else {"engine_ms": engine}
            if len(times) > 2:
                derived.update(times[2])  # per-phase *_ms fields
            records.append({"figure": fig, "name": f"{fig}/row",
                            "module_wall_ms": wall, "derived": derived})
        return {"schema": "bench.v1", "full": False, "records": records}

    checks = []
    ok, _ = compare(payload(f=(1000.0, 100.0)), payload(f=(1150.0, 110.0)))
    checks.append(("within-threshold passes", ok == []))
    bad, _ = compare(payload(f=(1000.0, 100.0)), payload(f=(1500.0, 100.0)))
    checks.append(("figure slowdown flagged",
                   [(r["kind"], r["name"]) for r in bad] == [("figure", "f")]))
    bad, _ = compare(payload(f=(1000.0, 100.0)), payload(f=(1000.0, 200.0)))
    checks.append(("record slowdown flagged",
                   [(r["kind"], r["name"]) for r in bad] == [("record", "f/row")]))
    ok, notes = compare(payload(f=(1000.0, None), gone=(1.0, None)),
                        payload(f=(1000.0, None), added=(9e9, None)))
    checks.append(("added/removed figures never fail",
                   ok == [] and len(notes) == 2))
    bad, _ = compare(payload(f=(1000.0, 100.0, {"table_ms": 50.0})),
                     payload(f=(1000.0, 100.0, {"table_ms": 100.0})))
    checks.append(("phase-field slowdown flagged",
                   [(r["kind"], r["name"]) for r in bad]
                   == [("record", "f/row:table_ms")]))
    ok, notes = compare(payload(f=(1000.0, 100.0)),
                        payload(f=(1000.0, 100.0, {"table_ms": 70.0})))
    checks.append(("breakdown absent from old baseline is note-only",
                   ok == [] and any("table_ms" in n for n in notes)))
    tight, _ = compare(payload(f=(1000.0, None)), payload(f=(1100.0, None)),
                       threshold=0.05)
    checks.append(("threshold configurable", len(tight) == 1))
    # A timed-out module is *missing*, not regressed: its marker record
    # must produce a one-sided note on both diff directions, never a fail.
    timeout_payload = {
        "schema": "bench.v1", "full": False,
        "records": [{"figure": "f", "name": "f/TIMEOUT",
                     "module_wall_ms": 0.0,
                     "derived": {"timeout": True, "budget_s": 60}}],
    }
    ok, notes = compare(payload(f=(1000.0, 100.0)), timeout_payload)
    checks.append(("timed-out candidate treated as missing",
                   ok == [] and len(notes) == 2))
    ok, notes = compare(timeout_payload, payload(f=(1000.0, 100.0)))
    checks.append(("timed-out baseline treated as missing",
                   ok == [] and len(notes) == 2))
    # fig22 chaos records carry warm_ms/cold_ms steady timings; the gate
    # must behave in BOTH diff directions: a slower candidate fails, a
    # faster one (or a baseline predating fig22) never does.
    def f22(warm_ms, cold_ms):
        return {
            "schema": "bench.v1", "full": False,
            "records": [{
                "figure": "fig22_fabric_chaos",
                "name": "fig22/mid-linkflap/vtrs_ssm",
                "module_wall_ms": 2000.0,
                "derived": {"warm_wins_probes": True,
                            "warm_ms": warm_ms, "cold_ms": cold_ms},
            }],
        }

    bad, _ = compare(f22(100.0, 400.0), f22(150.0, 400.0))
    checks.append(("fig22 warm_ms slowdown flagged",
                   [(r["kind"], r["name"]) for r in bad]
                   == [("record", "fig22/mid-linkflap/vtrs_ssm:warm_ms")]))
    ok, _ = compare(f22(150.0, 400.0), f22(100.0, 380.0))
    checks.append(("fig22 speedup passes", ok == []))
    ok, notes = compare(payload(f=(1000.0, 100.0)), f22(100.0, 400.0))
    checks.append(("fig22 absent from old baseline is note-only",
                   ok == [] and any("fig22" in n for n in notes)))
    ok, notes = compare(f22(100.0, 400.0), payload(f=(1000.0, 100.0)))
    checks.append(("fig22 dropped from candidate is note-only",
                   ok == [] and any("fig22" in n for n in notes)))
    # Observability fields (PR 10): records now carry top-level "manifest"
    # and "phases" keys, and timeout markers a derived "phase" string.  The
    # gate must ignore all of them — old baseline vs new candidate AND the
    # reverse (a rollback diff) — with no spurious notes, and keep comparing
    # the timings that are present.
    def obs_payload(engine_ms):
        return {
            "schema": "bench.v1", "full": False,
            "records": [{
                "figure": "f", "name": "f/row", "module_wall_ms": 1000.0,
                "manifest": ".obs/20260809-120000-bench-1.jsonl",
                "phases": {"sweep:steady": {"kind": "execute", "ms": 12.0,
                                            "count": 1}},
                "derived": {"engine_ms": engine_ms},
            }],
        }

    ok, notes = compare(payload(f=(1000.0, 100.0)), obs_payload(100.0))
    checks.append(("obs fields on new candidate ignored",
                   ok == [] and notes == []))
    ok, notes = compare(obs_payload(100.0), payload(f=(1000.0, 100.0)))
    checks.append(("obs fields on old baseline ignored",
                   ok == [] and notes == []))
    bad, _ = compare(payload(f=(1000.0, 100.0)), obs_payload(200.0))
    checks.append(("obs-annotated record still gated",
                   [(r["kind"], r["name"]) for r in bad]
                   == [("record", "f/row")]))
    obs_timeout = {
        "schema": "bench.v1", "full": False,
        "records": [{"figure": "f", "name": "f/TIMEOUT",
                     "module_wall_ms": 0.0,
                     "manifest": ".obs/x.jsonl",
                     "derived": {"timeout": True, "budget_s": 60,
                                 "phase": "sweep:warm"}}],
    }
    ok, notes = compare(payload(f=(1000.0, 100.0)), obs_timeout)
    checks.append(("phase-attributed timeout treated as missing",
                   ok == [] and len(notes) == 2))
    # Failure message ranks by magnitude: the 4x record outranks the 1.5x
    # figure even though name order would put the figure first.
    bad, _ = compare(
        payload(f=(1000.0, 100.0), g=(1000.0, 100.0)),
        payload(f=(1500.0, 100.0), g=(1000.0, 400.0)),
    )
    checks.append(("regressions sorted worst-first",
                   [round(r["ratio"], 1) for r in bad] == [4.0, 1.5]))
    prior = os.environ.get("BENCH_GATE_THRESHOLD")
    try:
        os.environ["BENCH_GATE_THRESHOLD"] = "0.5"
        checks.append(("env threshold respected", _default_threshold() == 0.5))
    finally:
        if prior is None:
            del os.environ["BENCH_GATE_THRESHOLD"]
        else:
            os.environ["BENCH_GATE_THRESHOLD"] = prior

    failed = [name for name, passed in checks if not passed]
    for name, passed in checks:
        print(f"self-test {'ok' if passed else 'FAIL'}: {name}")
    if failed:
        print(f"{len(failed)} self-test check(s) failed")
        return 1
    print("self-test OK")
    return 0


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail on >threshold per-figure BENCH regressions."
    )
    ap.add_argument("old", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("new", nargs="?", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=None,
                    help="max allowed fractional slowdown (default 0.20, or "
                         "the BENCH_GATE_THRESHOLD environment variable; an "
                         "explicit flag beats a broken environment)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the gate's built-in contract checks and exit")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    if args.threshold is None:
        args.threshold = _default_threshold()
    if args.old is None or args.new is None:
        ap.error("old and new BENCH files are required (or use --self-test)")

    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    if old.get("full") != new.get("full") or old.get("schema") != new.get("schema"):
        print(
            f"incomparable runs: old full={old.get('full')} "
            f"schema={old.get('schema')} vs new full={new.get('full')} "
            f"schema={new.get('schema')}"
        )
        return 2

    regressions, notes = compare(old, new, threshold=args.threshold)
    for note in notes:
        print(f"note: {note}")
    for r in regressions:
        print(
            f"REGRESSION [{r['kind']}] {r['name']}: "
            f"{r['old_ms']:.1f}ms -> {r['new_ms']:.1f}ms ({r['ratio']:.2f}x)"
        )
    if regressions:
        print(f"{len(regressions)} regression(s) beyond {args.threshold:.0%}")
        return 1
    print(f"OK: no regressions beyond {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
