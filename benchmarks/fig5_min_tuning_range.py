"""Fig. 5 — minimum tuning range vs sigma_rLV across DWDM configurations
(wdm8/16 x g200/400) for LtA and LtC under Natural/Permuted orderings.

Derived checks vs the paper: (a) near-linear ramp of slope ~2 before
saturation; (b) LtC saturates at its FSR; (c) N/A vs P/A (and N/N vs P/P)
indistinguishable for the ideal arbiter (§IV-A).

The sigma_rLV axis is one declarative ``SweepRequest`` (metric="min_tr")
per case — one jitted call via the sweep engine.  The config list includes
the beyond-paper WDM32 systems (N > 10 single-pass bottleneck matching)."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM_CONFIGS
from repro.core import SweepRequest, make_units, sweep

from .common import n_samples, timed_steady


CASES = (
    ("LtA-N/A", "lta", "natural"),
    ("LtA-P/A", "lta", "permuted"),
    ("LtC-N/N", "ltc", "natural"),
    ("LtC-P/P", "ltc", "permuted"),
)


def run(full: bool = False):
    n = n_samples(full)
    rows = []
    for wdm_name, base in WDM_CONFIGS.items():
        spacing = base.grid.grid_spacing
        rlvs = (np.array([0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0]) * spacing)
        for case, policy, order in CASES:
            cfg = base.with_orders(order)
            units = make_units(cfg, seed=5, n_laser=n, n_ring=n)
            req = SweepRequest(cfg=cfg, units=units, policy=policy,
                               metric="min_tr", axes={"sigma_rlv": rlvs})
            res, engine_ms = timed_steady(sweep, req)
            mt = [float(v) for v in np.asarray(res.data)]
            # ramp slope over the pre-saturation region (first 4 points)
            slope = float(np.polyfit(rlvs[:4], mt[:4], 1)[0])
            rows.append(
                (
                    f"fig5/{wdm_name}/{case}",
                    {
                        "sigma_rlv": rlvs.tolist(),
                        "min_tr": mt,
                        "ramp_slope": round(slope, 3),
                        "normalized_min_tr": [round(v / spacing, 3) for v in mt],
                        "engine_ms": round(engine_ms, 1),
                    },
                )
            )
    return rows
