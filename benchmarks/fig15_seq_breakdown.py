"""Fig. 15 — sequential-tuning CAFP broken into lock errors (zero/dup) vs
lane-order errors, under (a,b) ideal laser/ring variations and (c,d) nominal.

Paper claims: order errors dominate once TR exceeds ~FSR; significant
zero/dup lock errors below the FSR even with ideal device variations.

The TR axis is one declarative ``SweepRequest`` each; the "ideal" regime's
overrides ride along as a traced ``fixed`` ``Variations`` (no
recompilation)."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, Variations, make_units, sweep

from .common import n_samples, timed_steady, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rows = []
    for regime, overrides in (
        ("ideal", Variations(sigma_go=0.0, sigma_llv_frac=0.001,
                             sigma_fsr_frac=0.001, sigma_tr_frac=0.001)),
        ("nominal", Variations()),
    ):
        for order in ("natural", "permuted"):
            cfg = WDM8_G200.with_orders(order)
            units = make_units(cfg, seed=10, n_laser=n, n_ring=n)
            req = SweepRequest(cfg=cfg, units=units, scheme="seq",
                               axes={"tr_mean": trs}, fixed=overrides)
            r, engine_ms = timed_steady(sweep, req)
            res = r.data
            lock = [round(float(v), 4) for v in np.asarray(res.lock_err)]
            ordr = [round(float(v), 4) for v in np.asarray(res.order_err)]
            fsr_idx = int(np.argmin(np.abs(trs - cfg.grid.fsr)))
            rows.append(
                (
                    f"fig15/{regime}/{order}",
                    {
                        "tr": trs.tolist(),
                        "lock_err": lock,
                        "order_err": ordr,
                        "order_dominates_beyond_fsr": bool(
                            ordr[fsr_idx] >= lock[fsr_idx]
                        ),
                        "engine_ms": round(engine_ms, 1),
                    },
                )
            )
    return rows
