"""Fig. 15 — sequential-tuning CAFP broken into lock errors (zero/dup) vs
lane-order errors, under (a,b) ideal laser/ring variations and (c,d) nominal.

Paper claims: order errors dominate once TR exceeds ~FSR; significant
zero/dup lock errors below the FSR even with ideal device variations."""
from __future__ import annotations

import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import evaluate_scheme, make_units

from .common import n_samples, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rows = []
    for regime, overrides in (
        ("ideal", dict(sigma_go=0.0, sigma_llv_frac=0.001, sigma_fsr_frac=0.001,
                       sigma_tr_frac=0.001)),
        ("nominal", {}),
    ):
        for order in ("natural", "permuted"):
            cfg = WDM8_G200.with_orders(order)
            units = make_units(cfg, seed=10, n_laser=n, n_ring=n)
            lock, ordr = [], []
            for tr in trs:
                r = evaluate_scheme(cfg, units, "seq", float(tr), **overrides)
                lock.append(round(float(r.lock_err), 4))
                ordr.append(round(float(r.order_err), 4))
            fsr_idx = int(np.argmin(np.abs(trs - cfg.grid.fsr)))
            rows.append(
                (
                    f"fig15/{regime}/{order}",
                    {
                        "tr": trs.tolist(),
                        "lock_err": lock,
                        "order_err": ordr,
                        "order_dominates_beyond_fsr": bool(
                            ordr[fsr_idx] >= lock[fsr_idx]
                        ),
                    },
                )
            )
    return rows
