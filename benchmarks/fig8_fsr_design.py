"""Fig. 8 — FSR design guideline: minimum tuning range vs FSR mean.

Paper claims: ~±0.5 nm tolerance around the nominal N_ch*gS = 8.96 nm within
which min-TR rises < 0.5 nm; sharp increase when under-designed (resonance
aliasing), gradual when over-designed.

The FSR axis is one declarative ``SweepRequest`` (metric="min_tr") per
policy — one jitted sweep-engine call each."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, make_units, sweep

from .common import n_samples, timed_steady


def run(full: bool = False):
    n = n_samples(full)
    cfg = WDM8_G200
    units = make_units(cfg, seed=8, n_laser=n, n_ring=n)
    fsrs = np.array([6.72, 7.84, 8.46, 8.96, 9.46, 10.08, 12.32, 15.68], np.float32)
    rows = []
    for policy in ("lta", "ltc"):
        req = SweepRequest(cfg=cfg, units=units, policy=policy,
                           metric="min_tr", axes={"fsr_mean": fsrs})
        res, engine_ms = timed_steady(sweep, req)
        mt = [float(v) for v in np.asarray(res.data)]
        nominal = mt[list(fsrs).index(8.96)]
        within = [
            round(mt[i] - nominal, 3)
            for i, f in enumerate(fsrs)
            if abs(f - 8.96) <= 0.5
        ]
        rows.append(
            (
                f"fig8/{policy}",
                {
                    "fsr_mean": fsrs.tolist(),
                    "min_tr": [round(v, 3) for v in mt],
                    "delta_within_0p5nm": within,
                    "under_design_penalty": round(mt[0] - nominal, 3),
                    "over_design_penalty": round(mt[-1] - nominal, 3),
                    "engine_ms": round(engine_ms, 1),
                },
            )
        )
    return rows
