"""Fig. 14 — CAFP comparison: sequential tuning vs RS/SSM vs VT-RS/SSM,
Natural and Permuted orderings, over the (sigma_rLV x TR) shmoo.

Paper claims: proposed schemes beat the baseline everywhere; VT-RS/SSM
closely approximates ideal LtC (CAFP ~ 0); RS/SSM residual errors near
TR ~ 8 nm from the 10% tuning-range variation.

Each (order, scheme) shmoo is one declarative ``SweepRequest`` — one
jitted sweep-engine call."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, make_units, sweep

from .common import n_samples, rlv_sweep, timed_steady, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rlvs = rlv_sweep()[:6]
    axes = {"sigma_rlv": rlvs, "tr_mean": trs}
    rows = []
    for order in ("natural", "permuted"):
        cfg = WDM8_G200.with_orders(order)
        units = make_units(cfg, seed=9, n_laser=n, n_ring=n)
        for scheme in ("seq", "rs_ssm", "vtrs_ssm"):
            req = SweepRequest(cfg=cfg, units=units, scheme=scheme, axes=axes)
            res, engine_ms = timed_steady(sweep, req)
            grid = np.asarray(res.data.cafp, np.float32)
            rows.append(
                (
                    f"fig14/{order}/{scheme}",
                    {
                        "sigma_rlv": res.axis("sigma_rlv").tolist(),
                        "tr": res.axis("tr_mean").tolist(),
                        "cafp": np.round(grid, 4).tolist(),
                        "max_cafp": round(float(grid.max()), 4),
                        "mean_cafp": round(float(grid.mean()), 4),
                        "engine_ms": round(engine_ms, 1),
                    },
                )
            )
    return rows
