"""Retry-budget CAFP trade-off for the oblivious LtA family (beyond-paper,
§V-E future work) — the parametrized scheme registry end-to-end.

``seq_retry`` (sequential tuning with conflict retry) takes a static retry
budget: how many lock-order sweeps a controller is willing to spend before
declaring the link up.  Each budget is registered as its own scheme
(``seq_retry_r{1,2,4}`` plus the full-budget ``seq_retry`` and the
physical-order ``seq_retry_phys``) via ``register_scheme_family`` — static
params baked into jit-static names — so every variant gets the sweep
engine's CAFP scoring against the ideal LtA arbiter with zero bespoke code:
one declarative ``SweepRequest`` per budget.

Expected shape: CAFP falls monotonically with budget at mid TR (conflict
cascades need multiple sweeps to unwind), while r1 ~= full budget at the
extremes (low TR: nothing to retry into; high TR: first-choice locks
almost always stick)."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, make_units, scheme_spec, sweep

from .common import n_samples, timed_steady, tr_sweep

BUDGETS = ("seq_retry_r1", "seq_retry_r2", "seq_retry_r4", "seq_retry",
           "seq_retry_phys")


def run(full: bool = False):
    n = n_samples(full)
    cfg = WDM8_G200
    units = make_units(cfg, seed=17, n_laser=n, n_ring=n)
    trs = tr_sweep()
    rows = []
    curves = {}
    for scheme in BUDGETS:
        req = SweepRequest(cfg=cfg, units=units, scheme=scheme,
                           axes={"tr_mean": trs})
        res, engine_ms = timed_steady(sweep, req)
        cafp = [round(float(v), 4) for v in np.asarray(res.data.cafp)]
        curves[scheme] = cafp
        rows.append(
            (
                f"fig17/{scheme}",
                {
                    "tr": res.axis("tr_mean").tolist(),
                    "cafp_vs_ideal_lta": cafp,
                    "mean_cafp": round(float(np.mean(cafp)), 4),
                    "params": dict(scheme_spec(scheme).params),
                    "engine_ms": round(engine_ms, 1),
                },
            )
        )
    # budget monotonicity summary: mean CAFP must not degrade as the
    # constrained-first budget grows (r1 >= r2 >= r4 >= full, up to MC noise)
    means = [float(np.mean(curves[s]))
             for s in ("seq_retry_r1", "seq_retry_r2", "seq_retry_r4",
                       "seq_retry")]
    rows.append(
        (
            "fig17/summary",
            {
                "budget_order": ["r1", "r2", "r4", "full"],
                "mean_cafp_by_budget": [round(m, 4) for m in means],
                "monotone_improvement": bool(
                    all(a >= b - 1e-6 for a, b in zip(means, means[1:]))
                ),
            },
        )
    )
    return rows
