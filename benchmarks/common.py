"""Shared benchmark harness.

Each fig*.py exposes ``run(full=False) -> list[(name, derived_dict)]``;
``benchmarks.run`` times each and prints ``name,us_per_call,derived`` CSV
(the derived column carries the paper-comparable quantities).

Default sizes are CPU-friendly (24x24 = 576 Monte-Carlo trials/point);
``--full`` restores the paper's 100x100 = 10,000.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

TRIALS_FAST = 24
TRIALS_FULL = 100


def n_samples(full: bool) -> int:
    return TRIALS_FULL if full else TRIALS_FAST


def timed(fn: Callable, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def timed_steady(fn: Callable, *args, **kw):
    """(result, steady_ms): first call warms the jit cache, second is timed.

    Keeps ``engine_ms`` comparable across figures and commits in the
    BENCH_*.json trajectory — compile time is excluded everywhere.  With a
    ``repro.obs.phase`` recorder installed, the warm call is recorded as a
    ``compile`` span and the steady call as ``execute`` — the compile vs
    execute split the run manifests report — with no recorder it is two
    no-op context managers around the identical calls.
    """
    import jax

    from repro.obs.phase import span

    label = getattr(fn, "__name__", fn.__class__.__name__)
    with span(f"{label}:warm", kind="compile"):
        out = jax.block_until_ready(fn(*args, **kw))
    t0 = time.time()
    with span(f"{label}:steady", kind="execute"):
        jax.block_until_ready(fn(*args, **kw))
    return out, (time.time() - t0) * 1e3


def emit(rows: List[Tuple[str, float, Dict]]):
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{json.dumps(derived, default=float)}")


def write_json(path: str, records: List[Dict], *, full: bool) -> None:
    """Machine-readable benchmark output (seed for BENCH_*.json tracking).

    records: [{"figure": module, "name": row, "module_wall_ms": wall-time of
    the row's whole module, "derived": {...}}].  Schema version bumps on
    layout changes.
    """
    payload = {
        "schema": "bench.v1",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "full": full,
        "trials_per_point": n_samples(full) ** 2,
        "records": records,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")


def tr_sweep(n_ch: int = 8, spacing: float = 1.12) -> np.ndarray:
    """Paper default TR sweep: 0.25*gS .. FSR (Table I note 1)."""
    return np.linspace(0.25 * spacing, n_ch * spacing, 12).astype(np.float32)


def rlv_sweep(spacing: float = 1.12) -> np.ndarray:
    """sigma_rLV sweep: 0.25x .. 8x grid spacing (paper §II-C)."""
    return np.array(
        [0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0], dtype=np.float32
    ) * spacing
