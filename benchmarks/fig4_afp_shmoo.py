"""Fig. 4 — AFP shmoo over (sigma_rLV x TR) for the four policy/ordering
test cases of Table II (LtA-N/A, LtA-P/A, LtC-N/N, LtC-P/P) + LtD."""
from __future__ import annotations

import numpy as np

from repro.core import evaluate_policy, make_units
from repro.configs.wdm import WDM8_G200

from .common import n_samples, rlv_sweep, tr_sweep


CASES = (
    ("LtA-N/A", "lta", "natural"),
    ("LtA-P/A", "lta", "permuted"),
    ("LtC-N/N", "ltc", "natural"),
    ("LtC-P/P", "ltc", "permuted"),
    ("LtD-N/N", "ltd", "natural"),
)


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rlvs = rlv_sweep()
    rows = []
    for name, policy, order in CASES:
        cfg = WDM8_G200.with_orders(order)
        units = make_units(cfg, seed=4, n_laser=n, n_ring=n)
        grid = np.zeros((len(rlvs), len(trs)), np.float32)
        for i, srlv in enumerate(rlvs):
            for j, tr in enumerate(trs):
                grid[i, j] = float(
                    evaluate_policy(cfg, units, policy, float(tr), sigma_rlv=float(srlv))
                )
        # min tuning range achieving complete success, per sigma_rLV
        ok = np.abs(grid) <= 1e-6  # AFP == 0 up to fp32 roundoff of 1-mean
        min_tr = [
            float(trs[np.argmax(ok[i])]) if ok[i].any() else float("inf")
            for i in range(len(rlvs))
        ]
        grid = np.abs(grid)  # clean -0.0 roundoff for reporting
        rows.append(
            (
                f"fig4/{name}",
                {
                    "shmoo_afp": np.round(grid, 4).tolist(),
                    "sigma_rlv": rlvs.tolist(),
                    "tr": trs.tolist(),
                    "min_tr_per_sigma": min_tr,
                },
            )
        )
    return rows
