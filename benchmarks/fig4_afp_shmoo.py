"""Fig. 4 — AFP shmoo over (sigma_rLV x TR) for the four policy/ordering
test cases of Table II (LtA-N/A, LtA-P/A, LtC-N/N, LtC-P/P) + LtD.

Grids are filled by the batched sweep engine (one declarative
``SweepRequest`` -> one jitted call per case); the first case is also
evaluated two more ways to record before/after wall-time and assert
numerically identical grids (the engine's acceptance gate):

  * ``sweep_reference`` — the retired per-point dispatch loop over the
    *current* evaluators (isolates the batching win);
  * ``_seed_lta_loop`` — a faithful replica of the seed implementation
    (per-point dispatch + Kuhn augmenting-path matching, before the Hall
    fast path), i.e. the true pre-engine end-to-end baseline.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SweepRequest,
    Variations,
    make_units,
    metrics,
    sweep,
    sweep_reference,
)
from repro.core.matching import (
    _bottleneck_threshold_kuhn,
    adjacency_bitmask,
    max_matching,
)
from repro.core.reach import reach_matrix, scaled_residual
from repro.core.sampling import instantiate
from repro.configs.wdm import WDM8_G200, WDM16_G200

from .common import n_samples, rlv_sweep, tr_sweep


CASES = (
    ("LtA-N/A", "lta", "natural"),
    ("LtA-P/A", "lta", "permuted"),
    ("LtC-N/N", "ltc", "natural"),
    ("LtC-P/P", "ltc", "permuted"),
    ("LtD-N/N", "ltd", "natural"),
)


@partial(jax.jit, static_argnames=("cfg",))
def _seed_lta_point(cfg, units, tr, sigma_rlv):
    """Seed-identical LtA AFP at one grid point (Kuhn matching)."""
    sys = instantiate(cfg, units, Variations(sigma_rlv=sigma_rlv))
    match_wl, _ = max_matching(adjacency_bitmask(reach_matrix(sys, tr)))
    return metrics.afp(jnp.all(match_wl >= 0, axis=1))


def _seed_lta_loop(cfg, units, rlvs, trs):
    grid = np.zeros((len(rlvs), len(trs)), np.float32)
    for i, srlv in enumerate(rlvs):
        for j, tr in enumerate(trs):
            grid[i, j] = float(_seed_lta_point(cfg, units, float(tr), float(srlv)))
    return grid


@partial(jax.jit, static_argnames=("cfg",))
def _kuhn_engine_grid(cfg, units, rlvs, trs):
    """PR 1-style engine replica for N > 10: the same batched TR-fast-path
    sweep, but with per-trial LtA min-TRs from the Kuhn binary search
    instead of the single-pass bottleneck sweep.  The before/after baseline
    for the wdm16 row — only the matching algorithm differs."""

    def one(srlv):
        sys = instantiate(cfg, units, Variations(sigma_rlv=srlv))
        return _bottleneck_threshold_kuhn(scaled_residual(sys))

    min_tr = jax.vmap(one)(rlvs)                            # (R, T)
    ok = min_tr[:, None, :] <= trs[None, :, None]           # (R, L, T)
    return 1.0 - jnp.mean(ok.astype(jnp.float32), axis=-1)


def _best_of(fn, reps: int = 3) -> float:
    """Minimum wall-time [ms] over ``reps`` runs of an already-warm fn."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, (time.time() - t0) * 1e3)
    return best


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rlvs = rlv_sweep()
    axes = {"sigma_rlv": rlvs, "tr_mean": trs}
    rows = []
    for case_idx, (name, policy, order) in enumerate(CASES):
        cfg = WDM8_G200.with_orders(order)
        units = make_units(cfg, seed=4, n_laser=n, n_ring=n)
        req = SweepRequest(cfg=cfg, units=units, policy=policy, axes=axes)
        t0 = time.time()
        grid = np.asarray(jax.block_until_ready(sweep(req)).data)
        engine_first_ms = (time.time() - t0) * 1e3  # includes jit compile
        engine_ms = _best_of(lambda: jax.block_until_ready(sweep(req)))
        derived = {}
        if case_idx == 0:
            # Before/after evidence: per-point loop and seed replica vs
            # engine, all timed warm (compile excluded) and best-of-N so a
            # loaded machine cannot skew the committed ratio.
            ref_grid = np.asarray(
                jax.block_until_ready(sweep_reference(req)).data
            )
            loop_ms = _best_of(
                lambda: jax.block_until_ready(sweep_reference(req)),
                reps=2,
            )
            seed_grid = _seed_lta_loop(cfg, units, rlvs, trs)
            seed_ms = _best_of(lambda: _seed_lta_loop(cfg, units, rlvs, trs), reps=2)
            # Acceptance gate: a bit-exactness regression must fail the run,
            # not be silently committed as identical_to_*: false.
            if not np.array_equal(grid, ref_grid):
                raise AssertionError("fig4: engine grid != per-point loop grid")
            if not np.array_equal(grid, seed_grid):
                raise AssertionError("fig4: engine grid != seed-replica grid")
            derived.update(
                loop_ms=round(loop_ms, 1),
                seed_ms=round(seed_ms, 1),
                speedup_vs_loop=round(loop_ms / engine_ms, 2),
                speedup_vs_seed=round(seed_ms / engine_ms, 2),
                identical_to_loop=bool(np.array_equal(grid, ref_grid)),
                identical_to_seed=bool(np.array_equal(grid, seed_grid)),
            )
        # min tuning range achieving complete success, per sigma_rLV
        ok = np.abs(grid) <= 1e-6  # AFP == 0 up to fp32 roundoff of 1-mean
        min_tr = [
            float(trs[np.argmax(ok[i])]) if ok[i].any() else float("inf")
            for i in range(len(rlvs))
        ]
        grid = np.abs(grid)  # clean -0.0 roundoff for reporting
        derived.update(
            shmoo_afp=np.round(grid, 4).tolist(),
            sigma_rlv=rlvs.tolist(),
            tr=trs.tolist(),
            min_tr_per_sigma=min_tr,
            engine_ms=round(engine_ms, 1),
            engine_first_ms=round(engine_first_ms, 1),
        )
        rows.append((f"fig4/{name}", derived))

    # wdm16 scale-out row: the same sigma_rLV x TR shmoo at N=16, where the
    # engine's bottleneck thresholds come from the single-pass sweep.  The
    # PR 1 path (identical engine, Kuhn binary-search thresholds) is timed
    # as the before-baseline; grids must be bit-identical to each other and
    # to the per-point reference loop.
    cfg16 = WDM16_G200
    trs16 = tr_sweep(n_ch=16)
    units16 = make_units(cfg16, seed=4, n_laser=n, n_ring=n)
    req16 = SweepRequest(cfg=cfg16, units=units16, policy="lta",
                         axes={"sigma_rlv": rlvs, "tr_mean": trs16})
    grid16 = np.asarray(jax.block_until_ready(sweep(req16)).data)
    engine16_ms = _best_of(lambda: jax.block_until_ready(sweep(req16)))
    jrlvs, jtrs = jnp.asarray(rlvs), jnp.asarray(trs16)
    kuhn_grid = np.asarray(
        jax.block_until_ready(_kuhn_engine_grid(cfg16, units16, jrlvs, jtrs))
    )
    kuhn_ms = _best_of(
        lambda: jax.block_until_ready(_kuhn_engine_grid(cfg16, units16, jrlvs, jtrs)),
        reps=2,
    )
    ref16 = np.asarray(sweep_reference(req16).data)
    if not np.array_equal(grid16, ref16):
        raise AssertionError("fig4/LtA-16: engine grid != per-point loop grid")
    if not np.array_equal(grid16, kuhn_grid):
        raise AssertionError("fig4/LtA-16: engine grid != Kuhn binary-search grid")
    rows.append(
        ("fig4/LtA-16",
         {"shmoo_afp": np.round(np.abs(grid16), 4).tolist(),
          "sigma_rlv": rlvs.tolist(),
          "tr": trs16.tolist(),
          "engine_ms": round(engine16_ms, 1),
          "kuhn_ms": round(kuhn_ms, 1),
          "speedup_vs_kuhn": round(kuhn_ms / engine16_ms, 2),
          "identical_to_loop": True,
          "identical_to_kuhn": True})
    )
    return rows
