"""Fig. 6 — Lock-to-Deterministic minimum tuning range vs grid offset.

Paper claims: slope ~1 in sigma_rLV for small offsets; sigma_gO >= 4 nm
drives the requirement beyond the FSR (impractical)."""
from __future__ import annotations

import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import make_units, policy_min_tr

from .common import n_samples


def run(full: bool = False):
    n = n_samples(full)
    cfg = WDM8_G200
    units = make_units(cfg, seed=6, n_laser=n, n_ring=n)
    rlvs = np.array([0.28, 0.56, 1.12, 2.24, 3.36], np.float32)
    rows = []
    for sgo in (0.0, 2.0, 4.0, 6.0):
        mt = [
            float(
                policy_min_tr(
                    cfg, units, "ltd", sigma_rlv=float(s), sigma_go=float(sgo)
                )
            )
            for s in rlvs
        ]
        slope = float(np.polyfit(rlvs[:4], mt[:4], 1)[0])
        rows.append(
            (
                f"fig6/ltd_sgo_{sgo:g}nm",
                {
                    "sigma_rlv": rlvs.tolist(),
                    "min_tr": [round(v, 3) for v in mt],
                    "ramp_slope": round(slope, 3),
                    "exceeds_fsr": bool(max(mt) > cfg.grid.fsr),
                },
            )
        )
    return rows
