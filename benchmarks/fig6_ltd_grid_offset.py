"""Fig. 6 — Lock-to-Deterministic minimum tuning range vs grid offset.

Paper claims: slope ~1 in sigma_rLV for small offsets; sigma_gO >= 4 nm
drives the requirement beyond the FSR (impractical).

The whole (sigma_gO x sigma_rLV) grid is one declarative ``SweepRequest``
(metric="min_tr") — one jitted sweep-engine call."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, make_units, sweep

from .common import n_samples, timed_steady


def run(full: bool = False):
    n = n_samples(full)
    cfg = WDM8_G200
    units = make_units(cfg, seed=6, n_laser=n, n_ring=n)
    rlvs = np.array([0.28, 0.56, 1.12, 2.24, 3.36], np.float32)
    sgos = np.array([0.0, 2.0, 4.0, 6.0], np.float32)
    req = SweepRequest(cfg=cfg, units=units, policy="ltd", metric="min_tr",
                       axes={"sigma_go": sgos, "sigma_rlv": rlvs})
    res, engine_ms = timed_steady(sweep, req)
    grid = np.asarray(res.data)
    rows = []
    for gi, sgo in enumerate(sgos):
        mt = [float(v) for v in grid[gi]]
        slope = float(np.polyfit(rlvs[:4], mt[:4], 1)[0])
        rows.append(
            (
                f"fig6/ltd_sgo_{sgo:g}nm",
                {
                    "sigma_rlv": rlvs.tolist(),
                    "min_tr": [round(v, 3) for v in mt],
                    "ramp_slope": round(slope, 3),
                    "exceeds_fsr": bool(max(mt) > cfg.grid.fsr),
                    "engine_ms": round(engine_ms, 1),
                },
            )
        )
    return rows
