"""Fig. 21 (beyond paper) — fabric-scale yield: independent vs coupled links.

The ROADMAP's flagship open item: bring up a >= 1k-link DWDM fabric (8 pods,
28 bundles x 36 links = 1008 links, 2016 transceivers) in ONE sharded sweep
through the engine, for the protocol-family comparison at fabric scale —
per-link oblivious LtA with retries (``seq_retry``), the paper's best
one-shot scheme (``vtrs_ssm``), and the multi-hop augmenting protocol
(``protocol_lta``) — under the network-level wavelength-assignment
constraints of ``repro.fabric``:

  * ``comb_coupling = 0``: per-link-independent yield, asserted
    BIT-IDENTICAL to arbitrating each link separately through the core
    path (the fabric layer's parity contract);
  * ``comb_coupling = 1``: bundle-shared comb sources — correlated laser
    draws degrade whole bundles together, which is what separates fabric
    yield from the iid extrapolation of per-link AFP;
  * 2-hop ring routes scoring wavelength continuity (``route_cont``).

Memory: a fabric grid point is a 2*link_chunk-trial scheme evaluation; the
audit fields assert the whole 2016-trial point sits inside the engine's
256 MB chunk budget (at 100k links the link axis chunks internally and the
budget still holds per chunk).

``--full`` widens the TR axis and adds the half-coupled point; the fabric
stays 1008 links in both modes (the figure's point is the scale).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.fabric import FABRIC_1K, FABRIC_TINY
from repro.configs.wdm import WDM8_G200, WDM16_G200
from repro.core import SweepRequest, sweep
from repro.core.api import oblivious_arbitrate
from repro.core.sampling import SystemBatch, UnitSamples, instantiate
from repro.core.sweep import _CHUNK_BUDGET, scheme_point_bytes
from repro.core.variations import as_variations
from repro.fabric import auto_link_chunk, bringup, make_fabric_units
from repro.launch.mesh import make_sweep_mesh

from .common import timed_steady

SCHEMES = ("seq_retry", "vtrs_ssm", "protocol_lta")


def _assert_parity(cfg, spec, tr: float, scheme: str, seed: int) -> int:
    """Constraints-off fabric bring-up == independent per-link arbitration,
    bit for bit (the acceptance gate).  Oracle: vmapped core instantiate
    (L=1, R=2 per link) -> one flat oblivious_arbitrate.  Returns n_links
    checked."""
    res = bringup(cfg, spec, tr_mean=tr, scheme=scheme, seed=seed)
    units = make_fabric_units(cfg, spec, seed=seed)
    k, n = spec.n_links, cfg.grid.n_ch
    su = UnitSamples(
        u_go=units.go[:, None, None], u_llv=units.llv[:, None, :],
        u_rlv=units.rlv, u_fsr=units.fsr, u_tr=units.tr,
    )
    var = as_variations({})

    @jax.jit
    def ref(su):
        sysb = jax.vmap(lambda u: instantiate(cfg, u, var))(su)
        flat = SystemBatch(*[a.reshape(2 * k, n) for a in sysb])
        return oblivious_arbitrate(cfg, flat, tr, scheme)

    asg = ref(su)
    assert np.array_equal(
        np.asarray(asg.wl).reshape(k, 2, n), np.asarray(res.ev.wl)
    ), f"constraints-off parity broken for {scheme}"
    return k


def run(full: bool = False):
    cfg = WDM16_G200
    spec = FABRIC_1K
    units = make_fabric_units(cfg, spec, seed=33)
    mesh = make_sweep_mesh()

    trs = (np.array([0.40, 0.46], np.float32) if not full else
           np.array([0.37, 0.40, 0.43, 0.46], np.float32)) * cfg.grid.fsr
    coupling = (np.array([0.0, 1.0], np.float32) if not full else
                np.array([0.0, 0.5, 1.0], np.float32))
    axes = {"comb_coupling": coupling, "tr_mean": trs}

    n_trials = 2 * spec.n_links
    link_chunk = auto_link_chunk(cfg, spec.n_links)
    point_bytes = scheme_point_bytes(cfg, 2 * link_chunk)
    assert spec.n_links >= 1000, spec.n_links
    assert point_bytes <= _CHUNK_BUDGET, (
        f"fabric point {point_bytes} B exceeds the chunk budget"
    )

    # the acceptance parity gate, on the full 1008-link fabric
    parity_links = _assert_parity(cfg, spec, float(trs[0]), "vtrs_ssm", 33)

    rows = []
    for scheme in SCHEMES:
        req = SweepRequest(cfg=cfg, units=units, scheme=scheme, fabric=spec,
                           axes=axes, mesh=mesh)
        res, engine_ms = timed_steady(sweep, req)
        link_up = np.asarray(res.data.link_up, np.float32)
        cafp = np.asarray(res.data.cafp, np.float32)
        rows.append((
            f"fig21/wdm16-1k/{scheme}",
            {
                "n_links": int(spec.n_links),
                "trials_per_point": int(n_trials),
                "link_chunk": int(link_chunk),
                "point_bytes": int(point_bytes),
                "chunk_budget": int(_CHUNK_BUDGET),
                "fits_budget": bool(point_bytes <= _CHUNK_BUDGET),
                "parity_links": int(parity_links),
                "coupling": coupling.tolist(),
                "tr": trs.tolist(),
                "link_up": np.round(link_up, 4).tolist(),
                "cafp": np.round(cafp, 4).tolist(),
                "matched": np.round(
                    np.asarray(res.data.matched, np.float32), 4).tolist(),
                "route_up": np.round(
                    np.asarray(res.data.route_up, np.float32), 4).tolist(),
                "route_cont": np.round(
                    np.asarray(res.data.route_cont, np.float32), 4).tolist(),
                "bandwidth": np.round(
                    np.asarray(res.data.bandwidth, np.float32), 4).tolist(),
                "independent_link_up": round(float(link_up[0].max()), 4),
                "coupled_link_up": round(float(link_up[-1].max()), 4),
                "engine_ms": round(engine_ms, 1),
            },
        ))
    return rows


def smoke() -> dict:
    """Tiny-fabric CI smoke (``make ci``): the whole fig21 path — fabric
    sweep for all three schemes, constraints-off parity, route metrics —
    on the 6-link WDM8 tiny fabric."""
    cfg = WDM8_G200
    spec = FABRIC_TINY
    units = make_fabric_units(cfg, spec, seed=33)
    _assert_parity(cfg, spec, 4.8, "vtrs_ssm", 33)
    out = {}
    for scheme in SCHEMES:
        res = sweep(SweepRequest(
            cfg=cfg, units=units, scheme=scheme, fabric=spec,
            axes={"comb_coupling": [0.0, 1.0], "tr_mean": [4.4, 4.8]},
        ))
        link_up = np.asarray(res.data.link_up, np.float32)
        route_cont = np.asarray(res.data.route_cont, np.float32)
        assert link_up.shape == (2, 2), link_up.shape
        assert np.all((link_up >= 0) & (link_up <= 1))
        assert np.all((route_cont >= 0) & (route_cont <= 1))
        out[scheme] = {"link_up": np.round(link_up, 4).tolist()}
    print(f"fig21 smoke OK: {out}")
    return out


if __name__ == "__main__":
    smoke()
