"""Fig. 22 (beyond paper) — fabric chaos: correlated faults + warm re-lock.

The temporal x fabric composition: every scenario drives
``run_fabric_timeline`` twice over the same fabric-scoped fault timeline
(``configs.fabric.CHAOS_SCENARIOS`` — link kill-and-heal, comb-source
outage with fallback rerouting, correlated pod heating, endpoint ring
death) on the 48-link WDM16 mid fabric: warm (per-link protocol state
carried through the scan, disturbed links re-lock, undisturbed links spend
nothing) and cold (every link re-arbitrated from scratch each step).

Acceptance gates, asserted on every run:

  * **no-fault parity** — a zero-drift, zero-event timeline reproduces the
    single-shot ``fabric.bringup`` bit for bit at step 0 and spends zero
    probes afterwards;
  * **feasible-masked warm-vs-cold** — on (step, link) pairs where the
    live bus still admits a complete matching, warm re-lock uses fewer
    mean probes per step than cold and never ends with fewer locked
    lanes;
  * **heal recovery** — on kill-and-heal scenarios, post-heal fabric
    bandwidth returns to the pre-fault value;
  * **scale budget** — the 1008-link WDM16 fabric's link chunk sits inside
    the engine's 256 MB budget (``--full`` additionally scans a 3-step
    flap timeline across all 1008 links, mesh-sharded).

``--full`` also runs every scheme on every scenario (default: all schemes
on the kill-and-heal scenario, the paper's best one-shot scheme on the
rest).
"""
from __future__ import annotations

import numpy as np

from repro.configs.fabric import FABRIC_1K, chaos_timeline
from repro.core.sweep import _CHUNK_BUDGET, scheme_point_bytes
from repro.fabric import (
    auto_link_chunk,
    bringup,
    make_fabric_timeline,
    make_fabric_units,
    run_fabric_timeline,
)
from repro.launch.mesh import make_sweep_mesh

from .common import timed_steady

SCHEMES = ("seq_retry", "vtrs_ssm", "protocol_lta")
#: every CHAOS_SCENARIOS entry on the WDM16 mid fabric
SCENARIOS = ("mid-linkflap", "mid-combout", "mid-podheat", "mid-ringdeath")
#: scenarios whose events kill and later heal (the bandwidth-recovery gate)
HEAL_SCENARIOS = ("mid-linkflap", "mid-combout")


def _means(a) -> list:
    """(S, K) per-link stat -> per-step link means, rounded."""
    return [round(float(v), 2) for v in np.asarray(a, np.float32).mean(axis=1)]


def _steps(a) -> list:
    return [round(float(v), 4) for v in np.asarray(a, np.float32)]


def _assert_parity(name: str, scheme: str, seed: int) -> int:
    """No-fault parity gate: a zero-drift, zero-event timeline on the
    scenario's fabric reproduces single-shot bring-up bit for bit at step 0
    (records AND aggregate stats) and spends nothing afterwards.  Returns
    the number of links checked.  The quiet timeline copies the scenario's
    step count so the scan compiles once and the warm scenario run reuses
    it."""
    cfg, spec, tl0 = chaos_timeline(name)
    units = make_fabric_units(cfg, spec, seed)
    tl = make_fabric_timeline(spec, tl0.n_steps, cfg.grid.n_ch)
    _, cs = run_fabric_timeline(cfg, units, spec, tl, scheme=scheme)
    ref = bringup(cfg, spec, scheme=scheme, seed=seed)
    assert np.array_equal(np.asarray(cs.wl[0]), np.asarray(ref.ev.wl)), (
        f"no-fault parity broken for {scheme} on {name}"
    )
    for field in cs.fabric._fields:
        assert np.array_equal(
            np.asarray(getattr(cs.fabric, field)[0]),
            np.asarray(getattr(ref.stats, field)),
        ), f"no-fault stats parity broken: {field}"
    assert np.asarray(cs.probes[1:]).sum() == 0, "quiet steps spent probes"
    return spec.n_links


def _run_pair(name: str, scheme: str, seed: int = 33):
    """Warm and cold chaos scans for one scenario; (row dict, gates)."""
    cfg, spec, tl = chaos_timeline(name)
    units = make_fabric_units(cfg, spec, seed)
    (_, warm), warm_ms = timed_steady(
        run_fabric_timeline, cfg, units, spec, tl, scheme=scheme, warm=True
    )
    (_, cold), cold_ms = timed_steady(
        run_fabric_timeline, cfg, units, spec, tl, scheme=scheme, warm=False
    )
    # Feasibility is a property of the live drifted bus, not the mode.
    feas = np.asarray(warm.feasible, bool)
    mask = feas[1:]                       # step 0 is shared bring-up
    wp = np.asarray(warm.probes, np.float32)[1:]
    cp = np.asarray(cold.probes, np.float32)[1:]
    if mask.any():
        warm_probes = float(wp[mask].mean())
        cold_probes = float(cp[mask].mean())
    else:  # degenerate scenario: nothing feasible to compare
        warm_probes = cold_probes = 0.0
    locked_ok = bool(
        np.asarray(warm.locked[-1]).sum() >= np.asarray(cold.locked[-1]).sum()
    )
    # Recovery = the final (post-heal) bandwidth is no worse than the
    # pre-fault value.  >= rather than ==: warm repair also heals whatever
    # the one-shot bring-up itself left degraded (seq_retry's noisy
    # bring-up ends ABOVE its step-0 bandwidth).
    bw = np.asarray(warm.fabric.bandwidth, np.float32)
    healed = bool(float(bw[-1]) >= float(bw[0]) - 1e-6)
    derived = {
        "n_links": int(spec.n_links),
        "steps": int(feas.shape[0]),
        "feasible_frac": _means(feas),
        "warm_probes": _means(warm.probes),
        "cold_probes": _means(cold.probes),
        "warm_broken": _means(warm.broken),
        "warm_churn": _means(warm.churn),
        "warm_locked": _means(warm.locked),
        "cold_locked": _means(cold.locked),
        "bandwidth": _steps(bw),
        "route_up": _steps(warm.fabric.route_up),
        "route_served": _steps(warm.fabric.route_served),
        "route_bandwidth": _steps(warm.fabric.route_bandwidth),
        "matched": _steps(warm.fabric.matched),
        "feasible_warm_probes": round(warm_probes, 2),
        "feasible_cold_probes": round(cold_probes, 2),
        "warm_wins_probes": bool(warm_probes < cold_probes),
        "warm_locked_ge_cold": locked_ok,
        "bandwidth_recovered": healed,
        "warm_ms": round(warm_ms, 1),
        "cold_ms": round(cold_ms, 1),
    }
    gates = (derived["warm_wins_probes"], locked_ok,
             healed or name not in HEAL_SCENARIOS)
    return derived, gates


def run(full: bool = False):
    rows = []

    # --- no-fault parity gate --------------------------------------------
    parity_links = _assert_parity("mid-linkflap", "vtrs_ssm", 33)
    rows.append((
        "fig22/parity",
        {"parity_links": int(parity_links), "quiet_steps": 6,
         "bit_identical": True},
    ))

    # --- scenario x scheme chaos matrix ----------------------------------
    gate_bits = []
    for name in SCENARIOS:
        schemes = (SCHEMES if full or name == "mid-linkflap"
                   else ("vtrs_ssm",))
        for scheme in schemes:
            derived, gates = _run_pair(name, scheme)
            gate_bits.append(gates)
            assert gates[0], f"warm lost on probes: {name}/{scheme}"
            assert gates[1], f"warm locked < cold: {name}/{scheme}"
            assert gates[2], f"bandwidth did not recover: {name}/{scheme}"
            rows.append((f"fig22/{name}/{scheme}", derived))

    # --- 1008-link scale budget (the fabric chunking contract) -----------
    from repro.configs.wdm import WDM16_G200 as cfg1k

    spec1k = FABRIC_1K
    link_chunk = auto_link_chunk(cfg1k, spec1k.n_links)
    point_bytes = scheme_point_bytes(cfg1k, 2 * link_chunk)
    assert spec1k.n_links >= 1000, spec1k.n_links
    assert point_bytes <= _CHUNK_BUDGET, (
        f"1k-link chaos chunk {point_bytes} B exceeds the budget"
    )
    scale = {
        "n_links": int(spec1k.n_links),
        "link_chunk": int(link_chunk),
        "point_bytes": int(point_bytes),
        "chunk_budget": int(_CHUNK_BUDGET),
        "fits_budget": True,
    }
    if full:
        units = make_fabric_units(cfg1k, spec1k, seed=33)
        tl = make_fabric_timeline(
            spec1k, 3, cfg1k.grid.n_ch,
            thermal=0.2 * cfg1k.grid.grid_spacing,
            events=((1, "link_flap", 100, 1),),
        )
        (_, cs), ms = timed_steady(
            run_fabric_timeline, cfg1k, units, spec1k, tl,
            scheme="vtrs_ssm", mesh=make_sweep_mesh(),
        )
        scale["bandwidth"] = _steps(cs.fabric.bandwidth)
        scale["mean_probes"] = _means(cs.probes)
        scale["engine_ms"] = round(ms, 1)
    rows.append(("fig22/wdm16-1k/budget", scale))

    rows.append((
        "fig22/summary",
        {
            "scenarios": len(SCENARIOS),
            "runs": len(gate_bits),
            "warm_wins_probes_all": bool(all(g[0] for g in gate_bits)),
            "warm_locked_ge_cold_all": bool(all(g[1] for g in gate_bits)),
            "bandwidth_recovered_all": bool(all(g[2] for g in gate_bits)),
        },
    ))
    return rows


def smoke() -> dict:
    """Tiny-fabric CI smoke (``make ci``): the whole fig22 path — no-fault
    parity, a kill-and-heal chaos scan warm and cold, the feasible-masked
    gates — on the 6-link WDM8 tiny fabric."""
    _assert_parity("tiny-flap", "vtrs_ssm", 0)
    derived, gates = _run_pair("tiny-flap", "vtrs_ssm", seed=0)
    assert all(gates), derived
    out = {
        "warm_probes": derived["warm_probes"],
        "cold_probes": derived["cold_probes"],
        "bandwidth": derived["bandwidth"],
        "bandwidth_recovered": derived["bandwidth_recovered"],
    }
    print(f"fig22 smoke OK: {out}")
    return out


if __name__ == "__main__":
    smoke()
