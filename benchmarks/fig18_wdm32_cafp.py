"""Fig. 18 (beyond paper) — paper-scale WDM32 CAFP grid.

The ROADMAP's open wdm32 study: a CAFP shmoo of the paper's best oblivious
scheme (VT-RS/SSM) on the 32-channel configs at the paper's full Monte
Carlo size (100x100 = 10,000 trials per point).  This workload was
impossible before the streaming top-E table build: one scheme point's
dense (T, N, N*J) candidate tensor was ~2.5 GB against the sweep engine's
256 MB chunk budget, while the streaming build keeps the whole point
(persistent (T, N, E) tables + bounded merge transient) inside it — the
audit fields below record the estimate the engine actually budgets with.

Trials are paper-scale in *both* modes (that is the figure's point);
``--full`` only widens the sigma_rLV x TR grid.
"""
from __future__ import annotations

import numpy as np

from repro.configs.wdm import WDM32_G200
from repro.core import SweepRequest, make_units, sweep
from repro.core.sweep import _CHUNK_BUDGET, _auto_chunk, scheme_point_bytes

from .common import timed_steady

TRIALS = 100  # paper-scale Monte Carlo (100x100) in every mode
SCHEME = "vtrs_ssm"


def run(full: bool = False):
    cfg = WDM32_G200
    units = make_units(cfg, seed=21, n_laser=TRIALS, n_ring=TRIALS)
    spacing = cfg.grid.grid_spacing
    # TR around the interesting shoulder (fractions of the 32-ch FSR), a
    # small grid by default — every point is a 10,000-trial evaluation
    # whose table build alone streams ~5.4M candidate peaks.
    trs = (np.array([0.25, 0.28], np.float32) if not full else
           np.array([0.22, 0.25, 0.28, 0.31], np.float32)) * cfg.grid.fsr
    rlvs = (np.array([2.0], np.float32) if not full else
            np.array([1.0, 2.0], np.float32)) * spacing
    axes = {"sigma_rlv": rlvs, "tr_mean": trs}

    n_trials = TRIALS * TRIALS
    per_point = scheme_point_bytes(cfg, n_trials)
    n_points = len(rlvs) * len(trs)
    chunk = _auto_chunk(cfg, units, n_points, SCHEME)
    assert per_point <= _CHUNK_BUDGET, (
        f"WDM32 scheme point {per_point} B exceeds the chunk budget"
    )

    req = SweepRequest(cfg=cfg, units=units, scheme=SCHEME, axes=axes)
    res, engine_ms = timed_steady(sweep, req)
    cafp = np.asarray(res.data.cafp, np.float32)
    afp = np.asarray(res.data.afp, np.float32)
    return [
        (
            f"fig18/wdm32-g200/{SCHEME}",
            {
                "trials_per_point": n_trials,
                "point_bytes": int(per_point),
                "chunk_budget": int(_CHUNK_BUDGET),
                "fits_budget": bool(per_point <= _CHUNK_BUDGET),
                "auto_chunk": int(chunk),
                "sigma_rlv": res.axis("sigma_rlv").tolist(),
                "tr": res.axis("tr_mean").tolist(),
                "cafp": np.round(cafp, 4).tolist(),
                "afp": np.round(afp, 4).tolist(),
                "max_cafp": round(float(cafp.max()), 4),
                "mean_cafp": round(float(cafp.mean()), 4),
                "engine_ms": round(engine_ms, 1),
            },
        )
    ]
