"""Fig. 18 (beyond paper) — paper-scale WDM32 CAFP grid.

The ROADMAP's open wdm32 study: a CAFP shmoo of the paper's best oblivious
scheme (VT-RS/SSM) on the 32-channel configs at the paper's full Monte
Carlo size (100x100 = 10,000 trials per point).  This workload was
impossible before the streaming top-E table build: one scheme point's
dense (T, N, N*J) candidate tensor was ~2.5 GB against the sweep engine's
256 MB chunk budget, while the streaming build keeps the whole point
(persistent (T, N, E) tables + bounded merge transient) inside it — the
audit fields below record the estimate the engine actually budgets with.

Trials are paper-scale in *both* modes (that is the figure's point);
``--full`` only widens the sigma_rLV x TR grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.wdm import WDM32_G200
from repro.core import SweepRequest, Variations, make_units, sweep
from repro.core.sweep import _CHUNK_BUDGET, _auto_chunk, scheme_point_bytes

from .common import timed_steady

TRIALS = 100  # paper-scale Monte Carlo (100x100) in every mode
SCHEME = "vtrs_ssm"


def _phase_breakdown(cfg, units, rlv: float, tr: float) -> dict:
    """Steady-state per-phase times (ms) at one representative grid point.

    The sweep's ``engine_ms`` is the trajectory headline; this attributes it
    (table build vs arbitration vs CAFP scoring) so a regression in one
    phase can't hide behind an improvement in another.  Uses the same
    warm-then-time discipline as ``timed_steady``.
    """
    from repro.core.api import _build_tables, _ideal_success, scheme_spec
    from repro.core.outcomes import classify
    from repro.core.relation import chain_spec
    from repro.core.sampling import instantiate
    from repro.obs.phase import span

    over = Variations(tr_mean=float(tr), sigma_rlv=float(rlv))
    sys = jax.block_until_ready(
        jax.jit(instantiate, static_argnums=0)(cfg, units, over)
    )
    spec = chain_spec(cfg.s)
    sspec = scheme_spec(SCHEME)

    # Named obs spans around each phase: a --timeout wedge inside this
    # breakdown is attributed "table"/"arbitrate"/"score" in the marker
    # record (benchmarks/run.py), not just to the module.
    tab_fn = jax.jit(lambda s: _build_tables(cfg, s, float(tr), None))
    with span("table"):
        tables, table_ms = timed_steady(tab_fn, sys)
    arb_fn = jax.jit(lambda t: sspec.arbiter(cfg, t, spec, backend=None))
    with span("arbitrate"):
        assign, arbitrate_ms = timed_steady(arb_fn, tables)
    score_fn = jax.jit(lambda s, a: (
        _ideal_success(cfg, s, sspec.policy, float(tr), None),
        classify(a, jnp.asarray(cfg.s), policy=sspec.policy),
    ))
    with span("score"):
        _, score_ms = timed_steady(score_fn, sys, assign)
    return {
        "table_ms": round(table_ms, 1),
        "arbitrate_ms": round(arbitrate_ms, 1),
        "score_ms": round(score_ms, 1),
    }


def run(full: bool = False):
    cfg = WDM32_G200
    units = make_units(cfg, seed=21, n_laser=TRIALS, n_ring=TRIALS)
    spacing = cfg.grid.grid_spacing
    # TR around the interesting shoulder (fractions of the 32-ch FSR), a
    # small grid by default — every point is a 10,000-trial evaluation
    # whose table build alone streams ~5.4M candidate peaks.
    trs = (np.array([0.25, 0.28], np.float32) if not full else
           np.array([0.22, 0.25, 0.28, 0.31], np.float32)) * cfg.grid.fsr
    rlvs = (np.array([2.0], np.float32) if not full else
            np.array([1.0, 2.0], np.float32)) * spacing
    axes = {"sigma_rlv": rlvs, "tr_mean": trs}

    n_trials = TRIALS * TRIALS
    per_point = scheme_point_bytes(cfg, n_trials)
    n_points = len(rlvs) * len(trs)
    chunk = _auto_chunk(cfg, units, n_points, SCHEME)
    assert per_point <= _CHUNK_BUDGET, (
        f"WDM32 scheme point {per_point} B exceeds the chunk budget"
    )

    req = SweepRequest(cfg=cfg, units=units, scheme=SCHEME, axes=axes)
    res, engine_ms = timed_steady(sweep, req)
    cafp = np.asarray(res.data.cafp, np.float32)
    afp = np.asarray(res.data.afp, np.float32)
    phases = _phase_breakdown(cfg, units, float(rlvs[0]), float(trs[0]))
    return [
        (
            f"fig18/wdm32-g200/{SCHEME}",
            {
                "trials_per_point": n_trials,
                "point_bytes": int(per_point),
                "chunk_budget": int(_CHUNK_BUDGET),
                "fits_budget": bool(per_point <= _CHUNK_BUDGET),
                "auto_chunk": int(chunk),
                "sigma_rlv": res.axis("sigma_rlv").tolist(),
                "tr": res.axis("tr_mean").tolist(),
                "cafp": np.round(cafp, 4).tolist(),
                "afp": np.round(afp, 4).tolist(),
                "max_cafp": round(float(cafp.max()), 4),
                "mean_cafp": round(float(cafp.mean()), 4),
                "engine_ms": round(engine_ms, 1),
                **phases,
            },
        )
    ]


def smoke(trials: int = 12) -> dict:
    """Tiny-grid CI smoke (``make ci``): the paper-scale fig18 *path* —
    WDM32 streaming tables through the sweep engine plus the per-phase
    breakdown — on a 2x2 grid at low trials, so a regression that only
    bites this entry point cannot land silently.  Returns the derived dict
    it printed (for ad-hoc inspection)."""
    cfg = WDM32_G200
    units = make_units(cfg, seed=21, n_laser=trials, n_ring=trials)
    trs = np.array([0.25, 0.28], np.float32) * cfg.grid.fsr
    rlvs = np.array([1.0, 2.0], np.float32) * cfg.grid.grid_spacing
    req = SweepRequest(
        cfg=cfg, units=units, scheme=SCHEME,
        axes={"sigma_rlv": rlvs, "tr_mean": trs},
    )
    res = sweep(req)
    cafp = np.asarray(res.data.cafp, np.float32)
    assert cafp.shape == (2, 2), cafp.shape
    assert np.all((cafp >= 0.0) & (cafp <= 1.0)), cafp
    phases = _phase_breakdown(cfg, units, float(rlvs[0]), float(trs[0]))
    out = {"cafp": np.round(cafp, 4).tolist(), **phases}
    print(f"fig18 smoke OK: {out}")
    return out


if __name__ == "__main__":
    smoke()
