"""Fig. 19 (beyond paper) — multi-hop augmenting LtA via the protocol engine.

The LtA analogue of the paper's LtC headline: a wavelength-oblivious
arbiter whose CAFP against the *ideal* (perfect-matching) LtA arbiter is
driven to ~0 across the whole TR sweep.  ``seq_retry`` (depth-1 retry,
``benchmarks/beyond_lta``) leaves residual mid-TR CAFP; the protocol
engine's multi-hop displacement chains (``repro.core.protocol``) close it.

Three studies, every sweep one declarative ``SweepRequest``:

  * WDM8 scheme comparison — seq_retry vs protocol_lta and its chain-depth
    family; the acceptance record pins protocol_lta's worst CAFP at the TR
    points where seq_retry still fails (``near_ideal`` <= 1e-3).
  * probe-budget/CAFP trade-off — chain depth sweeps the probe budget; the
    per-trial probe counts come from ``run_protocol(..., with_stats=True)``.
  * WDM16 — the same protocol at double scale (the engine's per-round cost
    is O(1) jaxpr in N; chunk_size=1 keeps each TR point's round loop
    independently early-exiting).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.wdm import WDM8_G200, WDM16_G200
from repro.core import SweepRequest, ideal, make_units, sweep
from repro.core.outcomes import classify
from repro.core.protocol import run_protocol
from repro.core.relation import chain_spec
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables
from repro.core.variations import Variations

from .common import n_samples, timed_steady, tr_sweep

SCHEMES8 = ("seq_retry", "protocol_lta_h1", "protocol_lta_h2",
            "protocol_lta_h4", "protocol_lta")
#: chain-depth ladder of the trade-off study (None = full multi-hop)
DEPTHS = (1, 2, 4, None)


@functools.partial(jax.jit, static_argnames=("cfg", "depth"))
def _protocol_point(cfg, units, tr_mean, depth):
    """(cafp, mean probes, mean rounds) of the protocol arbiter at one TR."""
    sys = instantiate(cfg, units, Variations())
    tables = build_search_tables(sys, tr_mean, max_alias=cfg.max_fsr_alias)
    assign, stats = run_protocol(
        tables, chain_spec(cfg.s), depth=depth, with_stats=True
    )
    out = classify(assign, jnp.asarray(cfg.s), policy="lta")
    ok = ideal.success(sys, "lta", jnp.asarray(cfg.s), tr_mean)
    cafp = jnp.mean((~out.success & ok).astype(jnp.float32))
    return cafp, jnp.mean(stats.probes.astype(jnp.float32)), jnp.mean(
        stats.rounds.astype(jnp.float32)
    )


def run(full: bool = False):
    n = n_samples(full)
    rows = []

    # --- WDM8: scheme comparison over the paper's TR sweep ----------------
    cfg = WDM8_G200
    units = make_units(cfg, seed=21, n_laser=n, n_ring=n)
    trs = tr_sweep()
    curves = {}
    for scheme in SCHEMES8:
        # chunk_size=1: each TR point gets its own protocol round loop, so
        # converged points exit early instead of paying the worst point's
        # round count (a vmapped while_loop runs to the slowest lane).
        req = SweepRequest(cfg=cfg, units=units, scheme=scheme,
                           axes={"tr_mean": trs}, chunk_size=1)
        res, engine_ms = timed_steady(sweep, req)
        cafp = np.asarray(res.data.cafp, np.float32)
        curves[scheme] = cafp
        rows.append(
            (
                f"fig19/wdm8/{scheme}",
                {
                    "tr": trs.tolist(),
                    "cafp_vs_ideal_lta": [round(float(v), 4) for v in cafp],
                    "mean_cafp": round(float(cafp.mean()), 4),
                    "engine_ms": round(engine_ms, 1),
                },
            )
        )

    # acceptance summary: wherever depth-1 retry still fails, full multi-hop
    # augmenting must be ideal to <= 1e-3
    residual = curves["seq_retry"] > 0.0
    worst = float(curves["protocol_lta"][residual].max()) if residual.any() else 0.0
    rows.append(
        (
            "fig19/wdm8/summary",
            {
                "seq_retry_residual_points": int(residual.sum()),
                "max_protocol_cafp_at_residual": round(worst, 6),
                "near_ideal": bool(worst <= 1e-3),
            },
        )
    )

    # --- LtD-conditioned protocol variant (chain-order, no augmenting) ----
    # CAFP against the ideal *LtD* arbiter: with no absolute wavelength
    # anchor an oblivious controller can only hit the designated assignment
    # when nearest-visible == designated, so the LtD-conditioned CAFP
    # quantifies the price of anchor-freedom as TR (and aliasing) grows.
    req = SweepRequest(cfg=cfg, units=units, scheme="protocol_ltd",
                       axes={"tr_mean": trs})
    res, engine_ms = timed_steady(sweep, req)
    cafp_ltd = np.asarray(res.data.cafp, np.float32)
    rows.append(
        (
            "fig19/wdm8/protocol_ltd",
            {
                "tr": trs.tolist(),
                "cafp_vs_ideal_ltd": [round(float(v), 4) for v in cafp_ltd],
                "afp_ltd_ideal": [
                    round(float(v), 4) for v in np.asarray(res.data.afp)
                ],
                "engine_ms": round(engine_ms, 1),
            },
        )
    )

    # --- probe-budget / CAFP trade-off (chain depth ladder, WDM8) ---------
    by_depth = {"depth": [], "mean_probes": [], "mean_cafp": [],
                "mean_rounds": []}
    for depth in DEPTHS:
        pts = [_protocol_point(cfg, units, float(tr), depth) for tr in trs]
        cafp, probes, rounds_ = (np.asarray([float(p[i]) for p in pts])
                                 for i in range(3))
        by_depth["depth"].append(cfg.grid.n_ch if depth is None else depth)
        by_depth["mean_probes"].append(round(float(probes.mean()), 1))
        by_depth["mean_cafp"].append(round(float(cafp.mean()), 4))
        by_depth["mean_rounds"].append(round(float(rounds_.mean()), 1))
    monotone = all(
        a >= b - 1e-6
        for a, b in zip(by_depth["mean_cafp"], by_depth["mean_cafp"][1:])
    )
    rows.append(
        ("fig19/wdm8/probe_tradeoff", {**by_depth, "monotone": bool(monotone)})
    )

    # --- WDM16: double scale ---------------------------------------------
    cfg16 = WDM16_G200
    units16 = make_units(cfg16, seed=21, n_laser=n, n_ring=n)
    trs16 = tr_sweep(n_ch=cfg16.grid.n_ch, spacing=cfg16.grid.grid_spacing)
    req = SweepRequest(cfg=cfg16, units=units16, scheme="protocol_lta",
                       axes={"tr_mean": trs16}, chunk_size=1)
    res, engine_ms = timed_steady(sweep, req)
    cafp16 = np.asarray(res.data.cafp, np.float32)
    afp16 = np.asarray(res.data.afp, np.float32)
    rows.append(
        (
            "fig19/wdm16/protocol_lta",
            {
                "tr": trs16.tolist(),
                "afp_lta_ideal": [round(float(v), 4) for v in afp16],
                "cafp_vs_ideal_lta": [round(float(v), 4) for v in cafp16],
                "max_cafp": round(float(cafp16.max()), 4),
                "engine_ms": round(engine_ms, 1),
            },
        )
    )

    # --- WDM16 seq_retry failure taxonomy (flight recorder) ---------------
    # Every trial seq_retry loses while the ideal LtA arbiter wins is
    # re-arbitrated through the traced depth-1 protocol engine and
    # classified from its trace alone (repro.obs.taxonomy).  The obs
    # acceptance gate: the code set is closed — zero ``unknown``s.
    from repro.obs.taxonomy import explain_residuals

    tax = explain_residuals(cfg16, units16, trs16, scheme="seq_retry",
                            depth=1, trace_cap=128)
    rows.append(
        (
            "fig19/wdm16/seq_retry_taxonomy",
            {
                "residual_trials": tax["residual_total"],
                "histogram": tax["histogram"],
                "unknown": tax["unknown"],
                "all_classified": bool(tax["unknown"] == 0),
                "per_point": [
                    {"tr_mean": p["tr_mean"],
                     "residual_trials": p["residual_trials"],
                     **({"histogram": p["histogram"]}
                        if p["residual_trials"] else {})}
                    for p in tax["points"]
                ],
            },
        )
    )
    return rows
