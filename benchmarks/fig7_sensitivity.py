"""Fig. 7 — local sensitivity of the minimum tuning range to (a) grid offset,
(b) laser local variation, (c) TR variation, (d) FSR variation, at
sigma_rLV = 2.24 nm, for LtA and LtC.

Paper claims: flat beyond one grid spacing of offset (barrel-shift
compensation); d(minTR)/d(sigma_lLV) ~ 0.56 nm per 25%; LtA 'absorbs'
TR/FSR variations better than LtC.

Each named-sigma axis is one declarative ``SweepRequest`` (metric="min_tr")
— one jitted sweep-engine call."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, make_units, sweep

from .common import n_samples, timed_steady

SWEEPS = {
    "grid_offset_nm": ("sigma_go", [0.0, 0.28, 0.56, 0.84, 1.12]),
    "laser_llv_frac": ("sigma_llv_frac", [0.01, 0.15, 0.25, 0.35, 0.45]),
    "tr_var_frac": ("sigma_tr_frac", [0.0, 0.05, 0.10, 0.15, 0.20]),
    "fsr_var_frac": ("sigma_fsr_frac", [0.0, 0.01, 0.02, 0.035, 0.05]),
}


def run(full: bool = False):
    n = n_samples(full)
    cfg = WDM8_G200
    units = make_units(cfg, seed=7, n_laser=n, n_ring=n)
    rows = []
    for sweep_name, (axis, values) in SWEEPS.items():
        for policy in ("lta", "ltc"):
            req = SweepRequest(cfg=cfg, units=units, policy=policy,
                               metric="min_tr", axes={axis: np.asarray(values)})
            res, engine_ms = timed_steady(sweep, req)
            mt = [float(v) for v in np.asarray(res.data)]
            sens = (mt[-1] - mt[0]) / (values[-1] - values[0])
            rows.append(
                (
                    f"fig7/{sweep_name}/{policy}",
                    {
                        "values": list(values),
                        "min_tr": [round(v, 3) for v in mt],
                        "sensitivity": round(float(sens), 4),
                        "engine_ms": round(engine_ms, 1),
                    },
                )
            )
    return rows
