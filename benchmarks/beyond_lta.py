"""Beyond-paper — oblivious Lock-to-Any arbitration (the paper's §V-E
future work), now a protocol-family comparison:

  * SEQ-R/A (``seq_retry``): sequential-retry with depth-1 oblivious
    augmenting, scored as CAFP against the ideal LtA perfect-matching
    arbiter.  Finding: retry+augment closes most of the naive-greedy gap at
    the extremes but mid-TR starvation needs multi-hop augmenting (an
    O(N^3)-probe protocol) — quantitative evidence for why the paper
    deferred LtA.
  * the protocol engine (``protocol_lta``, ``repro.core.protocol``): the
    multi-hop augmenting protocol that claim called for — rounds of
    probe/release/augment displacement chains — which drives the residual
    CAFP to ~0 (the full grid is in ``fig19_lta_protocol``).

Each TR axis is one declarative ``SweepRequest`` — one jitted sweep-engine
call.  The retry-budget trade-off of the seq_retry family is studied in
``fig17_retry_budget``; the protocol chain-depth/probe-budget trade-off in
``fig19_lta_protocol``."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, make_units, sweep

from .common import n_samples, timed_steady, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    units = make_units(WDM8_G200, seed=21, n_laser=n, n_ring=n)
    trs = tr_sweep()
    req = SweepRequest(cfg=WDM8_G200, units=units, scheme="seq_retry",
                       axes={"tr_mean": trs})
    r, engine_ms = timed_steady(sweep, req)
    res = r.data
    afp = [round(float(v), 4) for v in np.asarray(res.afp)]
    cafp = [round(float(v), 4) for v in np.asarray(res.cafp)]
    rows = [
        (
            "beyond/lta_seq_retry_augment",
            {
                "tr": trs.tolist(),
                "afp_lta_ideal": afp,
                "cafp_vs_ideal_lta": cafp,
                "engine_ms": round(engine_ms, 1),
                "note": "zero-lock starvation dominates residual CAFP; "
                        "multi-hop augmenting required for ideal parity",
            },
        )
    ]
    req_p = SweepRequest(cfg=WDM8_G200, units=units, scheme="protocol_lta",
                         axes={"tr_mean": trs}, chunk_size=1)
    rp, engine_ms_p = timed_steady(sweep, req_p)
    cafp_p = [round(float(v), 4) for v in np.asarray(rp.data.cafp)]
    rows.append(
        (
            "beyond/lta_protocol_engine",
            {
                "tr": trs.tolist(),
                "cafp_vs_ideal_lta": cafp_p,
                "residual_closed": bool(max(cafp_p) <= 1e-3),
                "engine_ms": round(engine_ms_p, 1),
                "note": "multi-hop augmenting (repro.core.protocol) closes "
                        "the seq_retry residual to ideal-LtA parity",
            },
        )
    )
    return rows
