"""Beyond-paper — oblivious Lock-to-Any arbitration (the paper's §V-E
future work): sequential-retry with depth-1 oblivious augmenting (SEQ-R/A),
scored as CAFP against the ideal LtA perfect-matching arbiter.

Finding: retry+augment closes most of the naive-greedy gap at the extremes
but mid-TR starvation needs multi-hop augmenting (an O(N^3)-probe
protocol) — quantitative evidence for why the paper deferred LtA."""
from __future__ import annotations

import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import evaluate_scheme, make_units

from .common import n_samples, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    units = make_units(WDM8_G200, seed=21, n_laser=n, n_ring=n)
    trs = tr_sweep()
    rows = []
    afp, cafp = [], []
    for tr in trs:
        r = evaluate_scheme(WDM8_G200, units, "seq_retry", float(tr))
        afp.append(round(float(r.afp), 4))
        cafp.append(round(float(r.cafp), 4))
    rows.append(
        (
            "beyond/lta_seq_retry_augment",
            {
                "tr": trs.tolist(),
                "afp_lta_ideal": afp,
                "cafp_vs_ideal_lta": cafp,
                "note": "zero-lock starvation dominates residual CAFP; "
                        "multi-hop augmenting required for ideal parity",
            },
        )
    )
    return rows
