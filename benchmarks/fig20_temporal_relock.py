"""Fig. 20 (beyond paper) — temporal re-arbitration: incremental re-lock vs
cold re-arbitration under drift, aging, comb wander, and lane hot-swap.

Every scenario drives ``run_timeline`` twice over the same drift timeline
(``configs.wdm.DRIFT_SCENARIOS``): warm (the protocol resumes from its own
carried lock state, with transactional make-before-break commits and a
plateau halt) and cold (full re-arbitration each step, same engine
settings).  The acceptance comparison masks to the (step, trial) pairs
where a complete lock set remains *feasible* — on infeasible steps the warm
path honestly escalates unresolved trials to a cold rerun and pays both
passes, which is the controller a real system would run, not a win to gate
on.  Step 0 is excluded: both modes start cold there.

Studies:

  * WDM16 scenarios (x WDM32 under ``--full``) — per-step probe/round/
    churn/lock trajectories and the feasible-masked warm-vs-cold gate;
  * chain-depth ladder on the hot-swap scenario — does incremental re-lock
    still win when augmenting is depth-limited?
  * ``seq_retry`` quality row — a one-shot oblivious arbiter re-run cold
    each step: lock counts match, but churn shows why stateful re-lock
    matters (every drift step reshuffles rings that never had to move);
  * hysteresis margin sweep on the comb-wander scenario — how much margin
    the revalidator needs before marginal locks stop thrashing
    (break/re-lock cycles) as the comb sweeps back and forth.
"""
from __future__ import annotations

import numpy as np

from repro.configs.wdm import drift_timeline
from repro.core import make_units, run_timeline, slice_timeline

from .common import timed_steady

SCENARIOS16 = ("wdm16-thermal", "wdm16-aging", "wdm16-comb", "wdm16-hotswap")
SCENARIOS32 = ("wdm32-thermal", "wdm32-hotswap")
DEPTH_SCHEMES = ("protocol_lta_h1", "protocol_lta_h2", "protocol_lta_h4",
                 "protocol_lta")
#: operating TR for every temporal study, in units of grid spacing
TR_X = 4.0


def _trials(full: bool) -> int:
    return 32 if full else 12


def _means(a) -> list:
    """(S, T) per-trial stat -> per-step trial means, rounded."""
    return [round(float(v), 2) for v in np.asarray(a, np.float32).mean(axis=1)]


def _run_pair(name: str, scheme: str, n: int, seed: int = 33):
    """Warm and cold timelines for one scenario; returns (row dict, gates)."""
    cfg, tl = drift_timeline(name)
    units = make_units(cfg, seed=seed, n_laser=n, n_ring=n)
    var = {"tr_mean": TR_X * cfg.grid.grid_spacing}
    (_, warm), warm_ms = timed_steady(
        run_timeline, cfg, units, tl, var, scheme=scheme, warm=True
    )
    (_, cold), cold_ms = timed_steady(
        run_timeline, cfg, units, tl, var, scheme=scheme, warm=False
    )
    # Feasibility is a property of the drifted system, not the mode.
    feas = np.asarray(warm.feasible, bool)
    mask = feas[1:]                       # step 0 is cold for both modes
    wp = np.asarray(warm.probes, np.float32)[1:]
    cp = np.asarray(cold.probes, np.float32)[1:]
    wr = np.asarray(warm.rounds, np.float32)[1:]
    cr = np.asarray(cold.rounds, np.float32)[1:]
    if mask.any():
        warm_probes = float(wp[mask].mean())
        cold_probes = float(cp[mask].mean())
        warm_rounds = float(wr[mask].mean())
        cold_rounds = float(cr[mask].mean())
    else:  # degenerate scenario: nothing feasible to compare
        warm_probes = cold_probes = warm_rounds = cold_rounds = 0.0
    locked_ok = bool(
        np.all(np.asarray(warm.locked) >= np.asarray(cold.locked))
    )
    derived = {
        "steps": int(feas.shape[0]),
        "feasible_frac": _means(feas),
        "warm_probes": _means(warm.probes),
        "cold_probes": _means(cold.probes),
        "warm_rounds": _means(warm.rounds),
        "cold_rounds": _means(cold.rounds),
        "warm_churn": _means(warm.churn),
        "cold_churn": _means(cold.churn),
        "warm_locked": _means(warm.locked),
        "cold_locked": _means(cold.locked),
        "feasible_warm_probes": round(warm_probes, 2),
        "feasible_cold_probes": round(cold_probes, 2),
        "feasible_warm_rounds": round(warm_rounds, 2),
        "feasible_cold_rounds": round(cold_rounds, 2),
        "warm_wins_probes": bool(warm_probes < cold_probes),
        "warm_wins_rounds": bool(warm_rounds <= cold_rounds),
        "warm_locked_ge_cold": locked_ok,
        "warm_ms": round(warm_ms, 1),
        "cold_ms": round(cold_ms, 1),
    }
    gates = (derived["warm_wins_probes"], derived["warm_wins_rounds"],
             locked_ok)
    return derived, gates


def run(full: bool = False):
    n = _trials(full)
    rows = []

    # --- scenario sweep: incremental vs cold, feasible-masked gate --------
    gate_bits = []
    scenarios = SCENARIOS16 + (SCENARIOS32 if full else ())
    for name in scenarios:
        derived, gates = _run_pair(name, "protocol_lta", n)
        if name in SCENARIOS16:
            gate_bits.append(gates)
        rows.append((f"fig20/{name}/protocol_lta", derived))
    rows.append(
        (
            "fig20/summary",
            {
                "wdm16_scenarios": len(SCENARIOS16),
                "warm_wins_probes_all": bool(all(g[0] for g in gate_bits)),
                "warm_wins_rounds_all": bool(all(g[1] for g in gate_bits)),
                "warm_locked_ge_cold_all": bool(all(g[2] for g in gate_bits)),
            },
        )
    )

    # --- chain-depth ladder on the hot-swap scenario ----------------------
    ladder = {"scheme": [], "feasible_warm_probes": [],
              "feasible_cold_probes": [], "warm_wins_probes": []}
    for scheme in DEPTH_SCHEMES:
        derived, _ = _run_pair("wdm16-hotswap", scheme, n)
        ladder["scheme"].append(scheme)
        ladder["feasible_warm_probes"].append(derived["feasible_warm_probes"])
        ladder["feasible_cold_probes"].append(derived["feasible_cold_probes"])
        ladder["warm_wins_probes"].append(derived["warm_wins_probes"])
    rows.append(("fig20/wdm16-hotswap/depth_ladder", ladder))

    # --- seq_retry: one-shot oblivious arbitration re-run cold each step --
    cfg, tl = drift_timeline("wdm16-comb")
    tl4 = slice_timeline(tl, 0, 4)
    units = make_units(cfg, seed=33, n_laser=8, n_ring=8)
    var = {"tr_mean": TR_X * cfg.grid.grid_spacing}
    (_, sr), sr_ms = timed_steady(
        run_timeline, cfg, units, tl4, var, scheme="seq_retry", warm=False
    )
    (_, pl), _ = timed_steady(
        run_timeline, cfg, units, tl4, var, scheme="protocol_lta", warm=True
    )
    rows.append(
        (
            "fig20/wdm16-comb/seq_retry_cold",
            {
                "locked": _means(sr.locked),
                "churn": _means(sr.churn),
                "protocol_warm_locked": _means(pl.locked),
                "protocol_warm_churn": _means(pl.churn),
                "engine_ms": round(sr_ms, 1),
            },
        )
    )

    # --- hysteresis margin sweep (comb wander: locks thrash at the edge) --
    hx = (0.0, 0.1, 0.25, 0.5)
    units = make_units(cfg, seed=33, n_laser=n, n_ring=n)
    hrow = {"hysteresis_x_spacing": list(hx), "total_broken": [],
            "total_churn": [], "total_probes": [], "mean_locked": []}
    for h in hx:
        _, stats = run_timeline(
            cfg, units, tl, var, scheme="protocol_lta", warm=True,
            hysteresis=h * cfg.grid.grid_spacing,
        )
        hrow["total_broken"].append(round(float(
            np.asarray(stats.broken, np.float32).sum(axis=0).mean()), 2))
        hrow["total_churn"].append(round(float(
            np.asarray(stats.churn, np.float32).sum(axis=0).mean()), 2))
        hrow["total_probes"].append(round(float(
            np.asarray(stats.probes, np.float32).sum(axis=0).mean()), 1))
        hrow["mean_locked"].append(round(float(
            np.asarray(stats.locked, np.float32).mean()), 2))
    rows.append(("fig20/wdm16-comb/hysteresis", hrow))
    return rows


def smoke(trials: int = 4) -> dict:
    """Tiny-timeline CI smoke (``make ci``): the full temporal path — drift
    scenario resolution, warm scan with cold-fallback escalation, cold
    baseline — on a 3-step slice with 16 trials.  Asserts the structural
    invariants (shapes, warm never locking fewer than cold) without pinning
    the noisy probe comparison a 16-trial batch can't support."""
    cfg, tl = drift_timeline("wdm16-hotswap")
    tl = slice_timeline(tl, 0, 3)
    units = make_units(cfg, seed=5, n_laser=trials, n_ring=trials)
    var = {"tr_mean": TR_X * cfg.grid.grid_spacing}
    _, warm = run_timeline(cfg, units, tl, var, warm=True)
    _, cold = run_timeline(cfg, units, tl, var, warm=False)
    t = trials * trials
    assert np.asarray(warm.probes).shape == (3, t)
    assert np.all(np.asarray(warm.locked) >= np.asarray(cold.locked))
    assert np.array_equal(np.asarray(warm.feasible), np.asarray(cold.feasible))
    out = {
        "warm_probes": _means(warm.probes),
        "cold_probes": _means(cold.probes),
        "warm_locked": _means(warm.locked),
    }
    print(f"fig20 smoke OK: {out}")
    return out


if __name__ == "__main__":
    smoke()
