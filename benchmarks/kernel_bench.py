"""Pallas-kernel micro-benchmark: jnp path timings (the CPU-executable
production path) + interpret-mode parity check.  On-TPU wall-times are not
measurable in this container; the roofline for the kernels comes from the
BlockSpec VMEM analysis in kernels/*.py docstrings."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import ArbitrationConfig, make_units, wdm_config
from repro.core.matching import (
    adjacency_bitmask,
    _bottleneck_threshold_kuhn,
    bottleneck_matching_threshold,
)
from repro.core.reach import reach_matrix, scaled_residual
from repro.core.sampling import instantiate
from repro.kernels import ops

from .common import n_samples


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.time() - t0) / reps * 1e6


def run(full: bool = False):
    n = n_samples(full)
    cfg = ArbitrationConfig()
    units = make_units(cfg, seed=12, n_laser=n, n_ring=n)
    sys = instantiate(cfg, units)
    s = tuple(int(v) for v in cfg.s)
    rows = []

    (ltd, ltc), us = _time(
        ops.feasibility, sys.laser, sys.ring, sys.fsr, sys.tr_unit,
        s=s, backend="jnp",
    )
    rows.append(
        ("kernel/feasibility_jnp",
         {"trials": sys.n_trials, "us_per_call": round(us),
          "ns_per_trial": round(us * 1e3 / sys.n_trials, 1)})
    )

    adj = adjacency_bitmask(reach_matrix(sys, 4.0))
    (_, ok), us = _time(ops.perfect_matching, adj, backend="jnp")
    rows.append(
        ("kernel/bitmask_match_jnp",
         {"trials": sys.n_trials, "us_per_call": round(us),
          "match_rate": round(float(np.mean(np.asarray(ok))), 3)})
    )

    tr = 5.0 * sys.tr_unit
    _, us = _time(ops.build_tables, sys.laser, sys.ring, sys.fsr, tr,
                  max_alias=4, backend="jnp")
    rows.append(
        ("kernel/table_build_jnp",
         {"trials": sys.n_trials, "us_per_call": round(us)})
    )

    # Streaming top-E table build across channel counts: jnp (the engine's
    # scheme-path hot spot) at bench trials and the Pallas kernel in
    # interpret mode on one 128-trial lane block (correctness-path cost;
    # max_alias=2 there because interpret wall time is trace-dominated —
    # note that at that alias count the kernel's alias-group merge
    # degenerates to a single sort, so the multi-group path is guarded by
    # tests/test_kernels.py::test_table_kernel_multi_group_merge, not this
    # timing row).  The jnp legs DO run the multi-step streaming merge at
    # N=32, so a regression back to the dense build shows up in us_jnp and
    # in the memory pins before it OOMs a WDM32 sweep.
    for n_ch in (8, 16, 32):
        cfg_n = wdm_config(n_ch=n_ch)
        units_n = make_units(cfg_n, seed=7, n_laser=n, n_ring=n)
        sys_n = instantiate(cfg_n, units_n)
        tr_n = 5.0 * sys_n.tr_unit
        _, us_jnp = _time(ops.build_tables, sys_n.laser, sys_n.ring,
                          sys_n.fsr, tr_n, max_alias=4, backend="jnp")
        blk = type(sys_n)(*[a[:128] for a in sys_n])
        (d_i, w_i, nv_i), us_int = _time(
            ops.build_tables, blk.laser, blk.ring, blk.fsr, tr_n[:128],
            max_alias=2, backend="interpret", reps=1,
        )
        d_j, w_j, nv_j = ops.build_tables(
            blk.laser, blk.ring, blk.fsr, tr_n[:128],
            max_alias=2, backend="jnp",
        )
        fin = np.isfinite(np.asarray(d_j))
        parity = bool(
            np.array_equal(np.asarray(w_i), np.asarray(w_j))
            and np.array_equal(np.asarray(nv_i), np.asarray(nv_j))
            and np.allclose(np.asarray(d_i)[fin], np.asarray(d_j)[fin], atol=1e-5)
        )
        if not parity:
            raise AssertionError(f"table build n={n_ch}: interpret != jnp")
        rows.append(
            (f"kernel/table_build_n{n_ch}",
             {"trials": sys_n.n_trials, "us_jnp": round(us_jnp),
              "interpret_trials": 128, "us_interpret": round(us_int),
              "identical_wl": parity})
        )

    # Rank-merge streaming builder (the core jnp path every sweep runs)
    # across channel counts including the first 64-channel config: timing
    # plus the merge plan actually chosen, with a dense-oracle parity check
    # on a small trial slice at N<=32 (N=64 parity is covered by
    # tests/test_rank_merge.py; the dense tensor there is too large for a
    # timing row).
    from repro.core.search_table import (
        build_search_tables, build_search_tables_dense, merge_plan,
    )

    build_jit = jax.jit(build_search_tables)
    for n_ch in (16, 32, 64):
        cfg_n = wdm_config(n_ch=n_ch)
        units_n = make_units(cfg_n, seed=7, n_laser=n, n_ring=n)
        sys_n = instantiate(cfg_n, units_n)
        _, us_rm = _time(build_jit, sys_n, 5.0)
        plan = merge_plan(sys_n.n_trials, n_ch)
        derived = {
            "trials": sys_n.n_trials, "us_per_call": round(us_rm),
            "line_block": plan.line_block, "ring_block": plan.ring_block,
            "plan_mb": round(plan.total_bytes / 2**20, 1),
        }
        if n_ch <= 32:
            sub = type(sys_n)(*[a[:64] for a in sys_n])
            t_s = build_jit(sub, 5.0)
            t_d = build_search_tables_dense(sub, 5.0)
            parity = bool(
                np.array_equal(np.asarray(t_s.wl), np.asarray(t_d.wl))
                and np.array_equal(np.asarray(t_s.delta), np.asarray(t_d.delta),
                                   equal_nan=True)
            )
            if not parity:
                raise AssertionError(f"rank-merge n={n_ch}: stream != dense")
            derived["identical_to_dense"] = parity
        rows.append((f"kernel/table_rankmerge_n{n_ch}", derived))

    # WDM64 smoke: the first 64-channel config end to end — streaming
    # tables through the sweep engine plus one vtrs_ssm scheme point, all
    # inside the 256 MB chunk budget (LtC conditioning: the int32 adjacency
    # bitmask of the ideal LtA path tops out at N=32).  Trials are capped so
    # --full keeps the point inside the budget too.
    from repro.configs.wdm import WDM64_G200
    from repro.core import SweepRequest, sweep
    from repro.core.sweep import _CHUNK_BUDGET, scheme_point_bytes

    cfg64 = WDM64_G200
    m64 = min(n, 48)
    units64 = make_units(cfg64, seed=9, n_laser=m64, n_ring=m64)
    pt_bytes = scheme_point_bytes(cfg64, m64 * m64)
    if pt_bytes > _CHUNK_BUDGET:
        raise AssertionError(
            f"WDM64 scheme point {pt_bytes} B exceeds the chunk budget"
        )
    req64 = SweepRequest(
        cfg=cfg64, units=units64, scheme="vtrs_ssm",
        axes={"tr_mean": np.array([0.28 * cfg64.grid.fsr], np.float32)},
    )
    res64, us64 = _time(sweep, req64, reps=1)
    rows.append(
        ("kernel/wdm64_sweep_smoke",
         {"trials": m64 * m64, "point_mb": round(pt_bytes / 2**20, 1),
          "budget_mb": round(_CHUNK_BUDGET / 2**20, 1),
          "cafp": round(float(np.asarray(res64.data.cafp)[0]), 4),
          "afp": round(float(np.asarray(res64.data.afp)[0]), 4),
          "us_per_call": round(us64)})
    )

    # Bottleneck matching across channel counts: the retired Kuhn binary
    # search vs the current dispatch (Hall subsets at N=8, the single-pass
    # bottleneck sweep at N=16/32).  Thresholds must stay bit-identical —
    # the oracle pin is part of the benchmark, not just the test suite.
    new_fn = jax.jit(bottleneck_matching_threshold)
    kuhn_fn = jax.jit(_bottleneck_threshold_kuhn)
    for n_ch in (8, 16, 32):
        cfg_n = wdm_config(n_ch=n_ch)
        m = min(n, 16) if n_ch == 32 else n   # bound the Kuhn oracle's cost
        units_n = make_units(cfg_n, seed=5, n_laser=m, n_ring=m)
        w = scaled_residual(instantiate(cfg_n, units_n))
        new_thr, us_new = _time(new_fn, w, reps=3 if n_ch < 32 else 1)
        kuhn_thr, us_kuhn = _time(kuhn_fn, w, reps=3 if n_ch < 32 else 1)
        identical = bool(np.array_equal(np.asarray(new_thr), np.asarray(kuhn_thr)))
        if not identical:
            raise AssertionError(f"bottleneck n={n_ch}: sweep != Kuhn oracle")
        rows.append(
            (f"kernel/bottleneck_match_n{n_ch}",
             {"trials": int(w.shape[0]),
              "us_new": round(us_new), "us_kuhn": round(us_kuhn),
              "speedup_vs_kuhn": round(us_kuhn / us_new, 2),
              "identical_to_kuhn": identical})
        )

    # interpret-mode parity on a 128-trial lane block (correctness proof)
    sub = type(sys)(*[a[:128] for a in sys])
    l1, c1 = ops.feasibility(sub.laser, sub.ring, sub.fsr, sub.tr_unit, s=s,
                             backend="interpret")
    l2, c2 = ops.feasibility(sub.laser, sub.ring, sub.fsr, sub.tr_unit, s=s,
                             backend="jnp")
    parity = bool(
        np.allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
        and np.allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    )
    rows.append(("kernel/interpret_parity", {"pass": parity}))
    return rows


def main() -> None:
    """Standalone entry: ``python -m benchmarks.kernel_bench --json kb.json``."""
    import argparse

    from .common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(full=args.full)
    wall_ms = (time.time() - t0) * 1e3
    for name, derived in rows:
        print(name, derived)
    if args.json_out:
        write_json(
            args.json_out,
            [
                {"figure": "kernel_bench", "name": name,
                 "module_wall_ms": round(wall_ms, 1), "derived": derived}
                for name, derived in rows
            ],
            full=args.full,
        )


if __name__ == "__main__":
    main()
