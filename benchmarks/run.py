"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (derived = paper-comparable values);
``--json out.json`` additionally writes the per-figure wall-times and derived
metrics machine-readably (the seed for BENCH_*.json trajectory tracking)."""
from __future__ import annotations

import argparse
import json
import time

from .common import write_json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-size Monte Carlo (100x100 trials)")
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT",
                    help="also write machine-readable results to OUT")
    args = ap.parse_args()

    from . import (
        beyond_lta,
        fig4_afp_shmoo,
        fig5_min_tuning_range,
        fig6_ltd_grid_offset,
        fig7_sensitivity,
        fig8_fsr_design,
        fig14_cafp_schemes,
        fig15_seq_breakdown,
        fig16_high_variation,
        fig17_retry_budget,
        fig18_wdm32_cafp,
        kernel_bench,
        roofline_report,
    )

    modules = [
        fig4_afp_shmoo,
        fig5_min_tuning_range,
        fig6_ltd_grid_offset,
        fig7_sensitivity,
        fig8_fsr_design,
        fig14_cafp_schemes,
        fig15_seq_breakdown,
        fig16_high_variation,
        fig17_retry_budget,
        fig18_wdm32_cafp,
        kernel_bench,
        roofline_report,
        beyond_lta,
    ]
    print("name,us_per_call,derived")
    records = []
    for mod in modules:
        mod_name = mod.__name__.rsplit(".", 1)[-1]
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        rows = mod.run(full=args.full)
        wall_ms = (time.time() - t0) * 1e3
        us = wall_ms * 1e3 / max(len(rows), 1)
        for name, derived in rows:
            print(f"{name},{us:.0f},{json.dumps(derived, default=float)}")
            records.append(
                {
                    "figure": mod_name,
                    "name": name,
                    "module_wall_ms": round(wall_ms, 1),
                    "derived": derived,
                }
            )
    if args.json_out:
        write_json(args.json_out, records, full=args.full)


if __name__ == "__main__":
    main()
