"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (derived = paper-comparable values);
``--json out.json`` additionally writes the per-figure wall-times and derived
metrics machine-readably (the seed for BENCH_*.json trajectory tracking).

``--runs N`` repeats every module N times and records the *median* wall-time
and per-record ``*_ms`` timings (``engine_ms`` plus any per-phase breakdown
such as ``table_ms``/``arbitrate_ms``/``score_ms``) — the derived grids are
deterministic, so only the timings vary.  On noisy shared machines (PR 3 measured 23/51 records of
identical code drifting >20% between single runs on a 2-core container)
median-of-3 is what makes the ``check_regression`` wall-time gate usable.

``--timeout S`` arms a per-module alarm (SIGALRM; POSIX main thread only).
A module that hangs past it is recorded as a single marker record
(``derived: {"timeout": true, "phase": ...}``) attributing the hang to the
phase span that was executing when the alarm fired (``repro.obs.phase``
recorder — e.g. ``sweep:warm`` vs ``sweep:steady``, or fig18's
table/arbitrate/score breakdown), every module that already finished keeps
its records, and the JSON is still written — one wedged figure no longer
loses the whole run.  ``check_regression`` treats marker records as missing
(note, never a failure).

Every run also writes a ``repro.obs`` JSONL manifest (``.obs/``): each
record mirrors there as it lands, with per-module phase dumps; each JSON
record carries the manifest path and its module's aggregated ``phases``
fields so BENCH files and manifests cross-reference both ways.  Render
with ``python -m repro.obs.report``.
"""
from __future__ import annotations

import argparse
import json
import signal
import statistics
import time

from .common import write_json


class ModuleTimeout(Exception):
    """A benchmark module exceeded the per-module wall budget.

    ``phase`` carries the open span stack of the module's phase recorder at
    the instant the alarm fired (None when nothing was instrumented) — the
    difference between "the sweep compile wedged" and "the steady-state
    timing wedged" without re-running anything.
    """

    def __init__(self, phase: str | None = None):
        super().__init__(phase or "")
        self.phase = phase


def _run_with_timeout(fn, seconds: int | None, recorder=None):
    """Run ``fn()`` under a SIGALRM budget; raises ModuleTimeout on expiry.

    No-op passthrough when ``seconds`` is None/0 or SIGALRM is unavailable
    (non-POSIX or non-main-thread): the run degrades to untimed, never
    breaks.  ``recorder`` (a ``repro.obs.phase.PhaseRecorder``) attributes
    the timeout to the span executing when the alarm fired.
    """
    if not seconds or not hasattr(signal, "SIGALRM"):
        return fn()

    def on_alarm(signum, frame):
        raise ModuleTimeout(
            recorder.current_path() if recorder is not None else None
        )

    prev = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(seconds)
    try:
        return fn()
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-size Monte Carlo (100x100 trials)")
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT",
                    help="also write machine-readable results to OUT")
    ap.add_argument("--runs", type=int, default=1, metavar="N",
                    help="repeat each module N times; record median wall "
                         "and *_ms timings (noise-robust BENCH files)")
    ap.add_argument("--timeout", type=int, default=0, metavar="S",
                    help="per-module wall budget in seconds (0 = off); a "
                         "module over budget becomes a timeout marker "
                         "record and the run continues")
    args = ap.parse_args()
    if args.runs < 1:
        ap.error("--runs must be >= 1")
    if args.timeout < 0:
        ap.error("--timeout must be >= 0")

    from . import (
        beyond_lta,
        fig4_afp_shmoo,
        fig5_min_tuning_range,
        fig6_ltd_grid_offset,
        fig7_sensitivity,
        fig8_fsr_design,
        fig14_cafp_schemes,
        fig15_seq_breakdown,
        fig16_high_variation,
        fig17_retry_budget,
        fig18_wdm32_cafp,
        fig19_lta_protocol,
        fig20_temporal_relock,
        fig21_fabric_yield,
        fig22_fabric_chaos,
        kernel_bench,
        roofline_report,
    )

    modules = [
        fig4_afp_shmoo,
        fig5_min_tuning_range,
        fig6_ltd_grid_offset,
        fig7_sensitivity,
        fig8_fsr_design,
        fig14_cafp_schemes,
        fig15_seq_breakdown,
        fig16_high_variation,
        fig17_retry_budget,
        fig18_wdm32_cafp,
        fig19_lta_protocol,
        fig20_temporal_relock,
        fig21_fabric_yield,
        fig22_fabric_chaos,
        kernel_bench,
        roofline_report,
        beyond_lta,
    ]
    from repro.obs.manifest import RunManifest
    from repro.obs.phase import PhaseRecorder, use_recorder

    manifest = RunManifest.create(
        label="bench", full=args.full, runs=args.runs, timeout=args.timeout
    )
    print("name,us_per_call,derived")
    records = []
    for mod in modules:
        mod_name = mod.__name__.rsplit(".", 1)[-1]
        if args.only and args.only not in mod_name:
            continue
        walls, timing_runs = [], []
        # One recorder per module: its spans time each repeat's sweeps
        # (warm = compile, steady = execute) and — under --timeout — name
        # the phase a wedged module was stuck in.
        recorder = PhaseRecorder()
        try:
            with use_recorder(recorder):
                for _ in range(args.runs):
                    t0 = time.time()
                    rows = _run_with_timeout(
                        lambda: mod.run(full=args.full), args.timeout,
                        recorder,
                    )
                    walls.append((time.time() - t0) * 1e3)
                    timing_runs.append(
                        {name: {k: v for k, v in d.items()
                                if k.endswith("_ms")}
                         for name, d in rows}
                    )
        except ModuleTimeout as to:
            # One wedged module must not lose the run: emit a marker record
            # (check_regression treats it as missing) and move on.  Partial
            # repeats are discarded — a half-measured median is not a median.
            print(f"{mod_name}/TIMEOUT,0,{{}}")
            records.append(
                {
                    "figure": mod_name,
                    "name": f"{mod_name}/TIMEOUT",
                    "module_wall_ms": 0.0,
                    "manifest": manifest.path,
                    "derived": {"timeout": True,
                                "budget_s": args.timeout,
                                "phase": to.phase},
                }
            )
            manifest.record_bench(records[-1])
            manifest.record_phases(recorder, scope=mod_name)
            if args.json_out:
                write_json(args.json_out, records, full=args.full)
            continue
        wall_ms = statistics.median(walls)
        if args.runs > 1:
            # Grids are deterministic across runs; only timings vary.  Keep
            # the last run's rows and replace every *_ms derived field
            # (engine_ms and the per-phase breakdown) with its median.
            for name, derived in rows:
                for field in [k for k in derived if k.endswith("_ms")]:
                    derived[field] = round(statistics.median(
                        run[name][field] for run in timing_runs
                    ), 1)
        phases = recorder.phase_fields()
        manifest.record_phases(recorder, scope=mod_name)
        us = wall_ms * 1e3 / max(len(rows), 1)
        for name, derived in rows:
            print(f"{name},{us:.0f},{json.dumps(derived, default=float)}")
            records.append(
                {
                    "figure": mod_name,
                    "name": name,
                    "module_wall_ms": round(wall_ms, 1),
                    "manifest": manifest.path,
                    "phases": phases,
                    "derived": derived,
                }
            )
            manifest.record_bench(records[-1])
        if args.json_out:
            # incremental flush: a crash mid-suite keeps everything finished
            write_json(args.json_out, records, full=args.full)
    if args.json_out:
        write_json(args.json_out, records, full=args.full)
    manifest.close()


if __name__ == "__main__":
    main()
