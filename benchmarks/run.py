"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (derived = paper-comparable values);
``--json out.json`` additionally writes the per-figure wall-times and derived
metrics machine-readably (the seed for BENCH_*.json trajectory tracking).

``--runs N`` repeats every module N times and records the *median* wall-time
and per-record ``*_ms`` timings (``engine_ms`` plus any per-phase breakdown
such as ``table_ms``/``arbitrate_ms``/``score_ms``) — the derived grids are
deterministic, so only the timings vary.  On noisy shared machines (PR 3 measured 23/51 records of
identical code drifting >20% between single runs on a 2-core container)
median-of-3 is what makes the ``check_regression`` wall-time gate usable.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

from .common import write_json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-size Monte Carlo (100x100 trials)")
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT",
                    help="also write machine-readable results to OUT")
    ap.add_argument("--runs", type=int, default=1, metavar="N",
                    help="repeat each module N times; record median wall "
                         "and *_ms timings (noise-robust BENCH files)")
    args = ap.parse_args()
    if args.runs < 1:
        ap.error("--runs must be >= 1")

    from . import (
        beyond_lta,
        fig4_afp_shmoo,
        fig5_min_tuning_range,
        fig6_ltd_grid_offset,
        fig7_sensitivity,
        fig8_fsr_design,
        fig14_cafp_schemes,
        fig15_seq_breakdown,
        fig16_high_variation,
        fig17_retry_budget,
        fig18_wdm32_cafp,
        fig19_lta_protocol,
        kernel_bench,
        roofline_report,
    )

    modules = [
        fig4_afp_shmoo,
        fig5_min_tuning_range,
        fig6_ltd_grid_offset,
        fig7_sensitivity,
        fig8_fsr_design,
        fig14_cafp_schemes,
        fig15_seq_breakdown,
        fig16_high_variation,
        fig17_retry_budget,
        fig18_wdm32_cafp,
        fig19_lta_protocol,
        kernel_bench,
        roofline_report,
        beyond_lta,
    ]
    print("name,us_per_call,derived")
    records = []
    for mod in modules:
        mod_name = mod.__name__.rsplit(".", 1)[-1]
        if args.only and args.only not in mod_name:
            continue
        walls, timing_runs = [], []
        for _ in range(args.runs):
            t0 = time.time()
            rows = mod.run(full=args.full)
            walls.append((time.time() - t0) * 1e3)
            timing_runs.append(
                {name: {k: v for k, v in d.items() if k.endswith("_ms")}
                 for name, d in rows}
            )
        wall_ms = statistics.median(walls)
        if args.runs > 1:
            # Grids are deterministic across runs; only timings vary.  Keep
            # the last run's rows and replace every *_ms derived field
            # (engine_ms and the per-phase breakdown) with its median.
            for name, derived in rows:
                for field in [k for k in derived if k.endswith("_ms")]:
                    derived[field] = round(statistics.median(
                        run[name][field] for run in timing_runs
                    ), 1)
        us = wall_ms * 1e3 / max(len(rows), 1)
        for name, derived in rows:
            print(f"{name},{us:.0f},{json.dumps(derived, default=float)}")
            records.append(
                {
                    "figure": mod_name,
                    "name": name,
                    "module_wall_ms": round(wall_ms, 1),
                    "derived": derived,
                }
            )
    if args.json_out:
        write_json(args.json_out, records, full=args.full)


if __name__ == "__main__":
    main()
