"""Fig. 16 — RS/SSM vs VT-RS/SSM under harsh variations
(sigma_FSR = 5%, sigma_TR = 20%).

Paper claims: error regions near low TR (~3 nm, FSR variation) and high TR
(~8 nm, TR+FSR variation); VT-RS/SSM still performs well."""
from __future__ import annotations

import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import evaluate_scheme, make_units

from .common import n_samples, rlv_sweep, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rlvs = rlv_sweep()[:5]
    rows = []
    for order in ("natural", "permuted"):
        cfg = WDM8_G200.with_orders(order)
        units = make_units(cfg, seed=11, n_laser=n, n_ring=n)
        for scheme in ("rs_ssm", "vtrs_ssm"):
            grid = np.zeros((len(rlvs), len(trs)), np.float32)
            for i, srlv in enumerate(rlvs):
                for j, tr in enumerate(trs):
                    r = evaluate_scheme(
                        cfg, units, scheme, float(tr),
                        sigma_rlv=float(srlv),
                        sigma_fsr_frac=0.05, sigma_tr_frac=0.20,
                    )
                    grid[i, j] = float(r.cafp)
            rows.append(
                (
                    f"fig16/{order}/{scheme}",
                    {
                        "sigma_rlv": rlvs.tolist(),
                        "tr": trs.tolist(),
                        "cafp": np.round(grid, 4).tolist(),
                        "max_cafp": round(float(grid.max()), 4),
                    },
                )
            )
    return rows
