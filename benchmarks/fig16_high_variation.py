"""Fig. 16 — RS/SSM vs VT-RS/SSM under harsh variations
(sigma_FSR = 5%, sigma_TR = 20%).

Paper claims: error regions near low TR (~3 nm, FSR variation) and high TR
(~8 nm, TR+FSR variation); VT-RS/SSM still performs well.

Each shmoo is one declarative ``SweepRequest``; the harsh sigmas are a
traced ``fixed`` ``Variations`` shared by every grid point."""
from __future__ import annotations


import numpy as np

from repro.configs.wdm import WDM8_G200
from repro.core import SweepRequest, Variations, make_units, sweep

from .common import n_samples, rlv_sweep, timed_steady, tr_sweep


def run(full: bool = False):
    n = n_samples(full)
    trs = tr_sweep()
    rlvs = rlv_sweep()[:5]
    axes = {"sigma_rlv": rlvs, "tr_mean": trs}
    harsh = Variations(sigma_fsr_frac=0.05, sigma_tr_frac=0.20)
    rows = []
    for order in ("natural", "permuted"):
        cfg = WDM8_G200.with_orders(order)
        units = make_units(cfg, seed=11, n_laser=n, n_ring=n)
        for scheme in ("rs_ssm", "vtrs_ssm"):
            req = SweepRequest(cfg=cfg, units=units, scheme=scheme,
                               axes=axes, fixed=harsh)
            res, engine_ms = timed_steady(sweep, req)
            grid = np.asarray(res.data.cafp, np.float32)
            rows.append(
                (
                    f"fig16/{order}/{scheme}",
                    {
                        "sigma_rlv": rlvs.tolist(),
                        "tr": trs.tolist(),
                        "cafp": np.round(grid, 4).tolist(),
                        "max_cafp": round(float(grid.max()), 4),
                        "engine_ms": round(engine_ms, 1),
                    },
                )
            )
    return rows
