# CI entry points (ROADMAP "wire into CI"): `make ci` is what the GitHub
# workflow runs — the tier-1 suite, the BENCH-gate self-test, the kernel
# microbenches (table-build/rank-merge + matching + the WDM64 sweep smoke;
# no figure sweeps), a tiny-grid fig18 smoke (2x2 grid, low trials) so the
# paper-scale WDM32 path stays green, a tiny-timeline fig20 smoke so
# the temporal re-arbitration scan stays green, a tiny-fabric fig21
# smoke (6-link fabric, all three schemes + constraints-off parity) so the
# fabric layer stays green, a tiny-fabric fig22 chaos smoke (no-fault
# parity + kill-and-heal warm/cold gates) so the temporal x fabric
# composition stays green, and an obs smoke (trace-enabled protocol run +
# manifest write + report render) so the observability layer stays green —
# all without the full bench-gate cost.
PY ?= python

.PHONY: ci tier1 bench-selftest bench-kernel bench-fig18-smoke \
        bench-fig20-smoke bench-fig21-smoke bench-fig22-smoke obs-smoke \
        bench bench-gate

ci: tier1 bench-selftest bench-kernel bench-fig18-smoke bench-fig20-smoke \
        bench-fig21-smoke bench-fig22-smoke obs-smoke

tier1:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-selftest:
	$(PY) benchmarks/check_regression.py --self-test

bench-kernel:
	PYTHONPATH=src $(PY) -m benchmarks.run --only kernel

bench-fig18-smoke:
	PYTHONPATH=src:. $(PY) -m benchmarks.fig18_wdm32_cafp

bench-fig20-smoke:
	PYTHONPATH=src:. $(PY) -m benchmarks.fig20_temporal_relock

bench-fig21-smoke:
	PYTHONPATH=src:. $(PY) -m benchmarks.fig21_fabric_yield

bench-fig22-smoke:
	PYTHONPATH=src:. $(PY) -m benchmarks.fig22_fabric_chaos

# End-to-end observability gate: a trace-enabled tiny WDM8 protocol run
# (taxonomy), a recorded sweep (spans + memory watermark), a chaos health
# matrix — written to a run manifest and rendered back via repro.obs.report.
obs-smoke:
	PYTHONPATH=src $(PY) -m repro.obs.smoke

# Regenerate the BENCH trajectory file and gate it against the committed
# baseline (>20% per-figure / per-record slowdowns fail).  On noisy shared
# machines add `--runs 3` to benchmarks.run (median wall/engine times) or
# export BENCH_GATE_THRESHOLD to widen the gate — identical code drifts
# >20% between single runs on a loaded 2-core container.
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --json BENCH_new.json

bench-gate: bench
	$(PY) benchmarks/check_regression.py BENCH_sweep.json BENCH_new.json
