"""Quickstart: wavelength arbitration in a few lines.

Builds the paper's default 8-channel DWDM system (Table I), evaluates the
wavelength-oblivious arbitration schemes against their ideal policies, and
prints the robustness metrics (AFP / CAFP) across tuning ranges — the whole
TR axis in ONE jitted call through the declarative sweep frontend:

  * ``Variations``  — all device-variation / tuning-range overrides in one
    frozen pytree (``Variations(tr_mean=5.0, sigma_rlv=2.24)``);
  * ``SweepRequest`` — a declarative grid evaluation (cfg, units, axes,
    fixed overrides, scheme/policy) consumed by ``sweep(request)``;
  * results carry their axis metadata: ``res.axis("tr_mean")`` returns the
    coordinates the grid was evaluated over.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    ArbitrationConfig,
    SweepRequest,
    Variations,
    evaluate_scheme,
    make_units,
    sweep,
)

cfg = ArbitrationConfig()  # wdm8 @ 200 GHz, Table I defaults
units = make_units(cfg, seed=0, n_laser=40, n_ring=40)  # 1600 MC trials
trs = np.array([2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 8.96], np.float32)

# One SweepRequest per scheme: the whole TR axis is a single jitted call.
results = {
    scheme: sweep(SweepRequest(cfg=cfg, units=units, scheme=scheme,
                               axes={"tr_mean": trs}))
    for scheme in ("seq", "rs_ssm", "vtrs_ssm")
}

print(f"{'TR[nm]':>7s} {'AFP':>8s} {'CAFP seq':>9s} {'CAFP RS':>9s} {'CAFP VT':>9s}")
for i, tr in enumerate(results["seq"].axis("tr_mean")):
    print(
        f"{tr:7.2f} {float(results['seq'].data.afp[i]):8.4f} "
        f"{float(results['seq'].data.cafp[i]):9.4f} "
        f"{float(results['rs_ssm'].data.cafp[i]):9.4f} "
        f"{float(results['vtrs_ssm'].data.cafp[i]):9.4f}"
    )

# Every stage honors ``backend=``: None (default) is the core jnp path;
# "jnp" routes table build, ideal scoring and the protocol engine's masked
# re-search through the kernel wrappers' jnp mirrors, "interpret"/"pallas"
# select the Pallas kernels (interpreter / real accelerator).  The value
# reaches every registered scheme arbiter (see the ROADMAP backend
# matrix), and CPU-reachable backends are bit-identical by contract.
res_jnp = sweep(SweepRequest(cfg=cfg, units=units, scheme="vtrs_ssm",
                             axes={"tr_mean": trs}, backend="jnp"))
assert np.array_equal(np.asarray(res_jnp.data.cafp),
                      np.asarray(results["vtrs_ssm"].data.cafp))
print("\nbackend='jnp' sweep is bit-identical to the core path")

# Point evaluations take the same Variations pytree; any registered axis
# (including post-paper ones like thermal_drift) is a valid override.
r = evaluate_scheme(
    cfg, units, "vtrs_ssm",
    variations=Variations(tr_mean=5.0, sigma_rlv=2.24, thermal_drift=0.3),
)
print(f"\npoint eval @ TR=5nm, 0.3nm thermal drift: CAFP = {float(r.cafp):.4f}")

# Protocol-engine schemes (repro.core.protocol — multi-hop augmenting LtA,
# the paper's §V-E future work) are ordinary registry entries, so a whole
# protocol-family comparison is just more SweepRequests.  protocol_lta_h1
# caps displacement chains at one hop; protocol_lta runs full multi-hop
# augmenting and tracks the *ideal* perfect-matching LtA arbiter (CAFP ~ 0).
# (Smaller Monte-Carlo batch: the round-driven simulation is heavier than
# the one-shot schemes, and the contrast shows at 256 trials already.)
units_p = make_units(cfg, seed=0, n_laser=16, n_ring=16)
protocol = {
    scheme: sweep(SweepRequest(cfg=cfg, units=units_p, scheme=scheme,
                               axes={"tr_mean": trs}, chunk_size=1))
    for scheme in ("seq_retry", "protocol_lta_h1", "protocol_lta")
}
print(f"\n{'TR[nm]':>7s} {'CAFP retry':>11s} {'CAFP hop-1':>11s} {'CAFP multi':>11s}  (vs ideal LtA)")
for i, tr in enumerate(trs):
    print(
        f"{tr:7.2f} {float(protocol['seq_retry'].data.cafp[i]):11.4f} "
        f"{float(protocol['protocol_lta_h1'].data.cafp[i]):11.4f} "
        f"{float(protocol['protocol_lta'].data.cafp[i]):11.4f}"
    )

print(
    "\nVT-RS/SSM tracks the ideal wavelength-aware LtC arbiter (CAFP ~ 0)\n"
    "while sequential Lock-to-Nearest fails on most trials — paper Fig. 14.\n"
    "Multi-hop augmenting closes the oblivious-LtA gap the same way\n"
    "(beyond-paper Fig. 19; benchmarks/fig19_lta_protocol.py)."
)

# Temporal re-arbitration (beyond-paper Fig. 20): time is a simulation
# axis.  A drift Timeline (thermal ramps, comb wander, ring aging, lane
# kill/hot-swap events) scans the protocol engine step by step; with
# warm=True each step *resumes from the previous step's lock state* —
# transactional make-before-break re-locks instead of full re-init — so
# steady steps cost ~zero probes and disturbances re-lock incrementally.
from repro.configs.wdm import drift_timeline
from repro.core import run_timeline, slice_timeline

tcfg, tl = drift_timeline("wdm16-hotswap")   # thermal ramp + lane kill/swap
tl = slice_timeline(tl, 0, 4)
units_t = make_units(tcfg, seed=1, n_laser=8, n_ring=8)
var_t = {"tr_mean": 4.0 * tcfg.grid.grid_spacing}
_, warm = run_timeline(tcfg, units_t, tl, var_t, warm=True)
_, cold = run_timeline(tcfg, units_t, tl, var_t, warm=False)
print(f"\n{'step':>4s} {'warm probes':>12s} {'cold probes':>12s} {'locked':>7s}")
for s in range(4):
    print(
        f"{s:4d} {float(np.mean(warm.probes[s])):12.1f} "
        f"{float(np.mean(cold.probes[s])):12.1f} "
        f"{float(np.mean(warm.locked[s])):7.2f}"
    )
print(
    "incremental re-lock pays a fraction of a cold start after step 0\n"
    "(benchmarks/fig20_temporal_relock.py sweeps every drift scenario)"
)

# Fabric-scale arbitration (beyond-paper Fig. 21): a whole multi-pod DWDM
# fabric — pods, link bundles, shared comb groups, routes — brought up in
# one jitted, link-chunked call, then scored against the network-level
# wavelength-assignment constraints (endpoint-matched spectral orderings,
# comb-coupled laser draws, per-route wavelength continuity).
from repro.configs.fabric import FABRIC_TINY
from repro.fabric import bringup

fres = bringup(cfg, FABRIC_TINY, tr_mean=4.6, scheme="vtrs_ssm", seed=0)
st = fres.stats
print(
    f"\nfabric bring-up ({FABRIC_TINY.n_links} links, "
    f"{FABRIC_TINY.pods} pods): link yield {float(st.link_up):.2f}, "
    f"CAFP {float(st.cafp):.4f}, matched orderings {float(st.matched):.2f},"
    f"\n  bandwidth {float(st.bandwidth):.2f}, route continuity "
    f"{float(st.route_cont):.2f}"
)

# Degraded-link report + warm repair: the interconnect runtime wraps the
# fabric layer and carries live lock state, so re-arbitration warm-restarts
# the protocol engine (transactional, monotone) instead of re-drawing.
from repro.optics.interconnect import bringup as fabric_bringup_rt
from repro.optics.interconnect import rearbitrate

fab = fabric_bringup_rt(2, 8, cfg, tr_mean=4.6, scheme="vtrs_ssm", seed=0)
for link in fab.degraded_links():
    print(
        f"  degraded link pod{link.src_pod}->pod{link.dst_pod}"
        f"#{link.transceiver}: {link.lanes_up}/{link.lanes_total} lanes "
        f"({link.failure})"
    )
fab2, rounds = rearbitrate(fab, cfg)
print(
    f"warm re-arbitration: bandwidth {fab.bandwidth_fraction:.2f} -> "
    f"{fab2.bandwidth_fraction:.2f} in {rounds} protocol round(s)\n"
    f"(sigma x TR grids over whole fabrics: SweepRequest(fabric=...); "
    f"benchmarks/fig21_fabric_yield.py runs 1008 links per point)"
)

# Fabric chaos (beyond-paper Fig. 22): the temporal and fabric axes
# compose.  A FabricTimeline carries correlated drift plus fault events —
# here a link killed at step 1 and healed at step 3 — and
# run_fabric_timeline scans every link's protocol state through it:
# disturbed links warm re-lock, undisturbed links spend nothing, and a
# link that comes back from a full outage cold-restarts its arbitration.
from repro.fabric import (
    make_fabric_timeline,
    make_fabric_units,
    run_fabric_timeline,
)

tl_f = make_fabric_timeline(
    FABRIC_TINY, 5, cfg.grid.n_ch,
    events=((1, "link_kill", 2), (3, "link_heal", 2)),
)
units_f = make_fabric_units(cfg, FABRIC_TINY, seed=0)
_, chaos = run_fabric_timeline(cfg, units_f, FABRIC_TINY, tl_f,
                               scheme="vtrs_ssm")
bw = np.asarray(chaos.fabric.bandwidth)
probes = np.asarray(chaos.probes).mean(axis=1)
print(f"\n{'step':>4s} {'bandwidth':>10s} {'mean probes':>12s}")
for s in range(tl_f.n_steps):
    print(f"{s:4d} {float(bw[s]):10.3f} {float(probes[s]):12.1f}")
print(
    "kill-and-heal: bandwidth dips while the link is down and recovers on\n"
    "heal; survivors never spend a probe (benchmarks/fig22_fabric_chaos.py\n"
    "runs comb outages, pod heating and ring death with warm-vs-cold gates)"
)

# Flight recorder (repro.obs): pass ``trace=<capacity>`` and the protocol
# engine carries a per-trial event ring (probe / lock / displace /
# surrender / release / halt) through its round loop — off by default, and
# the disabled path is bit-identical to the untraced engine.  Here we take
# a TR point where depth-1 seq_retry still fails against a feasible ideal,
# replay it through the traced engine, and let the failure taxonomy say
# *why* each residual trial failed — starvation vs displacement-storm vs
# livelock vs hopeless — from the trace alone.
from repro.core.protocol import default_rounds, run_protocol
from repro.core.relation import chain_spec
from repro.core.sampling import instantiate
from repro.core.search_table import build_search_tables
from repro.obs import format_events, trace_events
from repro.obs.taxonomy import explain_residuals

mid_tr = float(trs[len(trs) // 2])  # mid-sweep: where seq_retry leaves CAFP
tax = explain_residuals(cfg, units_p, [mid_tr], scheme="seq_retry",
                        depth=1, trace_cap=64)
print(
    f"\nflight recorder @ TR={mid_tr:.2f}nm: seq_retry loses "
    f"{tax['residual_total']} trials the ideal LtA arbiter wins;\n"
    f"taxonomy: {tax['histogram']} (unknown={tax['unknown']})"
)
if tax["points"][0]["trial_index"]:
    # replay the first failing trial with tracing on and show its events
    trial = tax["points"][0]["trial_index"][0]
    sys_q = instantiate(cfg, units_p)
    tbl = build_search_tables(sys_q, mid_tr, max_alias=cfg.max_fsr_alias)
    _, buf = run_protocol(tbl, chain_spec(cfg.s), depth=1,
                          n_rounds=default_rounds(cfg.grid.n_ch), trace=64)
    print(f"trial {trial}'s last protocol events:")
    print(format_events(trace_events(buf, trial), limit=6))
print(
    "(benchmarks/fig19_lta_protocol.py classifies every WDM16 residual;\n"
    "`python -m repro.obs.report` renders bench-run manifests from .obs/)"
)
