"""Quickstart: wavelength arbitration in a few lines.

Builds the paper's default 8-channel DWDM system (Table I), runs the
wavelength-oblivious VT-RS/SSM arbiter against the ideal LtC model, and
prints the robustness metrics (AFP / CAFP) across tuning ranges.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ArbitrationConfig, evaluate_scheme, make_units

cfg = ArbitrationConfig()  # wdm8 @ 200 GHz, Table I defaults
units = make_units(cfg, seed=0, n_laser=40, n_ring=40)  # 1600 MC trials

print(f"{'TR[nm]':>7s} {'AFP':>8s} {'CAFP seq':>9s} {'CAFP RS':>9s} {'CAFP VT':>9s}")
for tr in (2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 8.96):
    r_seq = evaluate_scheme(cfg, units, "seq", tr)
    r_rs = evaluate_scheme(cfg, units, "rs_ssm", tr)
    r_vt = evaluate_scheme(cfg, units, "vtrs_ssm", tr)
    print(
        f"{tr:7.2f} {float(r_seq.afp):8.4f} {float(r_seq.cafp):9.4f} "
        f"{float(r_rs.cafp):9.4f} {float(r_vt.cafp):9.4f}"
    )

print(
    "\nVT-RS/SSM tracks the ideal wavelength-aware LtC arbiter (CAFP ~ 0)\n"
    "while sequential Lock-to-Nearest fails on most trials — paper Fig. 14."
)
