"""LEGACY SEED SCAFFOLD (see README.md here) — unrelated to the paper.

Batched serving example: prefill a batch of prompts, then decode with the
cached state — the same prefill/decode units the dry-run lowers for the
``prefill_*`` / ``decode_*`` shape cells.

    PYTHONPATH=src python examples/legacy_lm/serve_lm.py --batch 4 --new-tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)  # reduced same-family config for host serving
    params = M.init_params(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.new_tokens

    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    extra = None
    if cfg.frontend_len:
        extra = 0.02 * jax.random.normal(
            jax.random.key(2), (args.batch, cfg.frontend_len, cfg.d_model)
        )

    prefill = jax.jit(lambda p, t: M.prefill(p, cfg, t, max_len, extra_embeds=extra))
    decode = jax.jit(lambda p, s, t: M.decode_step(p, cfg, s, t))

    t0 = time.time()
    logits, state = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    nxt = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    for _ in range(args.new_tokens):
        out.append(np.asarray(nxt)[:, 0])
        logits, state = decode(params, state, nxt)
        nxt = jnp.argmax(logits, axis=-1)[:, None]
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    tokens = np.stack(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms  "
          f"decode: {t_decode/args.new_tokens*1e3:.2f} ms/token")
    for b in range(args.batch):
        print(f"  seq[{b}]: {tokens[b][:16].tolist()}...")
    assert np.all(tokens >= 0) and np.all(tokens < cfg.vocab)
    print("OK")


if __name__ == "__main__":
    main()
