"""LEGACY LM serving launcher (quarantined from ``repro.launch.serve``):
``PYTHONPATH=src python examples/legacy_lm/serve_arch_launcher.py --arch <id>``.

Batched request loop over the prefill/decode units of the dry-run; on host
hardware uses the reduced same-family config.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = M.init_params(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.new_tokens
    prefill = jax.jit(
        lambda p, t, e: M.prefill(p, cfg, t, max_len, extra_embeds=e)
    )
    decode = jax.jit(lambda p, s, t: M.decode_step(p, cfg, s, t))

    for req in range(args.requests):
        prompts = jax.random.randint(
            jax.random.key(10 + req), (args.batch, args.prompt_len), 0, cfg.vocab
        )
        extra = None
        if cfg.frontend_len:
            extra = 0.02 * jax.random.normal(
                jax.random.key(99), (args.batch, cfg.frontend_len, cfg.d_model)
            )
        t0 = time.time()
        logits, state = prefill(params, prompts, extra)
        nxt = jnp.argmax(logits, -1)[:, None]
        for _ in range(args.new_tokens):
            logits, state = decode(params, state, nxt)
            nxt = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(logits)
        dt = time.time() - t0
        tput = args.batch * args.new_tokens / dt
        print(f"request {req}: batch={args.batch} "
              f"{dt*1e3:.0f} ms total, {tput:.1f} tok/s")
    print("OK")


if __name__ == "__main__":
    main()
