"""LEGACY SEED SCAFFOLD (see README.md here) — unrelated to the paper.

End-to-end training driver: ~100M-parameter LM on the synthetic Markov
corpus with the full production stack — sharded params, microbatched train
step, AdamW, checkpointing/restart, optical-fabric bring-up, straggler
tracking.

    PYTHONPATH=src python examples/legacy_lm/train_lm.py --steps 300
    PYTHONPATH=src python examples/legacy_lm/train_lm.py --preset small --steps 80
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs.archs import _SMALL  # numerics preset
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding, steps
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig, dense_pattern
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~110M params: the assignment's "train ~100M model" driver
    "base": dict(d_model=768, n_layers=12, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=8192, seq_len=256, batch=8),
    # CPU-quick variant for CI / smoke evidence
    "small": dict(d_model=384, n_layers=6, n_heads=6, n_kv_heads=2,
                  d_ff=1152, vocab=4096, seq_len=128, batch=4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=PRESETS, default="base")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"train-lm-{args.preset}",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        head_dim=64, pattern=dense_pattern(), act="swiglu",
        q_chunk=128, kv_chunk=128, remat="full", **_SMALL,
    )
    from repro.models import model as M
    print(f"model: {cfg.name}  params={M.count_params(cfg)/1e6:.1f}M")

    mesh = make_host_mesh()
    opt_cfg = adamw.AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                                decay_steps=max(args.steps, 100))
    params_sh = sharding.param_shardings(cfg, mesh)
    opt_sh = sharding.opt_shardings(params_sh, sharding.replicated(mesh))
    step_fn = jax.jit(
        steps.make_train_step(cfg, opt_cfg, n_microbatch=1),
        donate_argnums=(0, 1),
    )

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 3, 10),
        ckpt_dir=ckpt_dir, log_every=10, pods=2, links_per_pod_pair=8,
        link_failure_prob_per_step=0.02,
    )
    trainer = Trainer(cfg, tcfg, opt_cfg, mesh, step_fn, params_sh, opt_sh)

    fabric = trainer.bringup_fabric()
    print(
        f"fabric: {len(fabric.links)} inter-pod DWDM links arbitrated "
        f"(VT-RS/SSM), bandwidth fraction {fabric.bandwidth_fraction:.3f}"
    )

    data = TokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=p["seq_len"],
                   global_batch=p["batch"], seed=1)
    )
    state = trainer.init_state()
    print(f"starting at step {state.step} -> {tcfg.total_steps}")
    state = trainer.fit(state, iter(data))
    data.close()

    print("\nstep   loss     gnorm    s/step")
    for m in trainer.metrics_log:
        print(f"{m['step']:5d} {m['loss']:8.4f} {m['grad_norm']:8.3f} {m['sec_per_step']:7.2f}")
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    print(
        f"\nloss {first['loss']:.4f} -> {last['loss']:.4f}  "
        f"(stragglers={trainer.straggler_events}, "
        f"rearb_rounds={trainer.rearb_rounds}, ckpt={ckpt_dir})"
    )


if __name__ == "__main__":
    main()
