"""Cluster bring-up at scale: arbitrate every inter-pod optical DWDM link of
a multi-pod deployment, inject lane failures, re-arbitrate (LtC barrel
shift), and report the fabric health + its effect on the cross-pod roofline
collective term — the paper's technique doing its production job.

    PYTHONPATH=src python examples/cluster_bringup.py --pods 4 --links 32
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs.wdm import WDM8_G200, WDM16_G200
from repro.optics import bringup, expected_failure_rates, rearbitrate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--links", type=int, default=32, help="transceivers per pod pair")
    ap.add_argument("--tr", type=float, default=6.0, help="mean tuning range [nm]")
    ap.add_argument("--wdm16", action="store_true")
    args = ap.parse_args()
    cfg = WDM16_G200 if args.wdm16 else WDM8_G200

    # fleet planning numbers at the chosen operating point (paper metrics)
    rates = expected_failure_rates(cfg, args.tr, scheme="vtrs_ssm", n=48)
    print(f"operating point: TR={args.tr} nm, {cfg.grid.n_ch}ch DWDM")
    print(f"  AFP (policy yield loss) = {rates['afp']:.4f}")
    print(f"  CAFP (algorithmic)      = {rates['cafp']:.4f}")

    t0 = time.time()
    fabric = bringup(
        pods=args.pods, links_per_pod_pair=args.links, cfg=cfg,
        tr_mean=args.tr, scheme="vtrs_ssm",
    )
    dt = time.time() - t0
    n_pairs = args.pods * (args.pods - 1) // 2
    print(
        f"\nbring-up: {len(fabric.links)} links over {n_pairs} pod pairs "
        f"in {dt:.2f}s (simulated transceivers)"
    )
    deg = fabric.degraded_links()
    print(f"  degraded after arbitration: {len(deg)}")
    shifts = np.array([l.spectral_shift for l in fabric.links])
    print(f"  LtC barrel shifts: {np.bincount(shifts, minlength=cfg.grid.n_ch).tolist()}")

    if deg:
        fabric, rounds = rearbitrate(fabric, cfg, seed=1)
        print(f"  re-arbitration rounds: {rounds}; "
              f"still degraded: {len(fabric.degraded_links())}")

    # inject a thermal event knocking lanes off 3 links, then recover
    for i in np.random.default_rng(0).integers(0, len(fabric.links), 3):
        l = fabric.links[int(i)]
        fabric.links[int(i)] = dataclasses.replace(
            l, lanes_up=max(0, l.lanes_up - 3), failure="zero_lock"
        )
    print(f"\ninjected lane loss -> bandwidth fraction {fabric.bandwidth_fraction:.3f}")
    fabric, rounds = rearbitrate(fabric, cfg, seed=2)
    print(f"recovered in {rounds} round(s) -> bandwidth fraction "
          f"{fabric.bandwidth_fraction:.3f}")

    # effect on the cross-pod roofline collective term
    frac = max(fabric.bandwidth_fraction, 1e-3)
    print(
        f"\ncross-pod collective term scale: x{1.0/frac:.2f} "
        f"(worst-link usable lanes {frac:.3f}) — consumed by the scheduler's "
        "chunk-size rescale (runtime/trainer.py)"
    )


if __name__ == "__main__":
    main()
