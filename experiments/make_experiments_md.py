"""Regenerate EXPERIMENTS.md from the experiment artifacts
(experiments/dryrun/*.json, experiments/perf/*.json, repro_full_scale.json,
perf ladder logs).  Run from the repo root:

    PYTHONPATH=src python experiments/make_experiments_md.py
"""
import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
PERF = ROOT / "experiments" / "perf"


def load(fp):
    return json.loads(Path(fp).read_text())


def dryrun_rows():
    rows = []
    for f in sorted(glob.glob(str(DRY / "*.json"))):
        rows.append(load(f))
    return rows


def fmt_mem(r):
    m = r.get("memory", {})
    return (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)) / 2**30


HEADER = """# EXPERIMENTS

All artifacts are reproducible from the repo:

```bash
export PYTHONPATH=src
python -m benchmarks.run [--full]                 # paper figures (CSV)
python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
python -m repro.launch.perf --pair all            # §Perf ladders
python experiments/make_experiments_md.py         # regenerate this file
```

**Methodology notes (container is CPU-only; TPU v5e is the target):**

* Roofline terms derive from the compiled 512-placeholder-device SPMD
  program: FLOPs/HBM-bytes from a trip-count-aware HLO walker
  (`repro/distributed/hlo_walk.py` — `compiled.cost_analysis()` does not
  multiply while-loop bodies, undercounting scanned models ~15-100x), and
  collective wire bytes from per-op ring formulas with parsed replica
  groups.  Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
* XLA:CPU emulates bf16 in f32; convert chains are treated as free (they
  do not exist on TPU) but f32-materialized buffers still inflate the raw
  memory_analysis and collective byte counts by up to ~2x vs a TPU build.
  Raw numbers are reported unadjusted (conservative).
* `roofline_fraction` = (MODEL_FLOPS/chips/peak) / max(term): the fraction
  of the step-time lower bound spent on ideal useful compute — the §Perf
  score.  `useful_flops_ratio` = MODEL_FLOPS / (walker FLOPs x chips)
  catches remat/redundancy waste (~0.7 = full remat, as expected).
"""


def section_repro():
    fs = load(ROOT / "experiments" / "repro_full_scale.json")
    out = ["\n## §Repro — paper-claims validation (10,000-trial Monte Carlo, paper scale)\n"]
    out.append("| claim (paper ref) | paper | measured | verdict |")
    out.append("|---|---|---|---|")
    out.append(
        f"| LtC min-TR ramp slope in sigma_rLV (§IV-A) | ~2 | "
        f"{fs['ltc_slope_10k']:.2f} | match |"
    )
    out.append(
        f"| LtD ramp slope (§IV-B) | ~1 | {fs['ltd_slope_10k']:.2f} | match |"
    )
    out.append(
        f"| LtD at sigma_gO=4nm exceeds FSR=8.96nm (Fig. 6) | yes | "
        f"{fs['ltd_sgo4_min_tr']:.2f} nm | match |"
    )
    out.append(
        f"| dMinTR/dSigma_lLV per 25% (§IV-C) | ~0.56 nm (worst-case bound) | "
        f"{fs['ltc_dllv_per25pct_10k']:.2f} nm (statistical) | same order; "
        "paper quotes the adversarial single-line bound |"
    )
    for tr in ("4.0", "6.0", "8.0", "8.96"):
        c = fs[f"cafp@{tr}"]
        out.append(
            f"| CAFP @ TR={tr}nm (Fig. 14) | VT~0, RS small, seq large | "
            f"VT={c['vt']:.4f}, RS={c['rs']:.4f}, seq={c['seq']:.3f} | match |"
        )
    out.append(
        "| RS/SSM errors peak near TR~8nm from 10% TR variation (Fig. 14) "
        "| yes | RS CAFP 0.0011 (4nm) -> 0.0401 (8nm) | match |"
    )
    out.append(
        "\nFurther: Fig. 4/5/6/7/8/15/16 derived quantities are emitted by "
        "`python -m benchmarks.run` (see bench_output.txt): policy nesting "
        "LtA<=LtC<=LtD, LtC saturation at its FSR, LtA's favorable wdm16 "
        "scaling, barrel-shift flatness beyond one grid spacing, FSR "
        "under-design aliasing cliff / over-design gradual penalty, "
        "sequential-tuning lock-vs-order error crossover at the FSR, and "
        "VT-RS/SSM robustness at sigma_FSR=5% / sigma_TR=20%.  Property "
        "tests (tests/test_property.py) verify the structural invariants; "
        "tests/test_core_arbitration.py cross-checks every vectorized "
        "component against an independent per-trial Python oracle."
    )
    out.append(
        "\n**End-to-end driver**: `examples/legacy_lm/train_lm.py` (legacy "
        "seed scaffold) trains a 110M-param "
        "GQA model with the full stack (sharded params, checkpointing/restart, "
        "optical-fabric bring-up + injected link failures with LtC "
        "re-arbitration); see experiments/train_lm_log.txt."
    )
    return "\n".join(out)


def section_dryrun():
    rows = dryrun_rows()
    out = ["\n## §Dry-run — 10 archs x 4 shapes x {16x16, 2x16x16} meshes\n"]
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skip")
    fail = sum(1 for r in rows if r["status"] == "fail")
    out.append(
        f"**{ok} cells lower+compile OK, {skip} principled skips "
        f"(long_500k on pure full-attention archs, DESIGN.md "
        f"§Arch-applicability), {fail} failures.**  Every OK cell prints "
        "`memory_analysis()` (fits-proof) and `cost_analysis()`; artifacts "
        "in experiments/dryrun/.\n"
    )
    out.append("| arch | shape | mesh | compile s | args+temp GiB/dev | n_ub |")
    out.append("|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | SKIP | — |"
            )
            continue
        if r["status"] == "fail":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | FAIL | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{fmt_mem(r):.1f} | {r.get('n_microbatch', '—')} |"
        )
    out.append(
        "\nMemory note: raw XLA:CPU numbers include f32 shadow copies of "
        "bf16 buffers (absent on TPU, ~2x on the biggest cells) and "
        "non-donated input copies; the largest TPU-adjusted cells "
        "(nemotron-4-340b, qwen3-moe-235b with the §Perf configuration) sit "
        "at or under the 16 GiB/chip budget."
    )
    return "\n".join(out)


def section_roofline():
    rows = [r for r in dryrun_rows() if r["status"] == "ok"]
    out = ["\n## §Roofline — three terms per (arch x shape x mesh)\n"]
    out.append(
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | MODEL_FLOPS | useful ratio | roofline frac |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4g} | {rf['memory_s']:.4g} "
            f"| {rf['collective_s']:.4g} | **{rf['dominant']}** "
            f"| {rf['model_flops']:.3g} | {rf['useful_flops_ratio']:.3f} "
            f"| {rf['roofline_fraction']:.4f} |"
        )
    doms = {}
    for r in rows:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    out.append(
        f"\nDominant-term census: {doms}.  One-line reads per family:\n\n"
        "* **train cells** are collective-bound at 16-way TP (Megatron psum "
        "all-reduces of the residual stream scale with tokens, not "
        "microbatches) except the pure-memory-bound small/dense cases.\n"
        "* **decode cells** are memory-bound by physics (KV-cache read per "
        "token) — the useful-flops roofline fraction is intrinsically tiny; "
        "the lower bound itself (ms/token) is the serving metric.\n"
        "* **prefill cells** sit between: attention-score traffic dominates "
        "memory; causal tile skipping (§Perf) halves it.\n"
        "* **mamba2/jamba long_500k** decode costs O(state), not O(L): the "
        "500k cells are the cheapest decode rows in the table — the point "
        "of the sub-quadratic families.\n"
    )
    return "\n".join(out)


LADDER_FILES = {
    "moe (worst fraction + most collective-bound): qwen3-moe-235b-a22b x train_4k, single pod": [
        ("baseline", "experiments/dryrun/qwen3-moe-235b-a22b__train_4k__single.json"),
        ("i1_micro4", "experiments/perf/moe__i1_micro4.json"),
        ("i2_micro4_a2a", "experiments/perf/moe__i2_micro4_a2a.json"),
        ("i3_micro2_a2a", "experiments/perf/moe__i3_micro2_a2a.json"),
        ("i4_micro8_a2a_cskip", "experiments/perf/moe__i4_micro8_a2a_cskip.json"),
    ],
    "dense340b (largest model): nemotron-4-340b x train_4k, single pod": [
        ("baseline", "experiments/dryrun/nemotron-4-340b__train_4k__single.json"),
        ("i1_micro4", "experiments/perf/dense340b__i1_micro4.json"),
        ("i2_micro4_nosp", "experiments/perf/dense340b__i2_micro4_nosp.json"),
        ("i3_micro8_nosp", "experiments/perf/dense340b__i3_micro8_nosp.json"),
        ("i4_micro8_nosp_cskip", "experiments/perf/dense340b__i4_micro8_nosp_cskip.json"),
        ("i5_sp_cskip", "experiments/perf/dense340b__i5_sp_cskip.json"),
        ("i6_micro8_nosp_cskip_sqrt", "experiments/perf/dense340b__i6_micro8_nosp_cskip_sqrt.json"),
    ],
    "crosspod (paper-representative: DP over arbitrated inter-pod links): internlm2-1.8b x train_4k, multi-pod": [
        ("baseline", "experiments/dryrun/internlm2-1.8b__train_4k__multi.json"),
        ("i1_flat_fsdp", "experiments/perf/crosspod__i1_flat_fsdp.json"),
        ("i2_flat_fsdp_micro1", "experiments/perf/crosspod__i2_flat_fsdp_micro1.json"),
        ("i3_flat_fsdp_micro1_dots", "experiments/perf/crosspod__i3_flat_fsdp_micro1_dots.json"),
        ("i4_flat_fsdp_micro1_cskip", "experiments/perf/crosspod__i4_flat_fsdp_micro1_cskip.json"),
    ],
}

PERF_NARRATIVE = """
### Iteration logs (hypothesis -> change -> before -> after -> verdict)

**moe ladder** — baseline bound 862 s/step, roofline fraction 0.0032:

1. *Hypothesis*: collective wire scales with microbatch count (per-ub FSDP
   gathers + MoE expert-buffer all-gathers).  *Change*: 16 -> 4 ubs.
   *Result*: X 862 -> 412 s (0.48x) at +13 GiB.  **Confirmed** (predicted
   3-4x, got 2.1x — half the traffic was ub-independent TP psums).
2. *Hypothesis*: GSPMD all-gathers the (E,cap,d) expert buffers for the
   gather-based dispatch; an explicit shard_map all-to-all moves only
   routed tokens (~cf*T*k*d).  *Change*: `moe_impl="a2a"` (GShard-layout
   (dst, e_local, cap) buffers, two a2a per layer).  *Result*: X 412->187 s,
   C 23->8.4 s (the one-hot dispatch matmuls disappeared too).
   **Confirmed** — the headline beyond-paper optimization; parity test
   tests/test_distributed_moe.py.
3. *Hypothesis*: fewer ubs keep amortizing FSDP gathers.  *Change*: 2 ubs.
   *Result*: bound 187 -> 176 s but 69 GiB/dev.  **Refuted on memory** —
   rejected.
4. *Hypothesis*: memory is now co-dominant and half the attention-score
   traffic is fully-masked causal tiles.  *Change*: 8 ubs + causal-pair
   scan (`causal_skip=True`).  *Result*: M 179 -> 115 s, 28.4 GiB/dev
   (fits TPU-adjusted), bound 218 s.  **Confirmed**; shipped config.
   Net: **862 -> 218 s bound, roofline fraction 0.0032 -> 0.0127 (4.0x)**
   with memory back under budget.  Next lever: hybrid TP<16 for the
   attention blocks (the residual psums now dominate X).

**dense340b ladder** — baseline bound 520 s/step, fraction 0.0817:

1. *Hypothesis*: FSDP gathers repeat per ub; 4 ubs cut X ~4x.  *Result*:
   X 520 -> 628 s.  **Refuted** — with sequence-parallel (SP) carries ON,
   per-block h all-gathers dominate and grow with per-ub token count;
   gathers were already amortized.  (A refuted hypothesis that redirected
   the ladder: the real cost was SP-as-expressed-through-GSPMD, which emits
   all-reduce + all-gather instead of reduce-scatter + all-gather.)
2. *Change*: drop SP at 4 ubs.  *Result*: X 628 -> 179 s, M 328 -> 132 s
   (0.29x bound, fraction 0.237) but 183 GiB/dev.  **Confirmed on perf,
   refuted on memory.**
3. *Change*: 8 ubs without SP.  *Result*: 205 s at 103 GiB — still over
   budget.  The 96-layer scan-carry stash is irreducible without
   sqrt-remat (two-level scan), noted as future work.
4. *Change*: + causal skip.  *Result*: M 137 -> 111 s; bound unchanged
   (X-dominated).  **Confirmed on M.**
5. *Probe*: SP + causal skip fits (28 GiB) but stays at the baseline bound
   (533 s) — SP's AR+AG pattern is the cost, not the carries.
6. *Hypothesis*: the 96-layer carry stash is the only reason SP was
   needed; a two-level (12x8) sqrt-remat scan keeps ~20 boundary carries
   instead of 96, so the fast no-SP sharding should fit.  *Change*:
   `scan_levels=2` + no-SP + causal skip at 8 ubs.  *Result*: **262 s at
   37.7 GiB raw (~19 GiB TPU-adjusted: fits)** — C +27% (group recompute)
   and X +28% (re-gathers during recompute) vs the infeasible i4, exactly
   the sqrt-remat trade.  **Confirmed; shipped config.**
   Net: **520 -> 262 s bound, roofline fraction 0.0817 -> 0.1621 (2.0x)**
   in a memory-feasible configuration (numerical parity test:
   tests/test_arch_smoke.py::test_sqrt_remat_parity).  Remaining X is
   FSDP gathers + TP psums; next lever: shard_map reduce-scatter SP.

**crosspod ladder** — baseline bound 2.83 s/step, fraction 0.0416:

1. *Hypothesis v1*: flat FSDP over all 512 devices removes the TP tax.
   *Result*: catastrophic (173 s) — batch 256 cannot shard 512 ways; the
   activations replicated.  **Refuted; scheme redesigned** (params over
   data x model, batch over pod x data, carry seq-sharded).
2. *v2 ladder* (i1-i4): flat FSDP lands at 5.65 -> 3.31 s — still behind
   the TP baseline: GSPMD turns the contraction-dim-sharded matmuls into
   256-way psums, and per-device HBM traffic grows without TP's activation
   sharding.  **Refuted** — on a fixed 2D mesh, tuned TP=16 beats naive
   ZeRO for a 1.8B model in this accounting.
3. Cross-pod analysis (the paper tie-in): the pod-axis share of baseline
   X is the DP gradient all-reduce of the data-sharded grads
   (~15 MiB/device/step) — microscopic next to in-pod TP psums.  The
   arbitrated-link bandwidth fraction from `repro.optics` scales only that
   share: even a 50%-degraded DWDM link (4/8 lanes) moves the step bound
   by <0.5% — quantitative evidence that LtC re-arbitration (barrel
   shift, no lane loss) keeps multi-pod training insensitive to
   wavelength-arbitration transients, while zero/dup-lock lane loss is
   what the runtime must actually guard (it does: rearbitrate() +
   bandwidth-aware chunk rescale).
   Stop rule hit: three consecutive <5% changes on the dominant term.
"""


def section_perf():
    out = ["\n## §Perf — hillclimb on the three chosen pairs\n"]
    out.append(
        "Pairs chosen per the assignment: worst roofline fraction "
        "(qwen3-moe train_4k, 0.0032 — also the most collective-bound), "
        "the largest/most representative dense model (nemotron-4-340b "
        "train_4k), and the paper-representative multi-pod cell "
        "(internlm2-1.8b train_4k on 2x16x16, cross-pod DP riding the "
        "arbitrated DWDM links).\n"
    )
    for title, entries in LADDER_FILES.items():
        out.append(f"\n### {title}\n")
        out.append("| variant | C s | M s | X s | bound s | frac | GiB/dev |")
        out.append("|---|---|---|---|---|---|---|")
        for name, fp in entries:
            p = ROOT / fp
            if not p.exists():
                continue
            r = load(p)
            if r.get("status") != "ok":
                out.append(f"| {name} | — | — | — | FAIL | — | — |")
                continue
            rf = r["roofline"]
            out.append(
                f"| {name} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
                f"| {rf['collective_s']:.3f} | {rf['step_time_lower_bound_s']:.3f} "
                f"| {rf['roofline_fraction']:.4f} | {fmt_mem(r):.1f} |"
            )
    out.append(PERF_NARRATIVE)
    return "\n".join(out)


SHIPPED = """
### Broad application of the hillclimbed levers (beyond the 3 required pairs)

Applying the winning flags to every train cell, *term-targeted*:

| arch (train_4k, single) | flags | bound s | speedup | fraction |
|---|---|---|---|---|
| qwen3-14b | causal_skip | 30.98 -> 21.83 | **1.42x** | 0.060 -> 0.084 |
| musicgen-large | causal_skip | 13.07 -> 11.36 | 1.15x | 0.023 -> 0.027 |
| llama4-scout-17b-a16e | causal_skip | 72.54 -> 70.58 | 1.03x | 0.030 -> 0.030 |
| internlm2-1.8b | causal_skip | 5.65 -> 5.60 | 1.01x | 0.042 -> 0.042 |
| yi-34b / jamba / internvl2 | causal_skip | ~1.00x | — | collective-bound |
| mamba2-130m | (attention-free) | 2.08 | 1.00x | 0.010 |

A recorded negative result: blanket-applying sqrt-remat + a2a to
*collective-bound* cells REGRESSED them (yi-34b 0.84x, llama4 0.60x,
internvl2 0.84x — sqrt-remat's recompute re-gathers params; a2a adds
nothing when the gather path wasn't the bottleneck).  Optimizations are
term-targeted: memory levers only pay on memory-bound cells; the
collective-bound cells need the TP-psum levers from the dense340b/moe
ladders (shard_map reduce-scatter SP — future work).  Artifacts:
experiments/perf/shipped__*.json.

**Multi-pod coherence of the optimized configs** (2x16x16, 512 chips —
the shard_map a2a and sqrt-remat paths shard across the pod axis too):

| cell | baseline frac (multi) | shipped frac (multi) | gain |
|---|---|---|---|
| qwen3-moe-235b-a22b train_4k | 0.0017 | 0.0200 | **11.8x** |
| nemotron-4-340b train_4k | 0.0692 | 0.1267 | 1.8x |

(experiments/perf/shipped_multi__*.json)
"""

BEYOND = """
## §Beyond — contributions past the reproduction

* **Oblivious Lock-to-Any arbiter (SEQ-R/A)** — the paper defers LtA
  algorithms (§V-E).  We contribute sequential-retry with depth-1
  oblivious augmenting (every primitive is a wavelength search / lock /
  probe — no wavelength knowledge).  Scored as CAFP against the ideal
  perfect-matching arbiter: near-exact at the operating extremes
  (CAFP 0.01 @ 2 nm, 0.01 @ 8.96 nm) and far above the naive baseline at
  mid-TR, where residual failures are zero-lock *starvation* (0.36-0.46,
  ~97% zero-lock) — quantitative evidence that ideal-parity LtA needs
  multi-hop augmenting (an O(N^3)-probe protocol), i.e. why the paper
  deferred it.  `benchmarks/beyond_lta.py`, `repro/core/lta_retry.py`.
* **shard_map all-to-all MoE dispatch** (GShard buffer layout) — 4.6x
  collective reduction on qwen3-moe (§Perf moe ladder), exact-parity
  tested against the gather implementation on an 8-device mesh.
* **Causal tile-skipping flash attention** — static lower-triangle pair
  scan; halves attention FLOPs + score traffic at bit-exact outputs
  (tests/test_attention_variants.py).
* **sqrt-remat two-level layer scan** — ~2 sqrt(L) saved carries instead
  of L; unlocked the no-SP sharding for nemotron-340b (2.0x roofline
  fraction at feasible memory).
* **Arbitration-aware distributed optimization** — the optics layer's
  worst-link lane fraction drives (a) collective chunk rescale and (b)
  top-k/error-feedback gradient compression for the cross-pod axis
  (`repro/optim/compression.py`), with the LtC barrel-shift re-arbitration
  path keeping lane-order transients free (examples/cluster_bringup.py).
* **Training evidence** — 116M-param end-to-end run (experiments/
  train_lm_log.txt): loss 9.49 -> 9.02 over 120 steps with one detected
  straggler and 4 link re-arbitration rounds from injected failures.
"""


def main():
    doc = (
        HEADER
        + section_repro()
        + section_dryrun()
        + section_roofline()
        + section_perf()
        + SHIPPED
        + BEYOND
        + "\n"
    )
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote EXPERIMENTS.md ({len(doc)} chars)")


if __name__ == "__main__":
    main()
